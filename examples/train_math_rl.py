"""Math-task RL driver (paper §4.3 analog): integer-answer synthetic
problems with exact-match verification, built by the one-call session
builder — the only difference from the logic driver is ``task="math"``.

  PYTHONPATH=src python examples/train_math_rl.py --groups 2
"""
import argparse

from repro.core.buffer import Mode
from repro.core.policy import available_policies
from repro.rl.session import RLSession, SessionConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", "--strategy", dest="policy",
                    default="sorted", choices=available_policies())
    ap.add_argument("--mode", default="on_policy",
                    choices=["on_policy", "partial"])
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--responses-per-prompt", type=int, default=1)
    ap.add_argument("--advantage", default="reinforce_pp",
                    choices=["reinforce_pp", "grpo"])
    args = ap.parse_args()
    cfg = SessionConfig(
        task="math", policy=args.policy, mode=Mode(args.mode),
        n_groups=args.groups, rollout_batch=16, group_size=2,
        update_batch=16, max_gen_len=8, max_total_len=96, sft_steps=100,
        d_model=96, layers=2, eval_size=32,
        responses_per_prompt=args.responses_per_prompt,
        advantage_kind=args.advantage)
    out = RLSession.from_config(cfg).run()
    print("final eval:", out["final_eval"])
    print("rollout:", out["rollout_metrics"])


if __name__ == "__main__":
    main()
