"""Quickstart: SortedRL scheduling in ~40 lines.

Picks the length-aware policy from the registry, hands it to the
orchestrator, and runs it against the discrete-event engine on the
paper's workload shape, printing the bubble ratio + micro-curriculum.
Swap the policy name ("baseline", "posthoc_sort", "pipelined", ...) to
compare strategies — the orchestration mechanics are shared.

  PYTHONPATH=src python examples/quickstart.py
"""
import random

from repro.core.buffer import Mode, StatefulRolloutBuffer
from repro.core.orchestrator import RolloutOrchestrator, SortedRLConfig
from repro.core.policy import make_policy
from repro.rollout.sim import SimEngine, lognormal_lengths


def main():
    rng = random.Random(0)
    prompts = [[1] * rng.randint(32, 128) for _ in range(512)]

    engine = SimEngine(capacity=128, max_gen_len=8192,
                       length_sampler=lognormal_lengths(median=2000,
                                                        sigma=1.5,
                                                        max_len=8192))
    buffer = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=128, group_size=4,
                         update_batch=128, max_gen_len=8192)

    batches = []

    def train_fn(req):
        lens = [e.gen_len for e in req.entries]
        batches.append(lens)
        print(f"update v{req.version}: {len(req.entries)} trajectories, "
              f"mean len {sum(lens)/len(lens):.0f} "
              f"(sorted: {lens == sorted(lens)})")

    orch = RolloutOrchestrator(engine, buffer, cfg, make_policy("sorted"),
                               train_fn)
    orch.run_group(prompts)
    print("\nrollout metrics:", orch.metrics.summary())
    print("micro-curriculum batch means:",
          [round(sum(b) / len(b)) for b in batches])


if __name__ == "__main__":
    main()
