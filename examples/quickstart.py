"""Quickstart: SortedRL scheduling in ~40 lines.

Runs the length-aware controller against the discrete-event engine on the
paper's workload shape and prints the bubble ratio + micro-curriculum.

  PYTHONPATH=src python examples/quickstart.py
"""
import random

from repro.core.buffer import Mode, StatefulRolloutBuffer
from repro.core.controller import SortedRLConfig, SortedRLController
from repro.rollout.sim import SimEngine, lognormal_lengths


def main():
    rng = random.Random(0)
    prompts = [[1] * rng.randint(32, 128) for _ in range(512)]

    engine = SimEngine(capacity=128, max_gen_len=8192,
                       length_sampler=lognormal_lengths(median=2000,
                                                        sigma=1.5,
                                                        max_len=8192))
    buffer = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=128, group_size=4,
                         update_batch=128, max_gen_len=8192)

    batches = []

    def train_fn(entries, version):
        lens = [e.gen_len for e in entries]
        batches.append(lens)
        print(f"update v{version}: {len(entries)} trajectories, "
              f"mean len {sum(lens)/len(lens):.0f} "
              f"(sorted: {lens == sorted(lens)})")

    ctl = SortedRLController(engine, buffer, cfg, train_fn)
    ctl.run_group(prompts)
    print("\nrollout metrics:", ctl.metrics.summary())
    print("micro-curriculum batch means:",
          [round(sum(b) / len(b)) for b in batches])


if __name__ == "__main__":
    main()
