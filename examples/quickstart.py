"""Quickstart: SortedRL scheduling in ~40 lines.

Picks the length-aware policy from the registry, hands it to the
orchestrator, and runs it against the discrete-event engine on the
paper's workload shape, printing the bubble ratio + micro-curriculum.
Swap the policy name ("baseline", "posthoc_sort", "pipelined", ...) to
compare strategies — the orchestration mechanics are shared.

The second half re-runs the same workload with rollout sharded over four
engine replicas behind an EngineGroup (length-aware load balancing) —
the orchestrator and policy are reused UNCHANGED; only the engine
changes.  `RLSession.from_config(SessionConfig(num_replicas=4))` wires
the same thing declaratively.

  PYTHONPATH=src python examples/quickstart.py
"""
import random

from repro.core.buffer import Mode, StatefulRolloutBuffer
from repro.core.orchestrator import RolloutOrchestrator, SortedRLConfig
from repro.core.policy import make_policy
from repro.rollout.group import EngineGroup
from repro.rollout.sim import SimEngine, lognormal_lengths


def main():
    rng = random.Random(0)
    prompts = [[1] * rng.randint(32, 128) for _ in range(512)]

    engine = SimEngine(capacity=128, max_gen_len=8192,
                       length_sampler=lognormal_lengths(median=2000,
                                                        sigma=1.5,
                                                        max_len=8192))
    buffer = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=128, group_size=4,
                         update_batch=128, max_gen_len=8192)

    batches = []

    def train_fn(req):
        lens = [e.gen_len for e in req.entries]
        batches.append(lens)
        print(f"update v{req.version}: {len(req.entries)} trajectories, "
              f"mean len {sum(lens)/len(lens):.0f} "
              f"(sorted: {lens == sorted(lens)})")

    orch = RolloutOrchestrator(engine, buffer, cfg, make_policy("sorted"),
                               train_fn)
    orch.run_group(prompts)
    print("\nrollout metrics:", orch.metrics.summary())
    print("micro-curriculum batch means:",
          [round(sum(b) / len(b)) for b in batches])

    # the SAME 512 prompts, rollout sharded over 4 data-parallel replicas
    # — the orchestrator and policy run unchanged against the EngineGroup
    # facade.  A shared length_table pins each trajectory's hidden length
    # to its uid, so lengths stay a property of the prompt rather than of
    # whichever replica serves it (routing-invariant workload).
    sample = lognormal_lengths(median=2000, sigma=1.5, max_len=8192)
    lengths = {uid: sample(rng) for uid in range(len(prompts))}
    group = EngineGroup([
        SimEngine(capacity=32, max_gen_len=8192, seed=i,
                  length_table=lengths)
        for i in range(4)])
    orch4 = RolloutOrchestrator(group, StatefulRolloutBuffer(Mode.PARTIAL),
                                cfg, make_policy("sorted"), lambda req: None)
    orch4.run_group(prompts)
    m = orch4.metrics.summary()
    print(f"\n4-replica rollout: bubble={m['bubble_ratio']} "
          f"replica_bubble={m['replica_bubble_ratio']} "
          f"busy_replicas={m['replica_busy']} steals={m['steal_count']}")


if __name__ == "__main__":
    main()
