"""End-to-end RL training driver (paper §4.2 at CPU scale): SFT warm-up,
then Reinforce++ with the chosen scheduling strategy on Knights & Knaves.

  PYTHONPATH=src python examples/train_logic_rl.py --strategy sorted \
      --mode on_policy --groups 4
"""
import argparse
import json

from repro.core.buffer import Mode
from repro.train.loop import RLExperimentConfig, run_logic_rl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="sorted",
                    choices=["sorted", "baseline", "posthoc_sort"])
    ap.add_argument("--mode", default="on_policy",
                    choices=["on_policy", "partial"])
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--rollout-batch", type=int, default=32)
    ap.add_argument("--update-batch", type=int, default=32)
    ap.add_argument("--group-size", type=int, default=2)
    ap.add_argument("--sft-steps", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = RLExperimentConfig(
        strategy=args.strategy, mode=Mode(args.mode),
        rollout_batch=args.rollout_batch, update_batch=args.update_batch,
        group_size=args.group_size, n_groups=args.groups,
        sft_steps=args.sft_steps, seed=args.seed)
    out = run_logic_rl(cfg)
    print("final eval:", out["final_eval"])
    print("rollout:", out["rollout_metrics"])
    for ev in out["evals"]:
        print("  eval", ev)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1, default=str)


if __name__ == "__main__":
    main()
