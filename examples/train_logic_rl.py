"""End-to-end RL training driver (paper §4.2 at CPU scale): SFT warm-up,
then Reinforce++ with the chosen scheduling policy on Knights & Knaves,
built by the one-call session builder.

  PYTHONPATH=src python examples/train_logic_rl.py --policy sorted \
      --mode on_policy --groups 4
"""
import argparse
import json

from repro.core.buffer import Mode
from repro.core.policy import available_policies
from repro.rl.session import RLSession, SessionConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", "--strategy", dest="policy",
                    default="sorted", choices=available_policies())
    ap.add_argument("--mode", default="on_policy",
                    choices=["on_policy", "partial"])
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--rollout-batch", type=int, default=32)
    ap.add_argument("--update-batch", type=int, default=32)
    ap.add_argument("--group-size", type=int, default=2)
    ap.add_argument("--sft-steps", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = SessionConfig(
        task="logic", policy=args.policy, mode=Mode(args.mode),
        rollout_batch=args.rollout_batch, update_batch=args.update_batch,
        group_size=args.group_size, n_groups=args.groups,
        sft_steps=args.sft_steps, seed=args.seed)
    out = RLSession.from_config(cfg).run()
    print("final eval:", out["final_eval"])
    print("rollout:", out["rollout_metrics"])
    for ev in out["evals"]:
        print("  eval", ev)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1, default=str)


if __name__ == "__main__":
    main()
