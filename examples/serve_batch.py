"""Batched serving example: a small LM behind the slot engine answering a
stream of Knights & Knaves prompts with continuous batching — the same
engine the RL controller drives, used inference-only.

  PYTHONPATH=src python examples/serve_batch.py --requests 24 --slots 8
"""
import argparse
import time

import jax

from repro.core.buffer import BufferEntry
from repro.data import logic
from repro.models.model import build_model
from repro.rollout.engine import SlotEngine
from repro.train.loop import tiny_lm_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-gen", type=int, default=16)
    args = ap.parse_args()

    vocab = logic.VOCAB
    model = build_model(tiny_lm_config(len(vocab), d_model=96, layers=2))
    params = model.init_params(jax.random.PRNGKey(0))
    engine = SlotEngine(model, lambda: params, capacity=args.slots,
                        max_total_len=128, max_gen_len=args.max_gen,
                        eos_id=vocab.eos_id, pad_id=vocab.pad_id,
                        temperature=0.0)

    gen = logic.LogicTaskGenerator(seed=1)
    prompts, metas = gen.batch(args.requests)
    queue = [BufferEntry(uid=i, prompt=p, meta=m)
             for i, (p, m) in enumerate(zip(prompts, metas))]
    outputs = {e.uid: [] for e in queue}
    t0 = time.monotonic()
    steps = 0
    while queue or engine.active_uids():
        free = engine.free_slots()
        if free and queue:
            engine.submit(queue[:free], 0)   # continuous batching
            queue = queue[free:]
        for ev in engine.step():
            outputs[ev.uid].append(ev.token)
        steps += 1
    dt = time.monotonic() - t0
    total = sum(len(v) for v in outputs.values())
    print(f"served {args.requests} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, {steps} engine steps)")
    for uid in list(outputs)[:3]:
        print(f"  req{uid}: {' '.join(vocab.decode(outputs[uid]))}")


if __name__ == "__main__":
    main()
