"""Knights & Knaves puzzle generator + rule-based verifier (LogicRL analog,
paper §4.1 / Xie et al. 2025).

Puzzles: n inhabitants, each a knight (truth-teller) or knave (liar); each
makes one statement; solvers must deduce every role.  We generate puzzles
with a *unique* solution (brute-force check over 2^n assignments) across a
difficulty mixture (3..7 characters), mirroring the LogicRL training mix.

Encoding (closed word-level language):
  prompt   = <bos> C0 says S0 <sep> C1 says S1 <sep> ... <ans>
  response = <think> ... free tokens ... <ans> r0 r1 ... r_{n-1} <eos>
where r_i in {knight, knave}.  The verifier scores:
  +0.2  format (an <ans> followed by exactly n role tokens then <eos>)
  +0.8 * (correct roles / n), +1.0 bonus if all correct
(a graded rule-based reward so a small from-scratch policy has signal).
"""
from __future__ import annotations

import dataclasses
import itertools
import random
from typing import List, Sequence, Tuple

from repro.data.tokenizer import ANS, BOS, EOS, SEP, Vocab

NAMES = ["alice", "bob", "carol", "dave", "erin", "frank", "grace"]
ROLES = ["knight", "knave"]
WORDS = (NAMES + ROLES
         + ["says", "and", "or", "iff", "not", "is", "same", "diff"])

VOCAB = Vocab(WORDS)


# statements are closures over the hidden assignment ------------------------

@dataclasses.dataclass(frozen=True)
class Statement:
    kind: str          # "role" | "iff" | "or"
    a: int
    b: int = -1
    role: str = "knight"

    def eval(self, assign: Sequence[bool]) -> bool:
        if self.kind == "role":
            return assign[self.a] == (self.role == "knight")
        if self.kind == "iff":
            return assign[self.a] == assign[self.b]
        if self.kind == "or":
            return assign[self.a] or assign[self.b]
        raise ValueError(self.kind)

    def words(self) -> List[str]:
        if self.kind == "role":
            return [NAMES[self.a], "is", self.role]
        if self.kind == "iff":
            return [NAMES[self.a], "same", NAMES[self.b]]
        if self.kind == "or":
            return [NAMES[self.a], "or", NAMES[self.b], "is", "knight"]
        raise ValueError(self.kind)


@dataclasses.dataclass
class Puzzle:
    n: int
    statements: List[Statement]
    solution: Tuple[bool, ...]      # True = knight

    def consistent(self, assign: Sequence[bool]) -> bool:
        return all(st.eval(assign) == assign[i]
                   for i, st in enumerate(self.statements))

    def unique(self) -> bool:
        sols = [a for a in itertools.product([True, False], repeat=self.n)
                if self.consistent(a)]
        return len(sols) == 1 and tuple(sols[0]) == self.solution


def _random_statement(rng: random.Random, n: int, speaker: int,
                      assign: Sequence[bool]) -> Statement:
    others = [i for i in range(n) if i != speaker] or [speaker]
    kind = rng.choice(["role", "role", "iff", "or"])
    a = rng.choice(range(n))
    b = rng.choice(others)
    if kind == "role":
        st = Statement("role", a, role=rng.choice(ROLES))
    elif kind == "iff":
        st = Statement("iff", a, b)
    else:
        st = Statement("or", a, b)
    # knights speak truth, knaves lie: flip the statement if needed
    want = assign[speaker]
    if st.eval(assign) != want:
        if st.kind == "role":
            st = Statement("role", st.a,
                           role=("knave" if st.role == "knight" else "knight"))
        elif st.kind == "iff":
            # negate iff -> use role statement about a instead
            st = Statement("role", st.a,
                           role=("knight" if assign[st.a] == want else "knave"))
        else:
            st = Statement("role", st.a,
                           role=("knight" if assign[st.a] == want else "knave"))
    assert st.eval(assign) == want
    return st


def generate_puzzle(rng: random.Random, n: int,
                    max_tries: int = 200) -> Puzzle:
    for _ in range(max_tries):
        assign = tuple(rng.random() < 0.5 for _ in range(n))
        statements = [_random_statement(rng, n, i, assign) for i in range(n)]
        pz = Puzzle(n, statements, assign)
        if pz.unique():
            return pz
    # fall back: accept consistent-but-maybe-ambiguous (rare)
    return pz


def encode_prompt(pz: Puzzle, vocab: Vocab = VOCAB) -> List[int]:
    words = [BOS]
    for i, st in enumerate(pz.statements):
        words += [NAMES[i], "says"] + st.words()
        words.append(SEP)
    words.append(ANS)
    return vocab.encode(words)


def solution_words(pz: Puzzle) -> List[str]:
    return [ROLES[0] if k else ROLES[1] for k in pz.solution]


def encode_solution(pz: Puzzle, vocab: Vocab = VOCAB) -> List[int]:
    return vocab.encode(solution_words(pz) + [EOS])


@dataclasses.dataclass
class LogicMeta:
    solution: Tuple[bool, ...]
    n: int
    prompt_id: int = 0


def verify(generated: Sequence[int], meta: LogicMeta,
           vocab: Vocab = VOCAB) -> float:
    """Rule-based graded reward (see module docstring)."""
    words = vocab.decode(generated)
    n = meta.n
    # find the final answer segment: last n role tokens before <eos>
    if EOS in words:
        words = words[:words.index(EOS)]
        has_eos = True
    else:
        has_eos = False
    roles = [w for w in words if w in ROLES]
    answer = roles[-n:] if len(roles) >= n else roles
    reward = 0.0
    if has_eos and len(roles) >= n and all(
            w in ROLES for w in words[-n:] if words):
        reward += 0.2                      # format
    if answer:
        truth = [ROLES[0] if k else ROLES[1] for k in meta.solution]
        correct = sum(a == t for a, t in zip(answer, truth[:len(answer)]))
        reward += 0.8 * correct / n
        if len(answer) == n and correct == n and has_eos:
            reward += 1.0                  # exact solve bonus
    return reward


class LogicTaskGenerator:
    """Difficulty-mixed stream of (prompt_tokens, meta), LogicRL style."""

    def __init__(self, min_chars: int = 3, max_chars: int = 5, seed: int = 0):
        self.rng = random.Random(seed)
        self.min_chars = min_chars
        self.max_chars = max_chars
        self._pid = 0

    def sample(self) -> Tuple[List[int], LogicMeta]:
        n = self.rng.randint(self.min_chars, self.max_chars)
        pz = generate_puzzle(self.rng, n)
        meta = LogicMeta(solution=pz.solution, n=n, prompt_id=self._pid)
        self._pid += 1
        return encode_prompt(pz), meta

    def batch(self, k: int):
        pairs = [self.sample() for _ in range(k)]
        return [p for p, _ in pairs], [m for _, m in pairs]

    def sft_example(self) -> Tuple[List[int], List[int]]:
        """(prompt, target) pair for supervised warm-up (the paper starts
        from instruct models; warm-up plays that role at toy scale)."""
        prompt, meta = self.sample()
        pz_sol = [ROLES[0] if k else ROLES[1] for k in meta.solution]
        return prompt, VOCAB.encode(pz_sol + [EOS])
