"""Grouped dataloader: feeds the controller group-sized prompt batches
(the n*b "grouped loading" unit of paper §3.1) from any task generator,
with responses-per-prompt duplication and epoch accounting.
"""
from __future__ import annotations

from typing import Any, Iterator, List, Protocol, Tuple


class TaskGenerator(Protocol):
    def batch(self, k: int) -> Tuple[List[List[int]], List[Any]]: ...


class GroupedLoader:
    def __init__(self, gen: TaskGenerator, rollout_batch: int,
                 group_size: int, responses_per_prompt: int = 1):
        self.gen = gen
        self.rollout_batch = rollout_batch
        self.group_size = group_size
        self.k = max(1, responses_per_prompt)
        self.groups_served = 0

    @property
    def prompts_per_group(self) -> int:
        return self.rollout_batch * self.group_size

    def next_group(self) -> Tuple[List[List[int]], List[Any]]:
        """One group of n*b trajectories (n*b/k distinct prompts, each
        duplicated k times for multi-response advantages)."""
        n_unique = self.prompts_per_group // self.k
        prompts, metas = self.gen.batch(n_unique)
        out_p = [list(p) for p in prompts for _ in range(self.k)]
        out_m = [m for m in metas for _ in range(self.k)]
        self.groups_served += 1
        return out_p, out_m

    def stream(self) -> Iterator[Tuple[List[int], Any]]:
        """Ungrouped prompt stream (for the no-grouping ablation)."""
        while True:
            p, m = self.gen.batch(1)
            yield list(p[0]), m[0]
