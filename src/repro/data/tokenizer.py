"""Minimal word-level tokenizer for the synthetic RL tasks.

The paper trains on text datasets with a production tokenizer; our CPU-scale
end-to-end runs use closed synthetic languages (Knights & Knaves, integer
math), so a fixed word-level vocabulary is exact and dependency-free.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

PAD, BOS, EOS, SEP, ANS, THINK = "<pad>", "<bos>", "<eos>", "<sep>", "<ans>", "<think>"
SPECIALS = [PAD, BOS, EOS, SEP, ANS, THINK]


class Vocab:
    def __init__(self, words: Sequence[str]):
        self.itos: List[str] = list(SPECIALS) + [w for w in words
                                                 if w not in SPECIALS]
        self.stoi: Dict[str, int] = {w: i for i, w in enumerate(self.itos)}
        assert len(self.stoi) == len(self.itos), "duplicate words"

    def __len__(self) -> int:
        return len(self.itos)

    @property
    def pad_id(self) -> int:
        return self.stoi[PAD]

    @property
    def bos_id(self) -> int:
        return self.stoi[BOS]

    @property
    def eos_id(self) -> int:
        return self.stoi[EOS]

    def encode(self, words: Sequence[str]) -> List[int]:
        return [self.stoi[w] for w in words]

    def decode(self, ids: Sequence[int]) -> List[str]:
        return [self.itos[int(i)] for i in ids]
