"""Synthetic integer-answer math tasks (DAPO-Math-17k analog, §4.1).

Problems are modular-arithmetic expressions with a single integer answer,
verified by exact match — the same rule-based verification contract as the
paper's transformed AoPS problems.  Difficulty scales with expression depth
(more operands -> longer reasoning -> longer responses), giving the
length/difficulty correlation the micro-curriculum relies on.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Sequence, Tuple

from repro.data.tokenizer import ANS, BOS, EOS, Vocab

DIGITS = [str(d) for d in range(10)]
WORDS = DIGITS + ["+", "-", "*", "mod", "(", ")", "="]
MATH_VOCAB = Vocab(WORDS)


@dataclasses.dataclass
class MathMeta:
    answer: int
    depth: int
    prompt_id: int = 0


def _expr(rng: random.Random, depth: int) -> Tuple[List[str], int]:
    if depth == 0:
        v = rng.randint(0, 9)
        return [str(v)], v
    op = rng.choice(["+", "-", "*"])
    lw, lv = _expr(rng, depth - 1)
    rw, rv = _expr(rng, rng.randint(0, depth - 1))
    val = {"+": lv + rv, "-": lv - rv, "*": lv * rv}[op]
    return ["("] + lw + [op] + rw + [")"], val


def generate(rng: random.Random, depth: int) -> Tuple[List[int], MathMeta]:
    words, val = _expr(rng, depth)
    ans = val % 10
    prompt = [BOS] + words + ["mod", "1", "0", "=", ANS]
    return MATH_VOCAB.encode(prompt), MathMeta(answer=ans, depth=depth)


def verify(generated: Sequence[int], meta: MathMeta,
           vocab: Vocab = MATH_VOCAB) -> float:
    words = vocab.decode(generated)
    if EOS in words:
        words = words[:words.index(EOS)]
        has_eos = True
    else:
        has_eos = False
    digits = [w for w in words if w in DIGITS]
    reward = 0.0
    if has_eos and digits:
        reward += 0.2
        if digits[-1] == str(meta.answer):
            reward += 1.0
    return reward


class MathTaskGenerator:
    def __init__(self, min_depth: int = 1, max_depth: int = 3, seed: int = 0):
        self.rng = random.Random(seed)
        self.min_depth = min_depth
        self.max_depth = max_depth
        self._pid = 0

    def sample(self):
        d = self.rng.randint(self.min_depth, self.max_depth)
        toks, meta = generate(self.rng, d)
        meta.prompt_id = self._pid
        self._pid += 1
        return toks, meta

    def batch(self, k: int):
        pairs = [self.sample() for _ in range(k)]
        return [p for p, _ in pairs], [m for _, m in pairs]

    def sft_example(self):
        toks, meta = self.sample()
        return toks, MATH_VOCAB.encode([str(meta.answer), EOS])
