"""Logical-axis sharding: models annotate activations/params with logical
axis names; a context-installed rule set maps them to mesh axes.

Outside any context (unit tests, CPU smoke runs) every annotation is a
no-op, so the model code is mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, object]]]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Dict[str, object]):
    """Install (mesh, logical->mesh-axis rules) for the enclosed region.

    ``rules`` maps a logical axis name to a mesh axis name, a tuple of mesh
    axis names, or None (replicated).
    """
    prev = _current()
    _state.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _state.ctx = prev


def logical_to_spec(logical: Sequence[Optional[str]],
                    rules: Dict[str, object]) -> P:
    parts = []
    for name in logical:
        if name is None:
            parts.append(None)
        else:
            parts.append(rules.get(name))
    return P(*parts)


def logical_constraint(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint by logical axis names (no-op w/o context)."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_to_spec(logical, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Update-batch sharding (trainer data parallelism)
# ---------------------------------------------------------------------------

def data_shard_count() -> int:
    """Total mesh extent the logical ``batch`` axis maps to under the
    installed rules — the number of equal slices an update batch is split
    into.  1 outside any context (unit tests, CPU smoke runs)."""
    ctx = _current()
    if ctx is None:
        return 1
    mesh, rules = ctx
    spec = rules.get("batch")
    axes = spec if isinstance(spec, (tuple, list)) else (spec,)
    size = 1
    for a in axes:
        if a is not None:
            size *= mesh.shape[a]
    return size


def pad_update_batch(batch: Dict[str, object], multiple: int,
                     pad_token: int = 0) -> Dict[str, object]:
    """Pad the leading (batch) dim of every array up to a multiple.

    Pad rows are inert: ``tokens`` rows are all ``pad_token`` and every
    other array (loss_mask, advantages, old_logprobs, ...) is zero, so
    they contribute nothing to the loss — call this AFTER advantage
    computation so batch statistics see only real rows.
    """
    import numpy as np
    if multiple <= 1:
        return batch
    B = next(iter(batch.values())).shape[0]
    extra = (-B) % multiple
    if extra == 0:
        return batch
    out = {}
    for key, x in batch.items():
        fill = np.zeros((extra,) + tuple(x.shape[1:]), dtype=x.dtype)
        if key == "tokens":
            fill = fill + np.asarray(pad_token, dtype=x.dtype)
        out[key] = jax.numpy.concatenate([jax.numpy.asarray(x),
                                          jax.numpy.asarray(fill)], axis=0)
    return out


def shard_update_batch(batch: Dict[str, object],
                       pad_token: int = 0) -> Dict[str, object]:
    """Shard an update batch's leading dim over the installed mesh.

    Rows are padded to a multiple of :func:`data_shard_count` with inert
    rows (see :func:`pad_update_batch`), then each array is placed with a
    NamedSharding so every data shard holds an equal contiguous slice —
    the trainer's jitted step then runs data-parallel without any gather.
    Identity outside any :func:`axis_rules` context.
    """
    ctx = _current()
    if ctx is None:
        return batch
    mesh, rules = ctx
    batch = pad_update_batch(batch, data_shard_count(), pad_token)
    spec = rules.get("batch")
    out = {}
    for key, x in batch.items():
        x = jax.numpy.asarray(x)
        sharding = NamedSharding(
            mesh, P(spec, *([None] * (x.ndim - 1))))
        out[key] = jax.device_put(x, sharding)
    return out


# ---------------------------------------------------------------------------
# Standard rule sets
# ---------------------------------------------------------------------------

def train_rules(multi_pod: bool = False) -> Dict[str, object]:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ffn": "model",
        "vocab": "model",
        "experts": "model",
        "expert_capacity": None,
        "ssm_heads": "model",
        "ssm_state": None,
        # FSDP: parameters stored sharded over the data axis on this
        # logical axis (biggest dim of each weight), gathered on use.
        "fsdp": batch,
        "cache_seq": None,
    }


def decode_rules(multi_pod: bool = False, context_parallel: bool = False
                 ) -> Dict[str, object]:
    """Decode: batch over data; long-context mode shards the KV cache's
    sequence axis over `data` (distributed flash-decode combine)."""
    batch = ("pod", "data") if multi_pod else ("data",)
    r = train_rules(multi_pod)
    if context_parallel:
        r["batch"] = ("pod",) if multi_pod else None
        r["cache_seq"] = "data"
    else:
        r["batch"] = batch
    return r
