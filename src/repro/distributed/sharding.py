"""Logical-axis sharding: models annotate activations/params with logical
axis names; a context-installed rule set maps them to mesh axes.

Outside any context (unit tests, CPU smoke runs) every annotation is a
no-op, so the model code is mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, object]]]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Dict[str, object]):
    """Install (mesh, logical->mesh-axis rules) for the enclosed region.

    ``rules`` maps a logical axis name to a mesh axis name, a tuple of mesh
    axis names, or None (replicated).
    """
    prev = _current()
    _state.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _state.ctx = prev


def logical_to_spec(logical: Sequence[Optional[str]],
                    rules: Dict[str, object]) -> P:
    parts = []
    for name in logical:
        if name is None:
            parts.append(None)
        else:
            parts.append(rules.get(name))
    return P(*parts)


def logical_constraint(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint by logical axis names (no-op w/o context)."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_to_spec(logical, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Standard rule sets
# ---------------------------------------------------------------------------

def train_rules(multi_pod: bool = False) -> Dict[str, object]:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ffn": "model",
        "vocab": "model",
        "experts": "model",
        "expert_capacity": None,
        "ssm_heads": "model",
        "ssm_state": None,
        # FSDP: parameters stored sharded over the data axis on this
        # logical axis (biggest dim of each weight), gathered on use.
        "fsdp": batch,
        "cache_seq": None,
    }


def decode_rules(multi_pod: bool = False, context_parallel: bool = False
                 ) -> Dict[str, object]:
    """Decode: batch over data; long-context mode shards the KV cache's
    sequence axis over `data` (distributed flash-decode combine)."""
    batch = ("pod", "data") if multi_pod else ("data",)
    r = train_rules(multi_pod)
    if context_parallel:
        r["batch"] = ("pod",) if multi_pod else None
        r["cache_seq"] = "data"
    else:
        r["batch"] = batch
    return r
