"""Data-parallel rollout: an EngineProtocol facade over N engine replicas.

The paper's headline bubble-ratio win gets interesting once rollout is
sharded across multiple engine instances: the long tail of ONE replica
stalls the whole group barrier (Seer's "global load balancing" problem,
RollPacker's tail-rank rebalancing).  :class:`EngineGroup` makes a set of
replicas — SimEngine, SlotEngine, or any other
:class:`~repro.core.engine_api.EngineProtocol` backend, each with its own
KV memory — look like ONE engine, so :class:`RolloutOrchestrator`, every
registered :class:`SchedulerPolicy`, and both conformance suites run
against it unchanged.

Routing
-------
``submit`` routes each entry through a pluggable **balancer** (string
registry, mirroring the policy registry):

* ``least_tokens`` (default) — length-aware: pick the replica with the
  least *estimated outstanding decode tokens*.  The estimate uses the
  same signal the scheduling policies' length keys use — tokens already
  generated (``entry.gen_len``) against an EWMA of observed completion
  lengths — or a caller-supplied ``length_hint(entry)``;
* ``least_loaded`` — fallback when no length signal is wanted: pick the
  replica with the fewest active slots (ties by free slots);
* ``round_robin`` — strawman for the benchmarks.

Two affinities run *before* the balancer:

* **home affinity** — an entry that already lives on a replica (it was
  interrupted there and its KV pages are resident) is routed back home,
  so a scavenged entry resumes with ZERO re-prefill exactly as it would
  on a single paged engine.  When the home replica has no free slot the
  entry migrates to another replica (work stealing — correct, but the
  new replica must re-prefill); each migration is counted in
  ``steal_count``;
* **prefix affinity** — entries of one submit batch that share a prefill
  prefix (a GRPO group) are co-routed so the group's prefix-sharing
  machinery keeps its (G-1)/G prefill saving; cross-batch, a replica
  already holding a donor for the prefix attracts the entry.

Merging
-------
``step()`` steps every busy replica and concatenates the per-replica
event streams in replica order.  Each replica emits in ascending slot
order, so the merged order is deterministic and stable for as long as a
request stays resident — the EngineProtocol event-order contract holds
for the group verbatim.

With ``async_step=True`` the lockstep barrier is dropped: each busy
replica is dispatched on its own clock, and a replica whose decode step
is cheaper than the straggler's fits additional *micro-steps* into the
straggler's one-step window (bounded by ``ASYNC_MAX_MICROSTEPS``)
instead of idling behind it.  The event merger still emits replica-major
(replica order, execution order within a replica), so each uid's token
stream is untouched — one ``step()`` call may just carry more than one
event per uid.

Migration (zero re-prefill)
---------------------------
With ``migrate_kv=True`` the group moves an entry's *resident KV* across
replica pools instead of abandoning it: ``export_entry`` on the donor,
``import_pages`` + buffer copy on the destination (free in the
simulator), counted in ``migrated_pages``.  Work stealing then lands the
stolen entry with its pages already warm — the destination's submit path
resumes it with ZERO re-prefill — and falls back to the old
release-and-re-prefill behaviour only when the destination cannot accept
(dense layout, exhausted pool, strict-sync stale KV).

Drain-phase tail packing
------------------------
``drain_pack=True`` (or ``balancer="drain_pack"``) attacks the tail the
way RollPacker's tail-rank rebalancing does: when pending work no longer
fills the group (free slots survive the orchestrator's fill), in-flight
entries are consolidated onto the fewest replicas that hold them — via
the same migration path, so packed entries keep decoding mid-flight with
zero re-prefill — and the drained replicas go fully idle, dropping out of
``replica_busy`` / ``replica_bubble_ratio`` (released, in the Seer fleet
view).  Packed moves are counted in ``packed_entries``.

Accounting
----------
The group keeps per-replica busy integrals on *replica-local* clocks:

* ``replica_bubble_ratio`` — Eq. 4 evaluated per replica and summed:
  idle-slot time on replicas that are actually running, over their
  running time.  A fully idle replica contributes nothing (a drained
  instance can be released or reassigned — the Seer fleet view), so this
  isolates the waste load balancing can actually fix;
* ``replica_busy`` — time-weighted mean number of busy replicas;
* ``steal_count`` — cumulative home-affinity misses (migrations).

``cache_stats()`` aggregates these with the per-replica paged-KV
counters (``stale_kv_reuses`` et al summed across replicas), so the
orchestrator's existing ``record_cache`` plumbing surfaces them as
RolloutMetrics fields; ``replica_stats()`` keeps the per-replica detail.
"""
from __future__ import annotations

from typing import (Callable, Dict, List, Optional, Sequence, Tuple)

from repro.core.buffer import BufferEntry
from repro.core.engine_api import EngineProtocol, SlotTable, StepEvent

# -----------------------------------------------------------------------------
# balancer registry
# -----------------------------------------------------------------------------

# pick(group, entry, free) -> replica index; `free` is the remaining free
# slots per replica for THIS submit batch (the group decrements as it
# assigns, so balancers never see an already-full replica as available)
Balancer = Callable[["EngineGroup", BufferEntry, List[int]], int]

_BALANCERS: Dict[str, Callable[..., Balancer]] = {}


def register_balancer(name: str):
    def deco(factory):
        _BALANCERS[name] = factory
        return factory
    return deco


def make_balancer(name: str, **kwargs) -> Balancer:
    if name not in _BALANCERS:
        raise KeyError(f"unknown balancer {name!r}; "
                       f"registered: {available_balancers()}")
    return _BALANCERS[name](**kwargs)


def available_balancers() -> List[str]:
    return sorted(_BALANCERS)


@register_balancer("least_tokens")
def least_tokens_balancer() -> Balancer:
    """Length-aware default: least estimated outstanding decode tokens.
    Occupancy ties break on ``capacity - free``, which (unlike the live
    active counts) already reflects this batch's earlier assignments."""
    def pick(group: "EngineGroup", entry: BufferEntry,
             free: List[int]) -> int:
        return min((i for i in range(len(free)) if free[i] > 0),
                   key=lambda i: (group.load[i],
                                  group.replicas[i].capacity - free[i], i))
    return pick


@register_balancer("least_loaded")
def least_loaded_balancer() -> Balancer:
    """Length-blind fallback: fewest occupied slots.  Occupancy is
    ``capacity - free`` so in-batch assignments (only visible through
    the decremented ``free``) count — the replicas themselves are not
    submitted to until routing finishes."""
    def pick(group: "EngineGroup", entry: BufferEntry,
             free: List[int]) -> int:
        return min((i for i in range(len(free)) if free[i] > 0),
                   key=lambda i: (group.replicas[i].capacity - free[i], i))
    return pick


@register_balancer("round_robin")
def round_robin_balancer() -> Balancer:
    """Benchmark strawman: cycle replicas, skipping full ones."""
    state = {"next": 0}

    def pick(group: "EngineGroup", entry: BufferEntry,
             free: List[int]) -> int:
        n = len(free)
        for k in range(n):
            i = (state["next"] + k) % n
            if free[i] > 0:
                state["next"] = (i + 1) % n
                return i
        raise AssertionError("round_robin: no free replica")
    return pick


@register_balancer("drain_pack")
def drain_pack_balancer() -> Balancer:
    """Length-aware routing + drain-phase tail packing: routes exactly
    like ``least_tokens`` but flags the group to consolidate the in-
    flight tail onto the fewest replicas (via KV migration) once pending
    work no longer fills the group."""
    pick = least_tokens_balancer()
    pick.drain_pack = True
    return pick


# -----------------------------------------------------------------------------
# the group
# -----------------------------------------------------------------------------

# affinity records kept per slot of group capacity: uids that were
# scavenged and trained (never resubmitted) must not grow _home forever
HOME_RETENTION_FACTOR = 4

# async stepping: cap on decode micro-steps one replica may run inside a
# single group step.  The catch-up loop projects the next micro-step from
# the last observed dt; real-engine wall clocks jitter, so an explicit
# bound keeps one noisy estimate from turning into a runaway inner loop.
ASYNC_MAX_MICROSTEPS = 4


class EngineGroup:
    """N engine replicas behind the single-engine EngineProtocol surface.

    ``async_step`` drops the lockstep step barrier (micro-step catch-up
    on replica-local clocks), ``migrate_kv`` moves resident KV across
    replica pools so stolen entries resume with zero re-prefill, and
    ``drain_pack`` consolidates the in-flight tail onto the fewest
    replicas once pending work stops filling the group (implies
    ``migrate_kv``; also enabled by ``balancer="drain_pack"``).  All
    three default off, preserving PR-4 lockstep semantics exactly.
    """

    def __init__(self, replicas: Sequence[EngineProtocol],
                 balancer: "str | Balancer" = "least_tokens",
                 length_hint: Optional[Callable[[BufferEntry], float]] = None,
                 async_step: bool = False,
                 drain_pack: Optional[bool] = None,
                 migrate_kv: Optional[bool] = None):
        assert replicas, "EngineGroup needs at least one replica"
        self.replicas = list(replicas)
        self.capacity = sum(r.capacity for r in self.replicas)
        self.balancer = (make_balancer(balancer)
                         if isinstance(balancer, str) else balancer)
        self.length_hint = length_hint
        self.async_step = async_step
        if drain_pack is None:
            drain_pack = bool(getattr(self.balancer, "drain_pack", False))
        self.drain_pack = drain_pack
        # packing moves in-flight entries, which only makes sense with
        # their KV; stealing can opt in independently
        self.migrate_kv = drain_pack if migrate_kv is None else migrate_kv
        self.version = 0
        n = len(self.replicas)
        # group wall clock: replicas run concurrently, so each submit /
        # step / sync advances the group by the MAX of the per-replica
        # clock deltas it caused (monotone by construction).  Taking the
        # running max of raw replica clocks instead would freeze while a
        # drained fast replica holds the max and lump-attribute laggards'
        # busy time later — distorting every dt the orchestrator records.
        self._clock = max(r.clock for r in self.replicas)
        # routing state
        self._home: Dict[int, int] = {}        # uid -> replica index
        self._est: Dict[int, float] = {}       # uid -> est remaining tokens
        self._gen_total: Dict[int, int] = {}   # uid -> generated incl prefix
        self.load: List[float] = [0.0] * n     # sum of _est per replica
        self.steal_count = 0
        self.steal_migrations = 0              # steals that moved their KV
        self.packed_entries = 0                # drain-pack consolidations
        self._submitted_since_step = False     # drain detection (see step)
        self._ewma_len: Optional[float] = None  # observed completion length
        self._max_gen = max((getattr(r, "max_gen_len", 0)
                             for r in self.replicas), default=0) or 1024
        # per-replica busy integrals over replica-local stepped time
        self._busy_time = [0.0] * n            # sum busy_slots * dt
        self._cap_time = [0.0] * n             # sum capacity   * dt
        self._busy_replicas_time = 0.0         # sum busy_replica_count * dt
        self._stepped_time = 0.0               # sum group-step dt (max over r)

    # -- protocol: time & slot queries ------------------------------------

    @property
    def clock(self) -> float:
        """Modeled-concurrent group wall clock (see __init__)."""
        return self._clock

    def free_slots(self) -> int:
        return sum(r.free_slots() for r in self.replicas)

    def active_uids(self) -> List[int]:
        out: List[int] = []
        for r in self.replicas:
            out.extend(r.active_uids())
        return out

    @property
    def active_counts(self) -> List[int]:
        return [len(r.active_uids()) for r in self.replicas]

    @property
    def slots(self) -> SlotTable:
        """Read-only aggregate host-state snapshot: the replicas' SlotTable
        rows concatenated in replica order (mutations do not propagate)."""
        view = SlotTable(self.capacity)
        off = 0
        for r in self.replicas:
            t = r.slots
            for name in ("uid", "active", "next_token", "kv_len", "kv_start",
                         "gen_count", "gen_budget"):
                getattr(view, name)[off:off + t.capacity] = getattr(t, name)
            off += t.capacity
        return view

    # -- routing ----------------------------------------------------------

    def _hint(self, entry: BufferEntry) -> float:
        if self.length_hint is not None:
            return max(1.0, float(self.length_hint(entry)))
        expect = (self._ewma_len if self._ewma_len is not None
                  else 0.5 * self._max_gen)
        return max(1.0, expect - entry.gen_len)

    def _prefill_key(self, entry: BufferEntry) -> Tuple[int, ...]:
        seq = list(entry.prompt) + list(entry.generated)
        return tuple(seq[:-1])

    def _drop_donor_residency(self, replica: int, uid: int) -> None:
        """Abandoned resident state is dead weight on the donor replica —
        release it explicitly (paged pool pages, or the simulator's
        modeled residency) instead of letting it crowd the pool until LRU
        pressure reaches it."""
        r = self.replicas[replica]
        kv = getattr(r, "kv", None)
        if kv is not None:
            kv.release_seq(uid)
        drop = getattr(r, "drop_resident", None)
        if drop is not None:
            drop(uid)

    def _remember_home(self, uid: int, replica: int) -> None:
        """Record the uid's home (insertion order doubles as recency) and
        bound the map: consumed-without-resume uids would otherwise leak
        one record per scavenged trajectory for the engine's lifetime."""
        self._home.pop(uid, None)
        self._home[uid] = replica
        cap = HOME_RETENTION_FACTOR * self.capacity
        if len(self._home) <= cap:
            return
        live = set(self.active_uids())
        for u in list(self._home):
            if len(self._home) <= cap:
                break
            if u in live:
                continue
            # forgetting a home abandons any KV still resident there
            self._drop_donor_residency(self._home[u], u)
            del self._home[u]

    def _migrate(self, uid: int, src: int, dst: int) -> bool:
        """Move `uid` (in-flight slot or resident KV) from replica `src`
        to `dst` through the engines' optional migration capability.
        Export -> import -> discard: the donor copy survives until the
        importer has accepted, so False always means 'nothing changed'."""
        export = getattr(self.replicas[src], "export_entry", None)
        accept = getattr(self.replicas[dst], "import_entry", None)
        if export is None or accept is None:
            return False
        handle = export(uid)
        if handle is None or not accept(handle):
            return False
        self.replicas[src].discard_entry(uid)
        return True

    def _resident_replica(self, key: Tuple[int, ...]) -> Optional[int]:
        """Replica already holding a donor for this prefill prefix."""
        for i, r in enumerate(self.replicas):
            kv = getattr(r, "kv", None)
            if kv is not None and kv.find_donor(key) is not None:
                return i
        return None

    def _pick_fresh(self, entry: BufferEntry, free: List[int],
                    key_dest: Dict[Tuple[int, ...], int]) -> int:
        """Prefix co-routing, then the balancer (no home affinity)."""
        key = self._prefill_key(entry)
        if key:      # an empty prefix is never shared — don't co-route on it
            dest = key_dest.get(key)
            if dest is None:
                dest = self._resident_replica(key)
            if dest is not None and free[dest] > 0:
                return dest
        return self.balancer(self, entry, free)

    def _route(self, entry: BufferEntry, free: List[int],
               key_dest: Dict[Tuple[int, ...], int]) -> int:
        home = self._home.get(entry.uid)
        if home is None:
            return self._pick_fresh(entry, free, key_dest)
        if free[home] > 0:
            return home
        self.steal_count += 1              # migrate: home replica is full
        dest = self._pick_fresh(entry, free, key_dest)
        if self.migrate_kv and self._migrate(entry.uid, home, dest):
            # the entry lands on the thief with its KV resident: the
            # destination's submit path resumes it with zero re-prefill
            self.steal_migrations += 1
        else:
            # fallback: the thief re-prefills, so any KV left resident on
            # the old home is dead weight — drop it instead of letting it
            # crowd the pool until LRU pressure gets to it
            self._drop_donor_residency(home, entry.uid)
        return dest

    # -- protocol: submit / step / interrupt / sync -----------------------

    def submit(self, entries: Sequence[BufferEntry], version: int) -> None:
        if not entries:
            return
        free = [r.free_slots() for r in self.replicas]
        assert len(entries) <= sum(free), "not enough free slots"
        batches: List[List[BufferEntry]] = [[] for _ in self.replicas]
        key_dest: Dict[Tuple[int, ...], int] = {}
        # two passes: home-affine (previously-seen) entries claim their
        # home slots FIRST, so a fresh entry earlier in the caller's
        # order cannot take the last free slot of a resumable entry's
        # home replica and force an avoidable steal
        order = sorted(range(len(entries)),
                       key=lambda j: entries[j].uid not in self._home)
        for j in order:
            e = entries[j]
            i = self._route(e, free, key_dest)
            assert free[i] > 0, (i, free)
            free[i] -= 1
            key = self._prefill_key(e)
            if key:
                key_dest.setdefault(key, i)
            batches[i].append(e)
            # account the assignment NOW so the balancer sees in-batch
            # routing decisions, not just the pre-submit loads
            est = self._hint(e)
            self._remember_home(e.uid, i)
            self._est[e.uid] = est
            self._gen_total[e.uid] = e.gen_len
            self.load[i] += est
        dt_group = 0.0
        for i, batch in enumerate(batches):
            if batch:
                t0 = self.replicas[i].clock
                self.replicas[i].submit(batch, version)
                dt_group = max(dt_group, self.replicas[i].clock - t0)
        self._clock += dt_group        # per-replica prefills run concurrently
        self._submitted_since_step = True

    def _micro_step(self, i: int) -> Tuple[List[StepEvent], float]:
        """One decode step on replica `i` with full event accounting."""
        r = self.replicas[i]
        t0 = r.clock
        evs = r.step()
        dt = r.clock - t0
        self._busy_time[i] += len(evs) * dt
        self._cap_time[i] += r.capacity * dt
        for ev in evs:
            if self._est.get(ev.uid, 0.0) >= 1.0:
                self._est[ev.uid] -= 1.0
                self.load[i] -= 1.0
            self._gen_total[ev.uid] = self._gen_total.get(ev.uid, 0) + 1
            if ev.done:
                self._finish(ev.uid, i)
        return evs, dt

    def step(self) -> List[StepEvent]:
        # pack only when no work arrived since the previous step: the
        # orchestrator fills before every step, so a quiet interval with
        # free slots means pending is genuinely dry (drain), while a
        # policy that is still admitting (group-barrier gating, lookahead)
        # keeps the flag set and avoids pack/redistribute churn
        if self.drain_pack and not self._submitted_since_step:
            self._maybe_pack()
        self._submitted_since_step = False
        busy = [i for i, r in enumerate(self.replicas) if r.active_uids()]
        if not busy:
            return []
        streams: List[List[StepEvent]] = []
        spent: List[float] = []                 # per-replica in-call time
        last_dt: List[float] = []
        for i in busy:
            evs, dt = self._micro_step(i)
            streams.append(evs)
            spent.append(dt)
            last_dt.append(dt)
        if self.async_step:
            # no step barrier: while the straggler's single step runs, a
            # replica with a cheaper step fits extra micro-steps into the
            # same window (projected from its last observed dt)
            horizon = max(spent)
            for _ in range(ASYNC_MAX_MICROSTEPS - 1):
                progressed = False
                for k, i in enumerate(busy):
                    if not self.replicas[i].active_uids():
                        continue
                    if last_dt[k] <= 0 or spent[k] + last_dt[k] > horizon:
                        continue
                    evs, dt = self._micro_step(i)
                    streams[k].extend(evs)
                    spent[k] += dt
                    last_dt[k] = dt
                    progressed = True
                if not progressed:
                    break
        # replica-major merge: replica order, execution order within one
        events = [ev for stream in streams for ev in stream]
        dt_group = max(spent)           # replicas overlap in time
        self._busy_replicas_time += len(busy) * dt_group
        self._stepped_time += dt_group
        self._clock += dt_group
        return events

    def _maybe_pack(self) -> None:
        """Drain-phase tail packing: once pending work no longer fills the
        group (free slots survived the orchestrator's fill), consolidate
        the in-flight tail onto the fewest replicas that can hold it and
        let the drained replicas go idle (released from the busy set)."""
        active = [len(r.active_uids()) for r in self.replicas]
        total = sum(active)
        if total == 0 or total >= self.capacity:
            return                      # empty, or pending still fills us
        busy = [i for i, a in enumerate(active) if a > 0]
        # fewest replicas (most-loaded first: they move the least) that
        # can hold every in-flight entry
        order = sorted(busy, key=lambda i: (-active[i], i))
        keep: List[int] = []
        cap = 0
        for i in order:
            keep.append(i)
            cap += self.replicas[i].capacity
            if cap >= total:
                break
        if len(keep) >= len(busy):
            return                      # already as consolidated as it gets
        keep_set = set(keep)
        room = {i: self.replicas[i].capacity - active[i] for i in keep}
        donors = sorted((i for i in busy if i not in keep_set),
                        key=lambda i: (active[i], i))
        for d in donors:
            export = getattr(self.replicas[d], "export_entry", None)
            if export is None:
                return                  # backend cannot migrate — leave it
            for uid in list(self.replicas[d].active_uids()):
                handle = export(uid)
                if handle is None:
                    return              # backend cannot migrate — leave it
                # one export, every willing destination: a destination-
                # local failure (exhausted page pool) must not strand the
                # tail when another keep replica still has room
                dst = None
                for i in (i for i in keep if room[i] > 0):
                    accept = getattr(self.replicas[i], "import_entry", None)
                    if accept is not None and accept(handle):
                        dst = i
                        break
                if dst is None:
                    return              # nobody can take it now — retry on
                                        # a later step once pressure eases
                self.replicas[d].discard_entry(uid)
                room[dst] -= 1
                est = self._est.get(uid, 0.0)
                self.load[d] = max(0.0, self.load[d] - est)
                self.load[dst] += est
                self._remember_home(uid, dst)
                self.packed_entries += 1

    def _finish(self, uid: int, replica: int) -> None:
        total = self._gen_total.pop(uid, 0)
        self._ewma_len = (float(total) if self._ewma_len is None
                          else 0.9 * self._ewma_len + 0.1 * total)
        self.load[replica] -= self._est.pop(uid, 0.0)
        self.load[replica] = max(0.0, self.load[replica])
        self._home.pop(uid, None)

    def interrupt(self, uids: Optional[Sequence[int]] = None) -> List[int]:
        out: List[int] = []
        for i, r in enumerate(self.replicas):
            got = r.interrupt(uids)
            for uid in got:
                # keep _home: resident pages make this replica the uid's
                # zero-re-prefill resume target
                self.load[i] -= self._est.pop(uid, 0.0)
                self.load[i] = max(0.0, self.load[i])
                self._gen_total.pop(uid, None)
            out.extend(got)
        return out

    def sync_weights(self, version: int) -> None:
        """Version-stamped broadcast: every replica syncs (its paged KV
        stamps/invalidates per its retain_across_sync setting).  The
        broadcasts overlap, so the group pays the slowest replica's
        sync latency once."""
        dt_group = 0.0
        for r in self.replicas:
            t0 = r.clock
            r.sync_weights(version)
            dt_group = max(dt_group, r.clock - t0)
        self._clock += dt_group
        self.version = version

    # -- observability ----------------------------------------------------

    @property
    def replica_bubble_ratio(self) -> float:
        """Per-replica Eq. 4, summed over replicas on replica-local time:
        idle-slot time of *running* replicas over their running time.
        Fully idle replicas count as released, not as bubble."""
        cap = sum(self._cap_time)
        if cap <= 0:
            return 0.0
        return (cap - sum(self._busy_time)) / cap

    @property
    def replica_busy(self) -> float:
        """Time-weighted mean number of simultaneously busy replicas."""
        if self._stepped_time <= 0:
            return 0.0
        return self._busy_replicas_time / self._stepped_time

    def replica_stats(self) -> List[Dict[str, float]]:
        """Per-replica detail behind the aggregated ``cache_stats()``."""
        out = []
        for i, r in enumerate(self.replicas):
            cap = self._cap_time[i]
            rec = {
                "capacity": float(r.capacity),
                "active": float(len(r.active_uids())),
                "est_load": self.load[i],
                "busy_time": self._busy_time[i],
                "bubble_ratio": ((cap - self._busy_time[i]) / cap
                                 if cap > 0 else 0.0),
            }
            sub = getattr(r, "cache_stats", None)
            sub = sub() if sub is not None else None
            if sub:
                rec["stale_kv_reuses"] = sub.get("stale_kv_reuses", 0.0)
                rec["prefill_tokens_saved"] = sub.get(
                    "prefill_tokens_saved", 0.0)
            out.append(rec)
        return out

    def cache_stats(self) -> Dict[str, float]:
        """Group gauges + the replicas' paged-KV counters summed.

        Always non-None (even over SimEngine replicas), so the
        orchestrator's ``record_cache`` plumbing picks the group fields up
        for any replica type."""
        out: Dict[str, float] = {
            "num_replicas": float(len(self.replicas)),
            "steal_count": float(self.steal_count),
            "steal_migrations": float(self.steal_migrations),
            "packed_entries": float(self.packed_entries),
            "replica_busy": self.replica_busy,
            "replica_bubble_ratio": self.replica_bubble_ratio,
        }
        subs = []
        for r in self.replicas:
            fn = getattr(r, "cache_stats", None)
            sub = fn() if fn is not None else None
            if sub:
                subs.append(sub)
        if subs:
            for key in ("prefill_tokens_run", "prefill_tokens_saved",
                        "shared_prefills", "resumed_without_prefill",
                        "cow_copies", "evictions", "stale_kv_reuses",
                        "migrated_pages", "pages_in_use", "pages_total",
                        "resident_seqs"):
                out[key] = float(sum(s.get(key, 0) for s in subs))
            # saturation gauge: the WORST per-replica occupancy.  Pooling
            # (sum in_use / sum total) would read ~0.4 while one skewed
            # replica sits at 1.0 evicting resident KV.
            out["page_occupancy"] = max(
                float(s.get("page_occupancy", 0.0)) for s in subs)
        return out
