"""Data-parallel rollout: an EngineProtocol facade over N engine replicas.

The paper's headline bubble-ratio win gets interesting once rollout is
sharded across multiple engine instances: the long tail of ONE replica
stalls the whole group barrier (Seer's "global load balancing" problem,
RollPacker's tail-rank rebalancing).  :class:`EngineGroup` makes a set of
replicas — SimEngine, SlotEngine, or any other
:class:`~repro.core.engine_api.EngineProtocol` backend, each with its own
KV memory — look like ONE engine, so :class:`RolloutOrchestrator`, every
registered :class:`SchedulerPolicy`, and both conformance suites run
against it unchanged.

Routing
-------
``submit`` routes each entry through a pluggable **balancer** (string
registry, mirroring the policy registry):

* ``least_tokens`` (default) — length-aware: pick the replica with the
  least *estimated outstanding decode tokens*.  The estimate uses the
  same signal the scheduling policies' length keys use — tokens already
  generated (``entry.gen_len``) against an EWMA of observed completion
  lengths — or a caller-supplied ``length_hint(entry)``;
* ``least_loaded`` — fallback when no length signal is wanted: pick the
  replica with the fewest active slots (ties by free slots);
* ``round_robin`` — strawman for the benchmarks.

Two affinities run *before* the balancer:

* **home affinity** — an entry that already lives on a replica (it was
  interrupted there and its KV pages are resident) is routed back home,
  so a scavenged entry resumes with ZERO re-prefill exactly as it would
  on a single paged engine.  When the home replica has no free slot the
  entry migrates to another replica (work stealing — correct, but the
  new replica must re-prefill); each migration is counted in
  ``steal_count``;
* **prefix affinity** — entries of one submit batch that share a prefill
  prefix (a GRPO group) are co-routed so the group's prefix-sharing
  machinery keeps its (G-1)/G prefill saving; cross-batch, a replica
  already holding a donor for the prefix attracts the entry.

Merging
-------
``step()`` steps every busy replica and concatenates the per-replica
event streams in replica order.  Each replica emits in ascending slot
order, so the merged order is deterministic and stable for as long as a
request stays resident — the EngineProtocol event-order contract holds
for the group verbatim.

With ``async_step=True`` the lockstep barrier is dropped: each busy
replica is dispatched on its own clock, and a replica whose decode step
is cheaper than the straggler's fits additional *micro-steps* into the
straggler's one-step window (bounded by ``ASYNC_MAX_MICROSTEPS``)
instead of idling behind it.  The event merger still emits replica-major
(replica order, execution order within a replica), so each uid's token
stream is untouched — one ``step()`` call may just carry more than one
event per uid.

Migration (zero re-prefill)
---------------------------
With ``migrate_kv=True`` the group moves an entry's *resident KV* across
replica pools instead of abandoning it: ``export_entry`` on the donor,
``import_pages`` + buffer copy on the destination (free in the
simulator), counted in ``migrated_pages``.  Work stealing then lands the
stolen entry with its pages already warm — the destination's submit path
resumes it with ZERO re-prefill — and falls back to the old
release-and-re-prefill behaviour only when the destination cannot accept
(dense layout, exhausted pool, strict-sync stale KV).

Drain-phase tail packing
------------------------
``drain_pack=True`` (or ``balancer="drain_pack"``) attacks the tail the
way RollPacker's tail-rank rebalancing does: when pending work no longer
fills the group (free slots survive the orchestrator's fill), in-flight
entries are consolidated onto the fewest replicas that hold them — via
the same migration path, so packed entries keep decoding mid-flight with
zero re-prefill — and the drained replicas go fully idle, dropping out of
``replica_busy`` / ``replica_bubble_ratio`` (released, in the Seer fleet
view).  Packed moves are counted in ``packed_entries``.

Accounting
----------
The group keeps per-replica busy integrals on *replica-local* clocks:

* ``replica_bubble_ratio`` — Eq. 4 evaluated per replica and summed:
  idle-slot time on replicas that are actually running, over their
  running time.  A fully idle replica contributes nothing (a drained
  instance can be released or reassigned — the Seer fleet view), so this
  isolates the waste load balancing can actually fix;
* ``replica_busy`` — time-weighted mean number of busy replicas;
* ``steal_count`` — cumulative home-affinity misses (migrations).

``cache_stats()`` aggregates these with the per-replica paged-KV
counters (``stale_kv_reuses`` et al summed across replicas), so the
orchestrator's existing ``record_cache`` plumbing surfaces them as
RolloutMetrics fields; ``replica_stats()`` keeps the per-replica detail.

Failure tolerance & elasticity
------------------------------
The fleet is no longer immortal.  A :class:`FaultInjector` plan makes a
replica die, stall, or slow at a chosen group step (deterministic under
a seed); faults are applied at the START of ``step()``, before any
replica dispatches.  On replica death the group re-homes the dead
replica's in-flight uids: with ``migrate_kv=True`` the same
export/import path work stealing uses transplants each entry — KV and
all — onto a survivor with a free slot, so it keeps decoding with ZERO
re-prefill (counted in ``rehomed_entries``); entries no survivor can
take are released for a re-roll under the *current* policy version
(``rerolled_entries``, drained by the orchestrator through
``take_failed_uids()`` and scavenged back to PENDING — the buffer's
mode decides what survives, so GRPO group barriers stay intact).  A
dead replica is fenced (slots freed, resident KV dropped) and leaves
``replica_busy`` / ``replica_bubble_ratio`` accounting: it accrues no
further busy or capacity time, exactly like a drained instance in the
Seer fleet view.

``scale_down(r)`` / ``scale_up(engine)`` make the fleet elastic
(``elastic=True``): scaling down is a *graceful* kill — drain-pack the
replica's tail onto survivors via KV migration regardless of
``migrate_kv`` (the move is voluntary, the state is healthy), re-roll
the rest, fence — and scaling up appends a replica that joins at the
group's current weight version and attracts work on the next submit.
The ``weighted_tokens`` balancer routes heterogeneous fleets by
estimated *drain time* (outstanding tokens x observed per-step cost /
slot count), so a replica that steps twice as fast takes
proportionally more work instead of the uniform share ``least_tokens``
would give it.
"""
from __future__ import annotations

from typing import (Callable, Dict, List, Optional, Sequence, Tuple)

from repro.core.buffer import BufferEntry
from repro.core.engine_api import (EngineProtocol, FaultEvent, FaultInjector,
                                   SlotTable, StepEvent)
from repro.core.metrics import MetricsSnapshot

def tenant_of(entry: BufferEntry) -> Optional[str]:
    """The serving tier tags entries with a tenant through their meta
    (``ServeMeta.tenant`` or a plain ``{"tenant": ...}`` dict); entries
    outside the serving tier have none."""
    meta = entry.meta
    t = getattr(meta, "tenant", None)
    if t is None and isinstance(meta, dict):
        t = meta.get("tenant")
    return t


# -----------------------------------------------------------------------------
# balancer registry
# -----------------------------------------------------------------------------

# pick(group, entry, free) -> replica index; `free` is the remaining free
# slots per replica for THIS submit batch (the group decrements as it
# assigns, so balancers never see an already-full replica as available)
Balancer = Callable[["EngineGroup", BufferEntry, List[int]], int]

_BALANCERS: Dict[str, Callable[..., Balancer]] = {}


def register_balancer(name: str):
    def deco(factory):
        _BALANCERS[name] = factory
        return factory
    return deco


def make_balancer(name: str, **kwargs) -> Balancer:
    if name not in _BALANCERS:
        raise KeyError(f"unknown balancer {name!r}; "
                       f"registered: {available_balancers()}")
    return _BALANCERS[name](**kwargs)


def available_balancers() -> List[str]:
    return sorted(_BALANCERS)


@register_balancer("least_tokens")
def least_tokens_balancer() -> Balancer:
    """Length-aware default: least estimated outstanding decode tokens.
    Occupancy ties break on ``capacity - free``, which (unlike the live
    active counts) already reflects this batch's earlier assignments."""
    def pick(group: "EngineGroup", entry: BufferEntry,
             free: List[int]) -> int:
        return min((i for i in range(len(free)) if free[i] > 0),
                   key=lambda i: (group.load[i],
                                  group.replicas[i].capacity - free[i], i))
    return pick


@register_balancer("least_loaded")
def least_loaded_balancer() -> Balancer:
    """Length-blind fallback: fewest occupied slots.  Occupancy is
    ``capacity - free`` so in-batch assignments (only visible through
    the decremented ``free``) count — the replicas themselves are not
    submitted to until routing finishes."""
    def pick(group: "EngineGroup", entry: BufferEntry,
             free: List[int]) -> int:
        return min((i for i in range(len(free)) if free[i] > 0),
                   key=lambda i: (group.replicas[i].capacity - free[i], i))
    return pick


@register_balancer("round_robin")
def round_robin_balancer() -> Balancer:
    """Benchmark strawman: cycle replicas, skipping full ones."""
    state = {"next": 0}

    def pick(group: "EngineGroup", entry: BufferEntry,
             free: List[int]) -> int:
        n = len(free)
        for k in range(n):
            i = (state["next"] + k) % n
            if free[i] > 0:
                state["next"] = (i + 1) % n
                return i
        raise AssertionError("round_robin: no free replica")
    return pick


@register_balancer("weighted_tokens")
def weighted_tokens_balancer() -> Balancer:
    """Throughput-weighted routing for heterogeneous fleets: least
    estimated *drain time* — outstanding tokens times the replica's
    observed per-step cost, normalised by slot count — so a replica
    that steps twice as fast (or is twice as wide) attracts
    proportionally more work.  Until a replica's step cost has been
    observed it assumes the fleet mean, which makes the cold-start
    routing identical to ``least_tokens``."""
    def pick(group: "EngineGroup", entry: BufferEntry,
             free: List[int]) -> int:
        def drain(i: int):
            cap = max(1, group.replicas[i].capacity)
            return ((group.load[i] + 1.0) * group.replica_step_cost(i) / cap,
                    group.replicas[i].capacity - free[i], i)
        return min((i for i in range(len(free)) if free[i] > 0), key=drain)
    return pick


@register_balancer("drain_pack")
def drain_pack_balancer() -> Balancer:
    """Length-aware routing + drain-phase tail packing: routes exactly
    like ``least_tokens`` but flags the group to consolidate the in-
    flight tail onto the fewest replicas (via KV migration) once pending
    work no longer fills the group."""
    pick = least_tokens_balancer()
    pick.drain_pack = True
    return pick


# -----------------------------------------------------------------------------
# the group
# -----------------------------------------------------------------------------

# affinity records kept per slot of group capacity: uids that were
# scavenged and trained (never resubmitted) must not grow _home forever
HOME_RETENTION_FACTOR = 4

# async stepping: cap on decode micro-steps one replica may run inside a
# single group step.  The catch-up loop projects the next micro-step from
# the last observed dt; real-engine wall clocks jitter, so an explicit
# bound keeps one noisy estimate from turning into a runaway inner loop.
ASYNC_MAX_MICROSTEPS = 4


class EngineGroup:
    """N engine replicas behind the single-engine EngineProtocol surface.

    ``async_step`` drops the lockstep step barrier (micro-step catch-up
    on replica-local clocks), ``migrate_kv`` moves resident KV across
    replica pools so stolen entries resume with zero re-prefill, and
    ``drain_pack`` consolidates the in-flight tail onto the fewest
    replicas once pending work stops filling the group (implies
    ``migrate_kv``; also enabled by ``balancer="drain_pack"``).  All
    three default off, preserving PR-4 lockstep semantics exactly.

    ``fault_injector`` attaches a deterministic chaos plan (kill /
    stall / slow per replica, see the module docstring) and
    ``elastic=True`` enables :meth:`scale_down` / :meth:`scale_up`;
    both default off — a plain group is the PR-4 immortal fixed fleet.
    """

    def __init__(self, replicas: Sequence[EngineProtocol],
                 balancer: "str | Balancer" = "least_tokens",
                 length_hint: Optional[Callable[[BufferEntry], float]] = None,
                 async_step: bool = False,
                 drain_pack: Optional[bool] = None,
                 migrate_kv: Optional[bool] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 elastic: bool = False,
                 spread_tenants: bool = False):
        assert replicas, "EngineGroup needs at least one replica"
        self.replicas = list(replicas)
        self.balancer = (make_balancer(balancer)
                         if isinstance(balancer, str) else balancer)
        self.length_hint = length_hint
        self.async_step = async_step
        if drain_pack is None:
            drain_pack = bool(getattr(self.balancer, "drain_pack", False))
        self.drain_pack = drain_pack
        # packing moves in-flight entries, which only makes sense with
        # their KV; stealing can opt in independently
        self.migrate_kv = drain_pack if migrate_kv is None else migrate_kv
        self.version = 0
        n = len(self.replicas)
        # group wall clock: replicas run concurrently, so each submit /
        # step / sync advances the group by the MAX of the per-replica
        # clock deltas it caused (monotone by construction).  Taking the
        # running max of raw replica clocks instead would freeze while a
        # drained fast replica holds the max and lump-attribute laggards'
        # busy time later — distorting every dt the orchestrator records.
        self._clock = max(r.clock for r in self.replicas)
        # routing state
        # tenant-tagged routing (serving tier): when on, fresh entries of
        # one tenant are spread across replicas so a bursty tenant cannot
        # monopolise a single replica's slots (fate sharing / noisy
        # neighbour isolation).  Off by default — RL workloads have no
        # tenants and the extra key would be pure overhead.
        self.spread_tenants = spread_tenants
        self._tenant_by_uid: Dict[int, str] = {}
        self._home: Dict[int, int] = {}        # uid -> replica index
        self._est: Dict[int, float] = {}       # uid -> est remaining tokens
        self._gen_total: Dict[int, int] = {}   # uid -> generated incl prefix
        self.load: List[float] = [0.0] * n     # sum of _est per replica
        self.steal_count = 0
        self.steal_migrations = 0              # steals that moved their KV
        self.packed_entries = 0                # drain-pack consolidations
        self._submitted_since_step = False     # drain detection (see step)
        self._ewma_len: Optional[float] = None  # observed completion length
        self._max_gen = max((getattr(r, "max_gen_len", 0)
                             for r in self.replicas), default=0) or 1024
        # per-replica busy integrals over replica-local stepped time
        self._busy_time = [0.0] * n            # sum busy_slots * dt
        self._cap_time = [0.0] * n             # sum capacity   * dt
        self._busy_replicas_time = 0.0         # sum busy_replica_count * dt
        self._stepped_time = 0.0               # sum group-step dt (max over r)
        # fault tolerance / elasticity
        self.fault_injector = fault_injector
        self.elastic = elastic
        self.alive: List[bool] = [True] * n
        self._step_index = 0                   # 1-based after first step()
        self._stall_until = [0] * n            # last stalled step, inclusive
        self._slow_until = [0] * n             # last throttled step, inclusive
        self._dt_ewma: List[Optional[float]] = [None] * n  # per-step cost
        self._failed_uids: List[int] = []      # await re-roll by the caller
        self.replica_deaths = 0
        self.rehomed_entries = 0               # migrated off a dying replica
        self.rerolled_entries = 0              # released: no survivor took it
        self.scale_events = 0                  # scale_down + scale_up calls
        self.residency_dropped = 0             # resident KV released unread

    # -- protocol: time & slot queries ------------------------------------

    @property
    def capacity(self) -> int:
        """Q of the *live* fleet: dead / scaled-down replicas stop
        counting, so the orchestrator's fill and the policies' capacity-
        relative thresholds track what can actually decode."""
        return sum(r.capacity
                   for i, r in enumerate(self.replicas) if self.alive[i])

    @property
    def clock(self) -> float:
        """Modeled-concurrent group wall clock (see __init__)."""
        return self._clock

    def _alive_indices(self) -> List[int]:
        return [i for i, a in enumerate(self.alive) if a]

    def free_slots(self) -> int:
        return sum(self.replicas[i].free_slots()
                   for i in self._alive_indices())

    def active_uids(self) -> List[int]:
        out: List[int] = []
        for i in self._alive_indices():
            out.extend(self.replicas[i].active_uids())
        return out

    @property
    def active_counts(self) -> List[int]:
        # one entry per replica, dead included (fenced replicas read 0)
        return [len(r.active_uids()) for r in self.replicas]

    @property
    def slots(self) -> SlotTable:
        """Read-only aggregate host-state snapshot: the live replicas'
        SlotTable rows concatenated in replica order (mutations do not
        propagate)."""
        view = SlotTable(self.capacity)
        off = 0
        for i in self._alive_indices():
            t = self.replicas[i].slots
            for name in ("uid", "active", "next_token", "kv_len", "kv_start",
                         "gen_count", "gen_budget"):
                getattr(view, name)[off:off + t.capacity] = getattr(t, name)
            off += t.capacity
        return view

    # -- routing ----------------------------------------------------------

    def _hint(self, entry: BufferEntry) -> float:
        if self.length_hint is not None:
            return max(1.0, float(self.length_hint(entry)))
        expect = (self._ewma_len if self._ewma_len is not None
                  else 0.5 * self._max_gen)
        return max(1.0, expect - entry.gen_len)

    def _prefill_key(self, entry: BufferEntry) -> Tuple[int, ...]:
        seq = list(entry.prompt) + list(entry.generated)
        return tuple(seq[:-1])

    def _drop_donor_residency(self, replica: int, uid: int) -> bool:
        """Abandoned resident state is dead weight on the donor replica —
        release it explicitly (paged pool pages, or the simulator's
        modeled residency) instead of letting it crowd the pool until LRU
        pressure reaches it.  Returns True (and counts it in the
        ``residency_dropped`` gauge) when something was actually held:
        losing resident KV means the uid re-prefills from scratch on its
        next run, a cost the fleet operator should be able to see."""
        if not self.alive[replica]:
            return False                # fenced: nothing resident to drop
        r = self.replicas[replica]
        dropped = False
        kv = getattr(r, "kv", None)
        if kv is not None:
            if uid in kv.tables:
                dropped = True
            kv.release_seq(uid)
        drop = getattr(r, "drop_resident", None)
        if drop is not None and drop(uid):
            dropped = True
        if dropped:
            self.residency_dropped += 1
        return dropped

    def _remember_home(self, uid: int, replica: int) -> None:
        """Record the uid's home (insertion order doubles as recency) and
        bound the map: consumed-without-resume uids would otherwise leak
        one record per scavenged trajectory for the engine's lifetime."""
        self._home.pop(uid, None)
        self._home[uid] = replica
        cap = HOME_RETENTION_FACTOR * self.capacity
        if len(self._home) <= cap:
            return
        live = set(self.active_uids())
        for u in list(self._home):
            if len(self._home) <= cap:
                break
            if u in live:
                continue
            # forgetting a home abandons any KV still resident there
            self._drop_donor_residency(self._home[u], u)
            del self._home[u]

    def _migrate(self, uid: int, src: int, dst: int) -> bool:
        """Move `uid` (in-flight slot or resident KV) from replica `src`
        to `dst` through the engines' optional migration capability.
        Export -> import -> discard: the donor copy survives until the
        importer has accepted, so False always means 'nothing changed'."""
        export = getattr(self.replicas[src], "export_entry", None)
        accept = getattr(self.replicas[dst], "import_entry", None)
        if export is None or accept is None:
            return False
        handle = export(uid)
        if handle is None or not accept(handle):
            return False
        self.replicas[src].discard_entry(uid)
        return True

    def _resident_replica(self, key: Tuple[int, ...]) -> Optional[int]:
        """Replica already holding a donor for this prefill prefix."""
        for i in self._alive_indices():
            kv = getattr(self.replicas[i], "kv", None)
            if kv is not None and kv.find_donor(key) is not None:
                return i
        return None

    def _pick_fresh(self, entry: BufferEntry, free: List[int],
                    key_dest: Dict[Tuple[int, ...], int],
                    tenant_scratch: Optional[Dict] = None) -> int:
        """Prefix co-routing, then tenant spreading (when enabled), then
        the balancer (no home affinity)."""
        key = self._prefill_key(entry)
        if key:      # an empty prefix is never shared — don't co-route on it
            dest = key_dest.get(key)
            if dest is None:
                dest = self._resident_replica(key)
            if dest is not None and free[dest] > 0:
                return dest
        if self.spread_tenants:
            t = tenant_of(entry)
            if t is not None:
                scratch = tenant_scratch or {}

                def same(i: int) -> int:
                    live = sum(1 for u in self.replicas[i].active_uids()
                               if self._tenant_by_uid.get(u) == t)
                    return live + scratch.get((i, t), 0)
                # fewest same-tenant entries wins; the balancer's choice
                # breaks ties, so within a tenant routing stays length- /
                # load-aware
                best = self.balancer(self, entry, free)
                return min((i for i in range(len(free)) if free[i] > 0),
                           key=lambda i: (same(i), i != best, i))
        return self.balancer(self, entry, free)

    def _route(self, entry: BufferEntry, free: List[int],
               key_dest: Dict[Tuple[int, ...], int],
               tenant_scratch: Optional[Dict] = None) -> int:
        home = self._home.get(entry.uid)
        if home is not None and not self.alive[home]:
            # the home died after this record was written (kill/scale
            # cleanup removes records eagerly, but a record can reappear
            # stale through caller-held handles): nothing is resident
            # there any more — treat as fresh
            self._home.pop(entry.uid, None)
            home = None
        if home is None:
            return self._pick_fresh(entry, free, key_dest, tenant_scratch)
        if free[home] > 0:
            return home
        self.steal_count += 1              # migrate: home replica is full
        dest = self._pick_fresh(entry, free, key_dest, tenant_scratch)
        if self.migrate_kv and self._migrate(entry.uid, home, dest):
            # the entry lands on the thief with its KV resident: the
            # destination's submit path resumes it with zero re-prefill
            self.steal_migrations += 1
        else:
            # fallback: the thief re-prefills, so any KV left resident on
            # the old home is dead weight — drop it instead of letting it
            # crowd the pool until LRU pressure gets to it
            self._drop_donor_residency(home, entry.uid)
        return dest

    # -- protocol: submit / step / interrupt / sync -----------------------

    def submit(self, entries: Sequence[BufferEntry], version: int) -> None:
        if not entries:
            return
        # dead / scaled-down replicas advertise zero free slots, so no
        # balancer (round_robin and least_loaded included) can route a
        # late-arriving submit onto a fenced replica; drained-but-ALIVE
        # replicas keep their free slots and rejoin on new work
        free = [r.free_slots() if self.alive[i] else 0
                for i, r in enumerate(self.replicas)]
        assert len(entries) <= sum(free), "not enough free slots"
        batches: List[List[BufferEntry]] = [[] for _ in self.replicas]
        key_dest: Dict[Tuple[int, ...], int] = {}
        tenant_scratch: Dict = {}   # (replica, tenant) -> in-batch count
        # two passes: home-affine (previously-seen) entries claim their
        # home slots FIRST, so a fresh entry earlier in the caller's
        # order cannot take the last free slot of a resumable entry's
        # home replica and force an avoidable steal
        order = sorted(range(len(entries)),
                       key=lambda j: entries[j].uid not in self._home)
        for j in order:
            e = entries[j]
            i = self._route(e, free, key_dest, tenant_scratch)
            assert free[i] > 0, (i, free)
            free[i] -= 1
            key = self._prefill_key(e)
            if key:
                key_dest.setdefault(key, i)
            t = tenant_of(e)
            if t is not None:
                self._tenant_by_uid[e.uid] = t
                tenant_scratch[(i, t)] = tenant_scratch.get((i, t), 0) + 1
            batches[i].append(e)
            # account the assignment NOW so the balancer sees in-batch
            # routing decisions, not just the pre-submit loads
            est = self._hint(e)
            self._remember_home(e.uid, i)
            self._est[e.uid] = est
            self._gen_total[e.uid] = e.gen_len
            self.load[i] += est
        cap = HOME_RETENTION_FACTOR * max(1, self.capacity)
        if len(self._tenant_by_uid) > cap:
            # bound the tag map (mirrors _remember_home): tags of consumed
            # uids must not leak one record per request forever
            live = set(self.active_uids()) | set(self._home)
            live.update(e.uid for e in entries)
            self._tenant_by_uid = {u: t for u, t in self._tenant_by_uid.items()
                                   if u in live}
        dt_group = 0.0
        for i, batch in enumerate(batches):
            if batch:
                t0 = self.replicas[i].clock
                self.replicas[i].submit(batch, version)
                dt_group = max(dt_group, self.replicas[i].clock - t0)
        self._clock += dt_group        # per-replica prefills run concurrently
        self._submitted_since_step = True

    def _micro_step(self, i: int) -> Tuple[List[StepEvent], float]:
        """One decode step on replica `i` with full event accounting."""
        r = self.replicas[i]
        t0 = r.clock
        evs = r.step()
        dt = r.clock - t0
        self._busy_time[i] += len(evs) * dt
        self._cap_time[i] += r.capacity * dt
        if dt > 0:
            # observed per-step cost, fed to the weighted_tokens balancer
            d = self._dt_ewma[i]
            self._dt_ewma[i] = dt if d is None else 0.8 * d + 0.2 * dt
        for ev in evs:
            if self._est.get(ev.uid, 0.0) >= 1.0:
                self._est[ev.uid] -= 1.0
                self.load[i] -= 1.0
            self._gen_total[ev.uid] = self._gen_total.get(ev.uid, 0) + 1
            if ev.done:
                self._finish(ev.uid, i)
        return evs, dt

    def step(self) -> List[StepEvent]:
        # faults fire at the step boundary, before any replica dispatches
        self._step_index += 1
        if self.fault_injector is not None:
            for f in self.fault_injector.due(self._step_index):
                self._apply_fault(f)
        for i in self._alive_indices():
            if self._slow_until[i] and self._step_index > self._slow_until[i]:
                self.replicas[i].throttle(1.0)   # degradation window over
                self._slow_until[i] = 0
        # pack only when no work arrived since the previous step: the
        # orchestrator fills before every step, so a quiet interval with
        # free slots means pending is genuinely dry (drain), while a
        # policy that is still admitting (group-barrier gating, lookahead)
        # keeps the flag set and avoids pack/redistribute churn
        if self.drain_pack and not self._submitted_since_step:
            self._maybe_pack()
        self._submitted_since_step = False
        # a stalled replica holds its entries but makes no progress this
        # step (and accrues no busy/capacity time: it is wedged, not
        # bubbling); a dead one is out of the fleet entirely
        busy = [i for i, r in enumerate(self.replicas)
                if self.alive[i] and self._step_index > self._stall_until[i]
                and r.active_uids()]
        if not busy:
            return []
        streams: List[List[StepEvent]] = []
        spent: List[float] = []                 # per-replica in-call time
        last_dt: List[float] = []
        for i in busy:
            evs, dt = self._micro_step(i)
            streams.append(evs)
            spent.append(dt)
            last_dt.append(dt)
        if self.async_step:
            # no step barrier: while the straggler's single step runs, a
            # replica with a cheaper step fits extra micro-steps into the
            # same window (projected from its last observed dt)
            horizon = max(spent)
            for _ in range(ASYNC_MAX_MICROSTEPS - 1):
                progressed = False
                for k, i in enumerate(busy):
                    if not self.replicas[i].active_uids():
                        continue
                    if last_dt[k] <= 0 or spent[k] + last_dt[k] > horizon:
                        continue
                    evs, dt = self._micro_step(i)
                    streams[k].extend(evs)
                    spent[k] += dt
                    last_dt[k] = dt
                    progressed = True
                if not progressed:
                    break
        # replica-major merge: replica order, execution order within one
        events = [ev for stream in streams for ev in stream]
        dt_group = max(spent)           # replicas overlap in time
        self._busy_replicas_time += len(busy) * dt_group
        self._stepped_time += dt_group
        self._clock += dt_group
        return events

    def _maybe_pack(self) -> None:
        """Drain-phase tail packing: once pending work no longer fills the
        group (free slots survived the orchestrator's fill), consolidate
        the in-flight tail onto the fewest replicas that can hold it and
        let the drained replicas go idle (released from the busy set)."""
        active = [len(r.active_uids()) if self.alive[i] else 0
                  for i, r in enumerate(self.replicas)]
        total = sum(active)
        if total == 0 or total >= self.capacity:
            return                      # empty, or pending still fills us
        busy = [i for i, a in enumerate(active) if a > 0]
        # fewest replicas (most-loaded first: they move the least) that
        # can hold every in-flight entry
        order = sorted(busy, key=lambda i: (-active[i], i))
        keep: List[int] = []
        cap = 0
        for i in order:
            keep.append(i)
            cap += self.replicas[i].capacity
            if cap >= total:
                break
        if len(keep) >= len(busy):
            return                      # already as consolidated as it gets
        keep_set = set(keep)
        room = {i: self.replicas[i].capacity - active[i] for i in keep}
        donors = sorted((i for i in busy if i not in keep_set),
                        key=lambda i: (active[i], i))
        for d in donors:
            export = getattr(self.replicas[d], "export_entry", None)
            if export is None:
                return                  # backend cannot migrate — leave it
            for uid in list(self.replicas[d].active_uids()):
                handle = export(uid)
                if handle is None:
                    return              # backend cannot migrate — leave it
                # one export, every willing destination: a destination-
                # local failure (exhausted page pool) must not strand the
                # tail when another keep replica still has room
                dst = None
                for i in (i for i in keep if room[i] > 0):
                    accept = getattr(self.replicas[i], "import_entry", None)
                    if accept is not None and accept(handle):
                        dst = i
                        break
                if dst is None:
                    return              # nobody can take it now — retry on
                                        # a later step once pressure eases
                self.replicas[d].discard_entry(uid)
                room[dst] -= 1
                est = self._est.get(uid, 0.0)
                self.load[d] = max(0.0, self.load[d] - est)
                self.load[dst] += est
                self._remember_home(uid, dst)
                self.packed_entries += 1

    # -- fault handling & elasticity --------------------------------------

    def _apply_fault(self, f: FaultEvent) -> None:
        i = f.replica
        if i >= len(self.replicas) or not self.alive[i]:
            return                      # already dead, or never existed
        if f.kind == "kill":
            self._kill_replica(i)
        elif f.kind == "stall":
            self._stall_until[i] = max(self._stall_until[i],
                                       self._step_index + f.duration - 1)
        elif f.kind == "slow":
            throttle = getattr(self.replicas[i], "throttle", None)
            if throttle is not None:    # wall-clock engines can't be modeled
                throttle(f.factor)
                self._slow_until[i] = max(self._slow_until[i],
                                          self._step_index + f.duration - 1)

    def _kill_replica(self, i: int) -> None:
        """Fail-stop replica death, detected at the step boundary.  Every
        in-flight uid is re-homed onto a survivor (KV transplanted, zero
        re-prefill) when ``migrate_kv`` and a survivor has room;
        otherwise it is released for a re-roll under the current policy
        version (its tokens so far were already reported through
        ``step()``, so the buffer's mode decides what survives)."""
        r = self.replicas[i]
        self.alive[i] = False
        self.replica_deaths += 1
        for uid in list(r.active_uids()):
            if self.migrate_kv and self._rehome(uid, i) is not None:
                # a survivor had a free slot: the entry keeps decoding
                # there, no rescheduling needed
                self.rehomed_entries += 1
            elif self.migrate_kv and self._rehome_resident(uid, i):
                # the fleet runs full (no survivor slot free), but the KV
                # fits a survivor's pool as RESIDENT state: hand the uid
                # back for rescheduling — it routes home to the new
                # replica and resumes with zero re-prefill
                self.rehomed_entries += 1
                self._reschedule(uid)
            else:
                self._release_for_reroll(uid)
        self._fence(i)

    def _rehome_resident(self, uid: int, src: int) -> bool:
        """Migrate `uid`'s KV to a survivor as resident (non-active)
        state: interrupt it on the dying replica (slot -> residency),
        then export/import the resident handle.  Needs pool room on the
        destination, not a free slot."""
        self.replicas[src].interrupt([uid])
        for dst in self._alive_indices():
            if dst != src and self._migrate(uid, src, dst):
                self._remember_home(uid, dst)
                return True
        return False

    def _reschedule(self, uid: int) -> None:
        """Hand a re-homed-as-resident uid back to the caller for a
        resubmit (``take_failed_uids``).  Unlike a re-roll its home and
        KV survive, so the resume is free."""
        self._failed_uids.append(uid)
        self._est.pop(uid, None)
        self._gen_total.pop(uid, None)

    def _rehome(self, uid: int, src: int) -> Optional[int]:
        """Transplant `uid` from replica `src` onto the emptiest survivor
        that accepts it (export -> import -> discard, the work-stealing
        path); returns the destination, or None when nobody can take it
        now.  Load and home-affinity records follow the entry."""
        order = sorted(self._alive_indices(),
                       key=lambda j: (len(self.replicas[j].active_uids()), j))
        for dst in order:
            if dst == src or self.replicas[dst].free_slots() <= 0:
                continue
            if self._migrate(uid, src, dst):
                est = self._est.get(uid, 0.0)
                self.load[src] = max(0.0, self.load[src] - est)
                self.load[dst] += est
                self._remember_home(uid, dst)
                return dst
        return None

    def _release_for_reroll(self, uid: int) -> None:
        """No survivor could take the uid: surrender it to the caller
        (``take_failed_uids``) for a re-roll under the current policy
        version, and forget every routing record (its engine-side state
        is gone)."""
        self._failed_uids.append(uid)
        self.rerolled_entries += 1
        self._est.pop(uid, None)
        self._gen_total.pop(uid, None)
        self._home.pop(uid, None)

    def _fence(self, i: int) -> None:
        """Seal off a dead or scaled-down replica: forget residency
        records that point at it, zero its routing load, and release its
        engine-side state so the fleet holds no references to it."""
        for uid, h in list(self._home.items()):
            if h == i:                  # pages died with the replica
                del self._home[uid]
        self.load[i] = 0.0
        r = self.replicas[i]
        shutdown = getattr(r, "shutdown", None)
        if shutdown is not None:
            shutdown()
        else:
            r.interrupt()

    def take_failed_uids(self) -> List[int]:
        """Drain the uids whose replica died (or scaled away) without a
        survivor able to take them.  Their engine-side state is gone;
        the caller must re-roll them — the orchestrator scavenges each
        back to PENDING, so on-policy mode discards its tokens and
        partial mode keeps them, exactly the interrupt rule."""
        out, self._failed_uids = self._failed_uids, []
        return out

    def scale_down(self, i: int) -> None:
        """Elastically release replica `i`: a graceful kill.  Its
        in-flight tail drain-packs onto the survivors through the same
        export/import path (the move is voluntary and the state healthy,
        so migration is attempted regardless of ``migrate_kv``), entries
        no survivor can hold are re-rolled, resident KV follows where it
        can, and the replica is fenced out of capacity and accounting."""
        assert self.elastic, "scale_down requires EngineGroup(elastic=True)"
        assert self.alive[i], f"replica {i} is not alive"
        assert sum(self.alive) > 1, "cannot scale down the last live replica"
        r = self.replicas[i]
        for uid in list(r.active_uids()):
            if self._rehome(uid, i) is not None:
                self.rehomed_entries += 1
            elif self._rehome_resident(uid, i):
                # survivors are slot-full: park the KV on one of them as
                # resident state and hand the uid back for a resubmit
                self.rehomed_entries += 1
                self._reschedule(uid)
            else:
                self._release_for_reroll(uid)
        # interrupted-but-resident uids keep their zero-re-prefill resume
        # where a survivor can host the pages
        for uid, h in list(self._home.items()):
            if h != i:
                continue
            for dst in self._alive_indices():
                if dst != i and self._migrate(uid, i, dst):
                    self._remember_home(uid, dst)
                    break
            else:
                # no survivor pool accepted: the pages are gone either
                # way, but release them explicitly (counted in
                # residency_dropped) instead of letting the fence wipe
                # them without trace — the uid re-prefills on resume
                self._drop_donor_residency(i, uid)
                del self._home[uid]
        self.alive[i] = False
        self.scale_events += 1
        self._fence(i)

    def scale_up(self, engine: EngineProtocol) -> int:
        """Elastically add a replica; returns its index.  It joins at
        the group's current weight version and advertises its free slots
        immediately, so new work routes onto it on the next submit (and
        ``weighted_tokens`` learns its speed from its first steps)."""
        assert self.elastic, "scale_up requires EngineGroup(elastic=True)"
        i = len(self.replicas)
        self.replicas.append(engine)
        self.alive.append(True)
        self.load.append(0.0)
        self._busy_time.append(0.0)
        self._cap_time.append(0.0)
        self._dt_ewma.append(None)
        self._stall_until.append(0)
        self._slow_until.append(0)
        engine.sync_weights(self.version)
        self._clock = max(self._clock, engine.clock)
        self._max_gen = max(self._max_gen,
                            getattr(engine, "max_gen_len", 0)) or self._max_gen
        self.scale_events += 1
        return i

    def replica_step_cost(self, i: int) -> float:
        """Observed per-decode-step cost of replica `i` (EWMA of its
        replica-local step dt).  A replica not yet observed assumes the
        fleet mean — and 1.0 before any observation at all, which makes
        every replica equal (cold-start parity with ``least_tokens``)."""
        d = self._dt_ewma[i]
        if d is not None and d > 0:
            return d
        known = [x for x in self._dt_ewma if x is not None and x > 0]
        return sum(known) / len(known) if known else 1.0

    def _finish(self, uid: int, replica: int) -> None:
        total = self._gen_total.pop(uid, 0)
        self._ewma_len = (float(total) if self._ewma_len is None
                          else 0.9 * self._ewma_len + 0.1 * total)
        self.load[replica] -= self._est.pop(uid, 0.0)
        self.load[replica] = max(0.0, self.load[replica])
        self._home.pop(uid, None)

    def interrupt(self, uids: Optional[Sequence[int]] = None) -> List[int]:
        out: List[int] = []
        targets = None if uids is None else set(uids)
        for i, r in enumerate(self.replicas):
            if not self.alive[i]:
                continue        # fenced: nothing left there to stop
            if targets is not None:
                # target the CURRENT holder: a steal or pack migration
                # may have moved a uid off the replica the home-affinity
                # map last recorded, so holders are resolved from live
                # slot state — never from _home, which this path once
                # indexed (the historical re-homing bug: interrupting a
                # migrated uid hit its stale home and missed the entry)
                held = targets.intersection(r.active_uids())
                if not held:
                    continue
                got = r.interrupt(sorted(held))
            else:
                got = r.interrupt()
            for uid in got:
                # keep _home: resident pages make this replica the uid's
                # zero-re-prefill resume target
                self.load[i] -= self._est.pop(uid, 0.0)
                self.load[i] = max(0.0, self.load[i])
                self._gen_total.pop(uid, None)
            out.extend(got)
        return out

    def sync_weights(self, version: int) -> None:
        """Version-stamped broadcast: every replica syncs (its paged KV
        stamps/invalidates per its retain_across_sync setting).  The
        broadcasts overlap, so the group pays the slowest replica's
        sync latency once."""
        dt_group = 0.0
        for i in self._alive_indices():
            r = self.replicas[i]
            t0 = r.clock
            r.sync_weights(version)
            dt_group = max(dt_group, r.clock - t0)
        self._clock += dt_group
        self.version = version

    # -- observability ----------------------------------------------------

    @property
    def replica_bubble_ratio(self) -> float:
        """Per-replica Eq. 4, summed over replicas on replica-local time:
        idle-slot time of *running* replicas over their running time.
        Fully idle replicas count as released, not as bubble."""
        cap = sum(self._cap_time)
        if cap <= 0:
            return 0.0
        return (cap - sum(self._busy_time)) / cap

    @property
    def replica_busy(self) -> float:
        """Time-weighted mean number of simultaneously busy replicas."""
        if self._stepped_time <= 0:
            return 0.0
        return self._busy_replicas_time / self._stepped_time

    def tenant_counts(self) -> List[Dict[str, int]]:
        """Per-replica active-entry count by tenant (serving-tier
        observability; empty dicts outside serving runs).  Dead replicas
        report empty — they are fenced and hold nothing."""
        out: List[Dict[str, int]] = []
        for i, r in enumerate(self.replicas):
            d: Dict[str, int] = {}
            if self.alive[i]:
                for u in r.active_uids():
                    t = self._tenant_by_uid.get(u)
                    if t is not None:
                        d[t] = d.get(t, 0) + 1
            out.append(d)
        # opportunistic prune: tags of long-gone uids must not grow the
        # map forever in an unbounded serving run
        cap = HOME_RETENTION_FACTOR * max(1, self.capacity)
        if len(self._tenant_by_uid) > cap:
            live = set(self.active_uids()) | set(self._home)
            self._tenant_by_uid = {u: t for u, t in self._tenant_by_uid.items()
                                   if u in live}
        return out

    def replica_stats(self) -> List[MetricsSnapshot]:
        """Per-replica detail behind the aggregated ``cache_stats()``,
        one :class:`MetricsSnapshot` per replica (Mapping-compatible, so
        legacy dict-indexing callers are unaffected)."""
        out = []
        for i, r in enumerate(self.replicas):
            cap = self._cap_time[i]
            rec = {
                "capacity": float(r.capacity),
                "alive": float(self.alive[i]),
                "active": float(len(r.active_uids())),
                "est_load": self.load[i],
                "busy_time": self._busy_time[i],
                "bubble_ratio": ((cap - self._busy_time[i]) / cap
                                 if cap > 0 else 0.0),
            }
            sub = getattr(r, "cache_stats", None)
            sub = sub() if sub is not None else None
            if sub:
                rec["stale_kv_reuses"] = sub.get("stale_kv_reuses", 0.0)
                rec["prefill_tokens_saved"] = sub.get(
                    "prefill_tokens_saved", 0.0)
            out.append(MetricsSnapshot(source=f"replica{i}", values=rec))
        return out

    def cache_stats(self) -> MetricsSnapshot:
        """Group gauges + the replicas' paged-KV counters summed, as one
        :class:`MetricsSnapshot` (Mapping-compatible).

        Always non-None (even over SimEngine replicas), so the
        orchestrator's ``record_cache`` plumbing picks the group fields up
        for any replica type."""
        out: Dict[str, float] = {
            "num_replicas": float(len(self.replicas)),
            "alive_replicas": float(sum(self.alive)),
            "steal_count": float(self.steal_count),
            "steal_migrations": float(self.steal_migrations),
            "packed_entries": float(self.packed_entries),
            "replica_deaths": float(self.replica_deaths),
            "rehomed_entries": float(self.rehomed_entries),
            "rerolled_entries": float(self.rerolled_entries),
            "scale_events": float(self.scale_events),
            "residency_dropped": float(self.residency_dropped),
            "replica_busy": self.replica_busy,
            "replica_bubble_ratio": self.replica_bubble_ratio,
            # cumulative Eq. 4 integrals: windowed consumers (the
            # autoscaler's MetricsWindow) difference successive snapshots
            # to get bubble over a recent span rather than the whole run
            "replica_busy_time": float(sum(self._busy_time)),
            "replica_cap_time": float(sum(self._cap_time)),
        }
        subs = []
        for r in self.replicas:
            fn = getattr(r, "cache_stats", None)
            sub = fn() if fn is not None else None
            if sub:
                subs.append(sub)
        if subs:
            for key in ("prefill_tokens_run", "prefill_tokens_saved",
                        "shared_prefills", "resumed_without_prefill",
                        "cow_copies", "evictions", "stale_kv_reuses",
                        "migrated_pages", "pages_in_use", "pages_total",
                        "resident_seqs", "prefill_launches",
                        "resume_attempts", "pool_capacity_tokens"):
                out[key] = float(sum(s.get(key, 0) for s in subs))
            # fleet-level hit rate, recomputed from the summed counters
            # (averaging per-replica rates would weight idle replicas
            # equally with loaded ones)
            out["resident_resume_rate"] = (
                out["resumed_without_prefill"]
                / max(out["resume_attempts"], 1.0))
            # saturation gauge: the WORST per-replica occupancy.  Pooling
            # (sum in_use / sum total) would read ~0.4 while one skewed
            # replica sits at 1.0 evicting resident KV.
            out["page_occupancy"] = max(
                float(s.get("page_occupancy", 0.0)) for s in subs)
        return MetricsSnapshot(source="engine_group", values=out)
