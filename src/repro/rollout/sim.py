"""Discrete-event rollout-engine simulator.

Implements the same EngineProtocol as the real SlotEngine but advances a
virtual clock with a decode cost model, so scheduling strategies can be
compared at paper scale (512-sample workloads, 8k generation budgets) on a
CPU box in milliseconds.  The cost model captures why bubbles hurt:

    step_time = t_fixed + t_token * active

Autoregressive decode is HBM-bandwidth bound — ``t_fixed`` (weight +
KV-cache streaming) dominates, so a step with 3 active slots costs almost
as much as a full one; idle slots are pure waste.  Prefill charges
``t_prefill_token`` per prompt token, and ``sync_weights`` charges a
weight-broadcast latency per update.

Hidden generation lengths are sampled per (uid, re-roll) from a pluggable
length distribution; the paper's long-tailed shape (Fig. 1c) is the
default.

Slot state lives in the same :class:`SlotTable` structure the real engine
uses — ``gen_count`` is the tokens generated this occupancy, ``kv_start``
the scavenged prefix carried in, and ``gen_budget`` the (capped) hidden
length target — so ``step()`` shares the engine's vectorized retirement
path and its ascending-slot event order.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.buffer import BufferEntry
from repro.core.engine_api import SlotTable, StepEvent


def lognormal_lengths(median: float = 1200.0, sigma: float = 0.9,
                      max_len: int = 8192) -> Callable[[random.Random], int]:
    """Long-tailed length distribution matching Fig. 1c's shape: ~80% of
    samples below ~2.5x median, a few percent hitting the budget cap."""
    mu = math.log(median)

    def sample(rng: random.Random) -> int:
        return max(1, min(max_len, int(rng.lognormvariate(mu, sigma))))
    return sample


@dataclasses.dataclass
class SimCostModel:
    t_fixed: float = 20e-3        # s/step: weight+cache streaming (HBM bound)
    t_token: float = 0.05e-3      # s/step/active-slot marginal cost
    t_prefill_token: float = 0.02e-3   # s per prefilled token
    t_sync: float = 0.5           # s per weight sync (trainer -> engine)
    t_update: float = 0.0         # charged externally by the harness

    def step_time(self, active: int) -> float:
        return self.t_fixed + self.t_token * active if active else 0.0


class SimEngine:
    """EngineProtocol implementation over a virtual clock."""

    def __init__(self, capacity: int, max_gen_len: int = 8192,
                 cost: Optional[SimCostModel] = None,
                 length_sampler: Optional[Callable] = None,
                 resample_on_reroll: bool = False, seed: int = 0,
                 length_table: Optional[Dict[int, int]] = None):
        self.capacity = capacity
        self.max_gen_len = max_gen_len
        self.cost = cost or SimCostModel()
        self.length_sampler = length_sampler or lognormal_lengths(
            max_len=max_gen_len)
        self.resample_on_reroll = resample_on_reroll
        # optional uid -> hidden length override.  Per-uid sampling draws
        # from THIS engine's rng at submit time, so in a multi-replica
        # setup the workload would depend on routing; a shared table
        # pins each entry's length to the entry (a property of the
        # prompt, not of the replica that happens to serve it), which is
        # what balancer comparisons need.
        self.length_table = length_table
        self.rng = random.Random(seed)
        self._clock = 0.0
        self.slots = SlotTable(capacity)
        # finish reason per slot: True when the hidden target fits the budget
        self._eos = np.zeros(capacity, bool)
        self._target_by_uid: Dict[int, int] = {}
        self.version = 0

    @property
    def clock(self) -> float:
        return self._clock

    def free_slots(self) -> int:
        return self.slots.free_count()

    def active_uids(self) -> List[int]:
        return self.slots.active_uids()

    def sync_weights(self, version: int) -> None:
        if version != self.version:
            self._clock += self.cost.t_sync
            self.version = version

    def _target(self, e: BufferEntry) -> int:
        if self.length_table is not None and e.uid in self.length_table:
            return self.length_table[e.uid]
        if e.uid not in self._target_by_uid or (
                self.resample_on_reroll and not e.generated):
            self._target_by_uid[e.uid] = self.length_sampler(self.rng)
        return self._target_by_uid[e.uid]

    def submit(self, entries: Sequence[BufferEntry], version: int) -> None:
        slots = self.slots.allocate(len(entries))
        targets = np.array([self._target(e) for e in entries], np.int64)
        prefix = np.array([len(e.generated) for e in entries], np.int32)
        plens = np.array([len(e.prompt) for e in entries], np.int64)
        t = self.slots
        t.uid[slots] = [e.uid for e in entries]
        t.active[slots] = True
        t.gen_count[slots] = 0
        t.kv_start[slots] = prefix
        t.gen_budget[slots] = np.minimum(targets, self.max_gen_len)
        self._eos[slots] = targets <= self.max_gen_len
        self._clock += self.cost.t_prefill_token * float((plens + prefix).sum())

    def step(self) -> List[StepEvent]:
        t = self.slots
        act = t.active_indices()
        if act.size == 0:
            return []
        self._clock += self.cost.step_time(int(act.size))
        t.gen_count[act] += 1
        total = t.kv_start[act] + t.gen_count[act]
        done = total >= t.gen_budget[act]
        reasons = np.where(done, np.where(self._eos[act], "eos", "length"),
                           None)
        uids = t.uid[act].tolist()          # read before batched release
        t.release(act[done])
        return [StepEvent(uid=u, token=1, logprob=-1.0, done=d,
                          finish_reason=r)
                for u, d, r in zip(uids, done.tolist(), reasons.tolist())]

    def interrupt(self, uids: Optional[Sequence[int]] = None) -> List[int]:
        sel = self.slots.select(uids)
        out = [int(u) for u in self.slots.uid[sel]]
        self.slots.release(sel)
        return out
