"""Discrete-event rollout-engine simulator.

Implements the same EngineProtocol as the real SlotEngine but advances a
virtual clock with a decode cost model, so scheduling strategies can be
compared at paper scale (512-sample workloads, 8k generation budgets) on a
CPU box in milliseconds.  The cost model captures why bubbles hurt:

    step_time = t_fixed + t_token * active

Autoregressive decode is HBM-bandwidth bound — ``t_fixed`` (weight +
KV-cache streaming) dominates, so a step with 3 active slots costs almost
as much as a full one; idle slots are pure waste.  Prefill charges
``t_prefill_token`` per prompt token, and ``sync_weights`` charges a
weight-broadcast latency per update.

Hidden generation lengths are sampled per (uid, re-roll) from a pluggable
length distribution; the paper's long-tailed shape (Fig. 1c) is the
default.

Slot state lives in the same :class:`SlotTable` structure the real engine
uses — ``gen_count`` is the tokens generated this occupancy, ``kv_start``
the scavenged prefix carried in, and ``gen_budget`` the (capped) hidden
length target — so ``step()`` shares the engine's vectorized retirement
path and its ascending-slot event order.

Residency & migration
---------------------
With ``kv_residency=True`` the simulator mirrors the paged engine's
resume semantics: interrupted uids stay "resident" and a later resubmit
charges ZERO prefill time (counted in ``resumed_without_prefill`` /
``prefill_tokens_saved``, surfaced via :meth:`cache_stats`).  The default
is off, preserving the pre-residency cost model (every resume re-charges
its prefix) for existing benchmarks.  ``kv_retain_across_sync`` matches
the paged cache's knob: with ``False`` (the on-policy setting) a weight
sync drops every modeled residency, so re-rolls charge a fresh prefill
exactly as :class:`~repro.core.kv_cache.PagedKVCache` would re-run it.
The engine also implements the
optional migration capability (:meth:`export_entry` /
:meth:`import_entry` / :meth:`discard_entry`) the
:class:`~repro.rollout.group.EngineGroup` uses for work stealing and
drain-phase tail packing — migration is FREE here (no pages to copy),
matching the "span copy between pools" the slot engine pays for.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.buffer import BufferEntry
from repro.core.engine_api import SlotTable, StepEvent


def lognormal_lengths(median: float = 1200.0, sigma: float = 0.9,
                      max_len: int = 8192) -> Callable[[random.Random], int]:
    """Long-tailed length distribution matching Fig. 1c's shape: ~80% of
    samples below ~2.5x median, a few percent hitting the budget cap."""
    mu = math.log(median)

    def sample(rng: random.Random) -> int:
        return max(1, min(max_len, int(rng.lognormvariate(mu, sigma))))
    return sample


@dataclasses.dataclass
class SimCostModel:
    t_fixed: float = 20e-3        # s/step: weight+cache streaming (HBM bound)
    t_token: float = 0.05e-3      # s/step/active-slot marginal cost
    t_prefill_token: float = 0.02e-3   # s per prefilled token
    t_sync: float = 0.5           # s per weight sync (trainer -> engine)
    t_update: float = 0.0         # charged externally by the harness

    def step_time(self, active: int) -> float:
        return self.t_fixed + self.t_token * active if active else 0.0


class SimEngine:
    """EngineProtocol implementation over a virtual clock."""

    # resident uids kept per slot of capacity (mirrors EngineGroup's
    # home-map bound): consumed-without-resume uids must not grow forever
    RESIDENT_RETENTION_FACTOR = 4

    def __init__(self, capacity: int, max_gen_len: int = 8192,
                 cost: Optional[SimCostModel] = None,
                 length_sampler: Optional[Callable] = None,
                 resample_on_reroll: bool = False, seed: int = 0,
                 length_table: Optional[Dict[int, int]] = None,
                 kv_residency: bool = False,
                 kv_retain_across_sync: bool = True):
        self.capacity = capacity
        self.max_gen_len = max_gen_len
        self.cost = cost or SimCostModel()
        self.length_sampler = length_sampler or lognormal_lengths(
            max_len=max_gen_len)
        self.resample_on_reroll = resample_on_reroll
        # optional uid -> hidden length override.  Per-uid sampling draws
        # from THIS engine's rng at submit time, so in a multi-replica
        # setup the workload would depend on routing; a shared table
        # pins each entry's length to the entry (a property of the
        # prompt, not of the replica that happens to serve it), which is
        # what balancer comparisons need.
        self.length_table = length_table
        self.kv_residency = kv_residency
        self.kv_retain_across_sync = kv_retain_across_sync
        self.rng = random.Random(seed)
        self.throttle_factor = 1.0
        self._clock = 0.0
        self.slots = SlotTable(capacity)
        # finish reason per slot: True when the hidden target fits the budget
        self._eos = np.zeros(capacity, bool)
        self._target_by_uid: Dict[int, int] = {}
        self._resident: Dict[int, None] = {}       # insertion-ordered LRU
        self.version = 0
        # paged-engine-shaped counters (cache_stats surface)
        self.prefill_tokens_run = 0
        self.prefill_tokens_saved = 0
        self.resumed_without_prefill = 0

    @property
    def clock(self) -> float:
        return self._clock

    def free_slots(self) -> int:
        return self.slots.free_count()

    def active_uids(self) -> List[int]:
        return self.slots.active_uids()

    def sync_weights(self, version: int) -> None:
        if version != self.version:
            self._clock += self.cost.t_sync
            self.version = version
            if not self.kv_retain_across_sync:
                # strict sync (on-policy re-rolls): pre-sync KV must not
                # serve a free resume — same rule as PagedKVCache
                self._resident.clear()

    def _target(self, e: BufferEntry) -> int:
        if self.length_table is not None and e.uid in self.length_table:
            return self.length_table[e.uid]
        if e.uid not in self._target_by_uid or (
                self.resample_on_reroll and not e.generated):
            self._target_by_uid[e.uid] = self.length_sampler(self.rng)
        return self._target_by_uid[e.uid]

    def submit(self, entries: Sequence[BufferEntry], version: int) -> None:
        slots = self.slots.allocate(len(entries))
        targets = np.array([self._target(e) for e in entries], np.int64)
        prefix = np.array([len(e.generated) for e in entries], np.int32)
        plens = np.array([len(e.prompt) for e in entries], np.int64)
        t = self.slots
        t.uid[slots] = [e.uid for e in entries]
        t.active[slots] = True
        t.gen_count[slots] = 0
        t.kv_start[slots] = prefix
        t.gen_budget[slots] = np.minimum(targets, self.max_gen_len)
        self._eos[slots] = targets <= self.max_gen_len
        charged = 0
        for e, rows in zip(entries, (plens + prefix).tolist()):
            if e.uid in self._resident:
                # resident resume: the modeled KV is still warm (paged-
                # engine semantics) — zero prefill charge
                del self._resident[e.uid]
                self.prefill_tokens_saved += rows
                self.resumed_without_prefill += 1
            else:
                charged += rows
        self.prefill_tokens_run += charged
        self._clock += self.cost.t_prefill_token * float(charged)

    def step(self) -> List[StepEvent]:
        t = self.slots
        act = t.active_indices()
        if act.size == 0:
            return []
        self._clock += self.cost.step_time(int(act.size)) * self.throttle_factor
        t.gen_count[act] += 1
        total = t.kv_start[act] + t.gen_count[act]
        done = total >= t.gen_budget[act]
        reasons = np.where(done, np.where(self._eos[act], "eos", "length"),
                           None)
        uids = t.uid[act].tolist()          # read before batched release
        t.release(act[done])
        return [StepEvent(uid=u, token=1, logprob=-1.0, done=d,
                          finish_reason=r)
                for u, d, r in zip(uids, done.tolist(), reasons.tolist())]

    def interrupt(self, uids: Optional[Sequence[int]] = None) -> List[int]:
        sel = self.slots.select(uids)
        out = [int(u) for u in self.slots.uid[sel]]
        self.slots.release(sel)
        if self.kv_residency:
            for uid in out:
                self._resident.pop(uid, None)
                self._resident[uid] = None       # re-insert: LRU recency
            cap = self.RESIDENT_RETENTION_FACTOR * self.capacity
            while len(self._resident) > cap:
                # oldest residency first (consumed-without-resume uids)
                del self._resident[next(iter(self._resident))]
        return out

    # -- fault-tolerance surface (EngineGroup chaos / elasticity) ---------

    def throttle(self, factor: float) -> None:
        """Scale the decode step cost (a degraded replica: thermal
        throttling, a sick host).  1.0 restores nominal speed; prefill
        and sync latencies are left alone — the fault models a slow
        decode loop, not a slow interconnect."""
        self.throttle_factor = float(factor)

    def shutdown(self) -> None:
        """Fence the engine (killed or scaled-down replica): release
        every slot and forget all modeled residency.  Counters survive —
        the work done before the fence was real."""
        self.slots.release(self.slots.active_indices())
        self._resident.clear()

    # -- residency / cache surface (paged-engine-shaped) ------------------

    def drop_resident(self, uid: int) -> bool:
        """Forget a uid's modeled residency (its warm KV is abandoned).
        Returns whether anything was actually held, so callers (the
        group's ``residency_dropped`` gauge) can count real losses."""
        held = uid in self._resident
        self._resident.pop(uid, None)
        return held

    def cache_stats(self) -> Dict[str, float]:
        """Prefill counters in the paged engine's cache_stats shape, so
        sim-replica groups and benchmarks can pin zero-re-prefill resumes
        without a real page pool behind them."""
        return {
            "prefill_tokens_run": float(self.prefill_tokens_run),
            "prefill_tokens_saved": float(self.prefill_tokens_saved),
            "resumed_without_prefill": float(self.resumed_without_prefill),
        }

    # -- migration capability (EngineGroup work stealing / tail packing) --

    def export_entry(self, uid: int) -> Optional[Dict]:
        """Snapshot an in-flight slot (or a resident uid) for migration to
        a peer replica.  Pure read — pair with :meth:`discard_entry` once
        the importer has accepted the handle."""
        sel = np.flatnonzero((self.slots.uid == uid) & self.slots.active)
        if sel.size:
            i = int(sel[0])
            t = self.slots
            return {"engine": "sim", "uid": uid, "active": True,
                    "slot": {"gen_count": int(t.gen_count[i]),
                             "kv_start": int(t.kv_start[i]),
                             "gen_budget": int(t.gen_budget[i]),
                             "eos": bool(self._eos[i])},
                    "target": self._target_by_uid.get(uid)}
        if uid in self._resident:
            return {"engine": "sim", "uid": uid, "active": False,
                    "target": self._target_by_uid.get(uid)}
        return None

    def import_entry(self, handle: Dict) -> bool:
        """Land a migrated entry: an active slot is transplanted verbatim
        (the decode continues exactly where the donor stopped), a resident
        uid becomes resident here.  Free — the simulator has no pages to
        copy.  Returns False (engine unchanged) when it cannot accept."""
        if handle.get("engine") != "sim":
            return False
        if handle["active"]:
            if self.free_slots() <= 0:
                return False
            s = handle["slot"]
            slot = self.slots.allocate(1)
            t = self.slots
            t.uid[slot] = handle["uid"]
            t.active[slot] = True
            t.gen_count[slot] = s["gen_count"]
            t.kv_start[slot] = s["kv_start"]
            t.gen_budget[slot] = s["gen_budget"]
            self._eos[slot] = s["eos"]
        else:
            if not self.kv_residency:
                return False
            self._resident[handle["uid"]] = None
        if handle.get("target") is not None:
            self._target_by_uid[handle["uid"]] = handle["target"]
        return True

    def discard_entry(self, uid: int) -> None:
        """Drop every local trace of a migrated-away uid."""
        sel = self.slots.select([uid])
        if sel.size:
            self.slots.release(sel)
        self._resident.pop(uid, None)
