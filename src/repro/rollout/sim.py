"""Discrete-event rollout-engine simulator.

Implements the same EngineProtocol as the real SlotEngine but advances a
virtual clock with a decode cost model, so scheduling strategies can be
compared at paper scale (512-sample workloads, 8k generation budgets) on a
CPU box in milliseconds.  The cost model captures why bubbles hurt:

    step_time = t_fixed + t_token * active

Autoregressive decode is HBM-bandwidth bound — ``t_fixed`` (weight +
KV-cache streaming) dominates, so a step with 3 active slots costs almost
as much as a full one; idle slots are pure waste.  Prefill charges
``t_prefill_token`` per prompt token, and ``sync_weights`` charges a
weight-broadcast latency per update.

Hidden generation lengths are sampled per (uid, re-roll) from a pluggable
length distribution; the paper's long-tailed shape (Fig. 1c) is the
default.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.buffer import BufferEntry
from repro.core.engine_api import StepEvent


def lognormal_lengths(median: float = 1200.0, sigma: float = 0.9,
                      max_len: int = 8192) -> Callable[[random.Random], int]:
    """Long-tailed length distribution matching Fig. 1c's shape: ~80% of
    samples below ~2.5x median, a few percent hitting the budget cap."""
    mu = math.log(median)

    def sample(rng: random.Random) -> int:
        return max(1, min(max_len, int(rng.lognormvariate(mu, sigma))))
    return sample


@dataclasses.dataclass
class SimCostModel:
    t_fixed: float = 20e-3        # s/step: weight+cache streaming (HBM bound)
    t_token: float = 0.05e-3      # s/step/active-slot marginal cost
    t_prefill_token: float = 0.02e-3   # s per prefilled token
    t_sync: float = 0.5           # s per weight sync (trainer -> engine)
    t_update: float = 0.0         # charged externally by the harness

    def step_time(self, active: int) -> float:
        return self.t_fixed + self.t_token * active if active else 0.0


@dataclasses.dataclass
class _Slot:
    uid: int
    target: int          # hidden total generation length for this request
    generated: int       # tokens generated in THIS occupancy
    prefix: int          # scavenged tokens carried in (partial mode)


class SimEngine:
    """EngineProtocol implementation over a virtual clock."""

    def __init__(self, capacity: int, max_gen_len: int = 8192,
                 cost: Optional[SimCostModel] = None,
                 length_sampler: Optional[Callable] = None,
                 resample_on_reroll: bool = False, seed: int = 0):
        self.capacity = capacity
        self.max_gen_len = max_gen_len
        self.cost = cost or SimCostModel()
        self.length_sampler = length_sampler or lognormal_lengths(
            max_len=max_gen_len)
        self.resample_on_reroll = resample_on_reroll
        self.rng = random.Random(seed)
        self._clock = 0.0
        self._slots: Dict[int, _Slot] = {}          # slot index -> state
        self._target_by_uid: Dict[int, int] = {}
        self.version = 0

    @property
    def clock(self) -> float:
        return self._clock

    def free_slots(self) -> int:
        return self.capacity - len(self._slots)

    def active_uids(self) -> List[int]:
        return [s.uid for s in self._slots.values()]

    def sync_weights(self, version: int) -> None:
        if version != self.version:
            self._clock += self.cost.t_sync
            self.version = version

    def _target(self, e: BufferEntry) -> int:
        if e.uid not in self._target_by_uid or (
                self.resample_on_reroll and not e.generated):
            self._target_by_uid[e.uid] = self.length_sampler(self.rng)
        return self._target_by_uid[e.uid]

    def submit(self, entries: Sequence[BufferEntry], version: int) -> None:
        assert len(entries) <= self.free_slots(), "not enough free slots"
        free = [i for i in range(self.capacity) if i not in self._slots]
        for slot, e in zip(free, entries):
            target = self._target(e)
            prefix = len(e.generated)
            self._slots[slot] = _Slot(uid=e.uid, target=target,
                                      generated=0, prefix=prefix)
            self._clock += self.cost.t_prefill_token * (len(e.prompt) + prefix)

    def step(self) -> List[StepEvent]:
        if not self._slots:
            return []
        self._clock += self.cost.step_time(len(self._slots))
        events: List[StepEvent] = []
        finished = []
        for slot, st in self._slots.items():
            st.generated += 1
            total = st.prefix + st.generated
            done = total >= min(st.target, self.max_gen_len)
            reason = None
            if done:
                reason = "eos" if st.target <= self.max_gen_len else "length"
                finished.append(slot)
            events.append(StepEvent(uid=st.uid, token=1,
                                    logprob=-1.0, done=done,
                                    finish_reason=reason))
        for slot in finished:
            del self._slots[slot]
        return events

    def interrupt(self, uids: Optional[Sequence[int]] = None) -> List[int]:
        out = []
        for slot in list(self._slots):
            uid = self._slots[slot].uid
            if uids is None or uid in uids:
                out.append(uid)
                del self._slots[slot]
        return out
