"""Feedback-driven fleet autoscaling: observed metrics drive scale events.

The paper's Eq. 4 bubble ratio is exactly an autoscaling signal — idle-slot
time on *running* replicas is capacity the fleet is paying for and not
using — and the serving tier's per-tenant backlog age is the opposite
signal: capacity the fleet is missing.  Until now both were observability
output only and every ``EngineGroup.scale_down``/``scale_up`` call was
manual.  This module closes the loop:

* :class:`AutoscalerPolicy` — a protocol behind a string registry
  (mirroring the scheduler / balancer / admission registries): given an
  :class:`AutoscaleView` of the fleet, propose ``-1`` (shed a replica),
  ``+1`` (add one), or ``0``.  Policies are *pure* deciders; feasibility
  (drainable victim, min/max clamp, cooldown) lives in the controller.

  - ``bubble_target`` — shed when the windowed ``replica_bubble_ratio``
    exceeds a high-water mark (drain-phase tail: RollPacker's "shedding
    is free during drain"), add when free capacity starves pending work
    while the fleet runs hot (windowed bubble under the low-water mark).
  - ``queue_depth`` — serving tier: add when per-tenant backlog age
    threatens SLO deadlines with no free slot to admit the head, shed
    when the ingress is drained and the fleet bubbles (Seer's fleet
    view: an idle replica is reclaimable capacity).

* :class:`MetricsWindow` — a sliding window of :class:`MetricsSnapshot`
  observations on the group clock.  The group's cumulative Eq. 4
  integrals (``replica_busy_time`` / ``replica_cap_time`` in
  ``cache_stats()``) are differenced across the window, so the policy
  sees *recent* bubble, not the whole-run average that a long healthy
  bulk phase would wash out.

* :class:`Autoscaler` — the controller, ticked once per group step by
  the orchestrator.  Hysteresis (a non-zero proposal must persist for
  ``confirm_steps`` consecutive ticks) plus a post-action ``cooldown``
  on the group clock keep chaos-plan faults (a stall window, a kill
  blip) from causing flapping; ``min_replicas``/``max_replicas`` bound
  the fleet; a replica ``factory`` mints warm replicas for ``scale_up``
  (the group syncs them to its weight version; mixed ``cap_total`` is
  fine — ``weighted_tokens`` already routes heterogeneous fleets).

Everything is deterministic: the view is derived from the group's
deterministic accounting, victim selection breaks ties on replica index,
and the event log is reproducible under a fixed workload seed.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import (Callable, Deque, Dict, List, Optional, Protocol, Tuple,
                    runtime_checkable)

from repro.core.engine_api import EngineProtocol
from repro.core.metrics import MetricsSnapshot


# -----------------------------------------------------------------------------
# windowed metrics view
# -----------------------------------------------------------------------------

class MetricsWindow:
    """Sliding window over (clock, MetricsSnapshot) observations.

    Keeps every observation within ``span`` of the newest plus one older
    observation as the delta base, so :meth:`delta` always spans at least
    ``span`` once enough history exists.  ``bubble()`` is the windowed
    per-replica Eq. 4: idle-slot time over capacity time of *running*
    replicas, differenced across the window."""

    def __init__(self, span: float):
        assert span > 0, "window span must be positive"
        self.span = float(span)
        self._obs: Deque[Tuple[float, MetricsSnapshot]] = deque()

    def push(self, now: float, snap: MetricsSnapshot) -> None:
        self._obs.append((float(now), snap))
        while len(self._obs) > 2 and self._obs[1][0] <= now - self.span:
            self._obs.popleft()

    def __len__(self) -> int:
        return len(self._obs)

    @property
    def covered(self) -> float:
        """Clock span actually covered by the current observations."""
        if len(self._obs) < 2:
            return 0.0
        return self._obs[-1][0] - self._obs[0][0]

    @property
    def full(self) -> bool:
        """Whether the window has accumulated ``span`` of history — shed
        decisions wait for this so a cold fleet's fill phase (briefly
        high bubble) cannot trigger a premature scale_down."""
        return self.covered >= self.span

    def delta(self, key: str) -> float:
        """Windowed increase of a cumulative gauge."""
        if len(self._obs) < 2:
            return 0.0
        new = float(self._obs[-1][1].get(key, 0.0))
        old = float(self._obs[0][1].get(key, 0.0))
        return new - old

    def bubble(self) -> float:
        """Windowed replica_bubble_ratio (Eq. 4 over the window)."""
        cap = self.delta("replica_cap_time")
        if cap <= 0:
            return 0.0
        busy = self.delta("replica_busy_time")
        return max(0.0, (cap - busy) / cap)


# -----------------------------------------------------------------------------
# the policy protocol + registry
# -----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AutoscaleView:
    """What a policy sees each tick — fleet shape, windowed signals, and
    (for serving) backlog pressure.  All fields derive deterministically
    from the group's accounting and the orchestrator's buffer/ingress."""
    now: float                  # group clock at this tick
    alive: int                  # live replicas
    capacity: int               # live fleet slot count
    free_slots: int             # live fleet free slots
    pending: int                # buffer entries waiting for a slot
    running: int                # buffer entries decoding
    window_bubble: float        # windowed replica_bubble_ratio (Eq. 4)
    window_full: bool           # window has span's worth of history
    min_replicas: int
    max_replicas: Optional[int]
    # serving-tier backlog signals (zero outside serving runs)
    queue_backlog: int = 0      # queued requests across tenants
    oldest_wait: float = 0.0    # max head wait across tenant queues
    slo_pressure: float = 0.0   # max head (wait / latency_slo); 0 = no SLO

    @property
    def can_grow(self) -> bool:
        return self.max_replicas is None or self.alive < self.max_replicas

    @property
    def can_shed(self) -> bool:
        return self.alive > max(1, self.min_replicas)


@runtime_checkable
class AutoscalerPolicy(Protocol):
    """propose(view) -> -1 (shed one replica), 0 (hold), +1 (add one)."""

    name: str

    def propose(self, view: AutoscaleView) -> int: ...


_AUTOSCALERS: Dict[str, Callable[..., AutoscalerPolicy]] = {}


def register_autoscaler(name: str):
    def deco(factory):
        _AUTOSCALERS[name] = factory
        return factory
    return deco


def make_autoscaler(name: str, **kwargs) -> AutoscalerPolicy:
    if name not in _AUTOSCALERS:
        raise KeyError(f"unknown autoscaler {name!r}; "
                       f"registered: {available_autoscalers()}")
    return _AUTOSCALERS[name](**kwargs)


def available_autoscalers() -> List[str]:
    return sorted(_AUTOSCALERS)


@register_autoscaler("bubble_target")
class BubbleTargetPolicy:
    """Hold the windowed bubble ratio between two water marks.

    Shed when the windowed Eq. 4 bubble exceeds ``high`` — running
    replicas are collectively idling more than the target, so the tail
    fits on fewer of them (the controller only acts when a victim is
    drainable).  Add when pending work is starved of capacity (zero free
    slots, non-empty pending queue) while the fleet runs *hot*
    (windowed bubble at or under ``low``) — adding capacity when the
    fleet already bubbles would just add idle slots.  The gap between
    the marks is the hysteresis band: a fleet sitting between them is
    left alone."""

    name = "bubble_target"

    def __init__(self, high: float = 0.5, low: float = 0.15):
        assert 0.0 <= low < high <= 1.0, "need 0 <= low < high <= 1"
        self.high = float(high)
        self.low = float(low)

    def propose(self, view: AutoscaleView) -> int:
        if (view.can_grow and view.pending > 0 and view.free_slots <= 0
                and view.window_bubble <= self.low):
            return 1
        if (view.can_shed and view.window_full
                and view.window_bubble >= self.high):
            return -1
        return 0


@register_autoscaler("queue_depth")
class QueueDepthPolicy:
    """Serving tier: scale on per-tenant backlog age vs SLO deadlines.

    Add a replica when a queued head has burned ``wait_frac`` of its
    tenant's ``latency_slo`` waiting (or has waited ``target_wait``
    absolute, for tenants without an SLO) and the fleet has no free slot
    to admit it — backlog age, not raw depth, so a deep-but-fresh burst
    within budget does not trigger growth.  Shed when the ingress is
    fully drained and the windowed bubble shows the fleet idling
    (``idle_bubble``): an idle replica is reclaimable capacity."""

    name = "queue_depth"

    def __init__(self, wait_frac: float = 0.5, target_wait: float = 2.0,
                 idle_bubble: float = 0.5):
        assert 0.0 < wait_frac <= 1.0
        self.wait_frac = float(wait_frac)
        self.target_wait = float(target_wait)
        self.idle_bubble = float(idle_bubble)

    def propose(self, view: AutoscaleView) -> int:
        starved = view.queue_backlog > 0 and view.free_slots <= 0
        aged = (view.slo_pressure >= self.wait_frac
                or view.oldest_wait >= self.target_wait)
        if view.can_grow and starved and aged:
            return 1
        if (view.can_shed and view.window_full
                and view.queue_backlog == 0
                and view.window_bubble >= self.idle_bubble):
            return -1
        return 0


# -----------------------------------------------------------------------------
# the controller
# -----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One acted-on proposal, for logs / benchmarks / tests."""
    t: float                    # group clock when the action fired
    direction: int              # +1 added a replica, -1 shed one
    replica: int                # index added or shed
    window_bubble: float        # signal at decision time


class Autoscaler:
    """Evaluates an :class:`AutoscalerPolicy` each group step and drives
    ``EngineGroup.scale_down``/``scale_up``.

    ``policy`` is a registry name or a policy instance.  ``factory``
    mints a warm replica for scale_up, called with the new replica's
    index (``factory(index) -> EngineProtocol``); without one the
    controller can only shed.  ``window`` and ``cooldown`` are in group
    clock units (modeled seconds for SimEngine fleets).  Hysteresis: a
    non-zero proposal must persist for ``confirm_steps`` consecutive
    ticks before it is acted on, so one noisy step (a stall fault, a
    fill blip) cannot flap the fleet."""

    def __init__(self, policy, *,
                 factory: Optional[Callable[[int], EngineProtocol]] = None,
                 min_replicas: int = 1,
                 max_replicas: Optional[int] = None,
                 window: float = 1.0,
                 cooldown: float = 0.5,
                 confirm_steps: int = 2,
                 policy_kwargs: Optional[dict] = None):
        if isinstance(policy, str):
            policy = make_autoscaler(policy, **(policy_kwargs or {}))
        assert min_replicas >= 1, "min_replicas must be >= 1"
        assert max_replicas is None or max_replicas >= min_replicas
        assert confirm_steps >= 1
        self.policy: AutoscalerPolicy = policy
        self.factory = factory
        self.min_replicas = int(min_replicas)
        self.max_replicas = max_replicas
        self.cooldown = float(cooldown)
        self.confirm_steps = int(confirm_steps)
        self.window = MetricsWindow(window)
        self.events: List[ScaleEvent] = []
        self.last_view: Optional[AutoscaleView] = None
        self._streak_dir = 0        # direction of the current streak
        self._streak = 0            # consecutive ticks proposing it
        self._last_action_t: Optional[float] = None

    # -- observation -------------------------------------------------------

    def observe(self, group) -> AutoscaleView:
        """Push the group's current snapshot and build this tick's view
        (without acting) — also the hook tests/benchmarks use to read
        the windowed signal at run end."""
        now = float(group.clock)
        self.window.push(now, group.cache_stats())
        return self._view(group, now)

    def _view(self, group, now: float, *, pending: int = 0, running: int = 0,
              queue_backlog: int = 0, oldest_wait: float = 0.0,
              slo_pressure: float = 0.0) -> AutoscaleView:
        return AutoscaleView(
            now=now, alive=sum(group.alive), capacity=group.capacity,
            free_slots=group.free_slots(), pending=pending, running=running,
            window_bubble=self.window.bubble(), window_full=self.window.full,
            min_replicas=self.min_replicas, max_replicas=self.max_replicas,
            queue_backlog=queue_backlog, oldest_wait=oldest_wait,
            slo_pressure=slo_pressure)

    # -- the per-step tick -------------------------------------------------

    def tick(self, group, *, pending: int = 0, running: int = 0,
             queue_backlog: int = 0, oldest_wait: float = 0.0,
             slo_pressure: float = 0.0) -> Optional[ScaleEvent]:
        """One observe -> propose -> (maybe) act cycle.  Returns the
        ScaleEvent when an action fired, else None."""
        now = float(group.clock)
        self.window.push(now, group.cache_stats())
        view = self._view(group, now, pending=pending, running=running,
                          queue_backlog=queue_backlog,
                          oldest_wait=oldest_wait, slo_pressure=slo_pressure)
        self.last_view = view
        want = self.policy.propose(view)
        if want == 0:
            self._streak_dir, self._streak = 0, 0
            return None
        if want == self._streak_dir:
            self._streak += 1
        else:
            self._streak_dir, self._streak = want, 1
        if self._streak < self.confirm_steps:
            return None
        if (self._last_action_t is not None
                and now - self._last_action_t < self.cooldown):
            return None             # cooling down; streak stays armed
        if want > 0:
            return self._grow(group, view, now)
        return self._shed(group, view, now)

    def _record(self, now: float, direction: int, replica: int,
                view: AutoscaleView) -> ScaleEvent:
        ev = ScaleEvent(t=now, direction=direction, replica=replica,
                        window_bubble=view.window_bubble)
        self.events.append(ev)
        self._last_action_t = now
        self._streak_dir, self._streak = 0, 0
        return ev

    def _grow(self, group, view: AutoscaleView,
              now: float) -> Optional[ScaleEvent]:
        if self.factory is None or not view.can_grow:
            return None
        idx = group.scale_up(self.factory(len(group.replicas)))
        return self._record(now, +1, idx, view)

    def _shed(self, group, view: AutoscaleView,
              now: float) -> Optional[ScaleEvent]:
        if not view.can_shed:
            return None
        victim = self._pick_victim(group)
        if victim is None:
            return None             # nothing drainable; stay armed
        group.scale_down(victim)
        return self._record(now, -1, victim, view)

    def _pick_victim(self, group) -> Optional[int]:
        """The emptiest live replica, if it is drainable: idle outright,
        or its in-flight tail fits in the survivors' free slots (so the
        scale_down migrates/resubmits instead of re-rolling work).
        Deterministic: ties break on replica index."""
        alive = [i for i, a in enumerate(group.alive) if a]
        if len(alive) <= 1:
            return None
        counts = {i: len(group.replicas[i].active_uids()) for i in alive}
        victim = min(alive, key=lambda i: (counts[i], i))
        survivor_free = sum(group.replicas[i].free_slots()
                            for i in alive if i != victim)
        if counts[victim] == 0 or counts[victim] <= survivor_free:
            return victim
        return None
