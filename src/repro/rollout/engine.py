"""Slot-based JAX rollout engine.

TPU adaptation of the paper's SGLang/CUDA-graph setup: a *fixed* slot count
means the jitted ``decode_step`` has one static shape — the XLA analogue of
graph capture.  Oversubscription (the controller refilling slots every
step) keeps the engine at its saturation batch; early termination frees
slots at harvest boundaries.  Inactive slots decode garbage that is masked
out — exactly the padding waste the bubble ratio (Eq. 4) measures.

Weight sync is O(1): the engine reads params through a callback, so the
trainer's latest state is always visible (colocated / stage-fused setup).

Hot-path notes
--------------
* ``step()`` is loop-free on the host: EOS/budget masking, event
  construction, and slot retirement are numpy array ops over the
  :class:`SlotTable`.  Events come out in ascending slot order, which is
  stable for the lifetime of each request's occupancy.
* Prefill shapes are bucketed — width to the next power of two (clamped
  to ``max_total_len``) and batch to the next power of two (clamped to
  ``capacity``) — so ``_prefill_cache`` holds at most
  O(log max_total_len · log capacity) compiled functions instead of one
  per exact (width, batch) pair.  Right-padding models mask the extra
  width via ``prompt_lens``/``kv_len``; left-padding models see a longer
  pad prefix (masked by their prefill), but since their valid tokens end
  AT the width, inflation eats generation headroom — their buckets are
  capped at ``max_total_len - max_gen_len - 1`` with an exact-width
  fallback for longer prompts (see ``_bucket_width``).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffer import BufferEntry
from repro.core.engine_api import SlotTable, StepEvent
from repro.models.model import Model

# per-family cache batch-axis maps (see Model cache layouts)
CACHE_BATCH_AXIS = {
    "k": 1, "v": 1, "k_local": 1, "v_local": 1, "k_global": 1, "v_global": 1,
    "k_x": 1, "v_x": 1,
    "ssm_main": 2, "conv_x_main": 2, "conv_bc_main": 2, "ssm_tail": 1,
    "conv_x_tail": 1, "conv_bc_tail": 1,
    "attn_k": 1, "attn_v": 1,
    "mlstm_C": 1, "mlstm_n": 1, "mlstm_conv": 1,
    "slstm_c": 1, "slstm_n": 1, "slstm_h": 1, "slstm_m": 1,
}


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << (n - 1).bit_length()


def cache_put(cache: Dict[str, jnp.ndarray], sub: Dict[str, jnp.ndarray],
              slots: np.ndarray) -> Dict[str, jnp.ndarray]:
    """Write per-slot sub-cache into the engine cache at `slots`.

    The sub-cache batch may be padded past ``len(slots)`` (batch-bucketed
    prefill); only the first ``len(slots)`` rows are real and written.
    """
    out = {}
    k = len(slots)
    for name, arr in cache.items():
        ax = CACHE_BATCH_AXIS[name]
        sl = sub[name]
        if sl.shape[ax] != k:
            sl = jax.lax.slice_in_dim(sl, 0, k, axis=ax)
        idx = (slice(None),) * ax + (slots,)
        out[name] = arr.at[idx].set(sl.astype(arr.dtype))
    return out


class SlotEngine:
    def __init__(self, model: Model, params_fn: Callable[[], Dict],
                 capacity: int, max_total_len: int, max_gen_len: int,
                 eos_id: int, pad_id: int = 0, temperature: float = 1.0,
                 seed: int = 0):
        self.model = model
        self.params_fn = params_fn
        self.capacity = capacity
        self.max_total_len = max_total_len
        self.max_gen_len = max_gen_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)
        self._t0 = time.monotonic()
        self.version = 0

        self.slots = SlotTable(capacity)
        self.cache = model.init_cache(capacity, max_total_len)
        self._decode_jit = jax.jit(self._decode_fn)
        self._prefill_cache: Dict[Tuple[int, int], Callable] = {}

    # -- time ---------------------------------------------------------------

    @property
    def clock(self) -> float:
        return time.monotonic() - self._t0

    # -- slot queries ---------------------------------------------------------

    def free_slots(self) -> int:
        return self.slots.free_count()

    def active_uids(self) -> List[int]:
        return self.slots.active_uids()

    def sync_weights(self, version: int) -> None:
        self.version = version   # params_fn always reads the latest state

    # -- submit: batched prefill of new entries into free slots ---------------

    def submit(self, entries: Sequence[BufferEntry], version: int) -> None:
        if not entries:
            return
        k = len(entries)
        slots = self.slots.allocate(k)
        params = self.params_fn()

        seqs = [list(e.prompt) + list(e.generated) for e in entries]
        # prefill everything but the last token; it is fed on the next step
        pre = [s[:-1] for s in seqs]
        width = self._bucket_width(max(1, max(len(p) for p in pre)))
        kb = self._bucket_batch(k)
        toks = np.full((kb, width), self.pad_id, np.int32)
        plens = np.zeros(kb, np.int32)
        for i, p in enumerate(pre):
            plens[i] = len(p)
            if self.model.padding_side == "right":
                toks[i, :len(p)] = p
            else:
                toks[i, width - len(p):] = p

        batch = {"tokens": jnp.asarray(toks), "prompt_lens": jnp.asarray(plens)}
        self._add_stub_inputs(batch, kb)
        sub_cache = self.model.init_cache(kb, self.max_total_len)
        _, sub_cache = self._prefill(params, batch, sub_cache, width, kb)
        self.cache = cache_put(self.cache, sub_cache, slots)

        t = self.slots
        t.uid[slots] = [e.uid for e in entries]
        t.active[slots] = True
        t.next_token[slots] = [s[-1] for s in seqs]
        if self.model.padding_side == "right":
            t.kv_len[slots] = plens[:k] + self.model.prefill_extra
            t.kv_start[slots] = 0
        else:
            t.kv_len[slots] = width
            t.kv_start[slots] = width - plens[:k]
        t.gen_count[slots] = [len(e.generated) for e in entries]
        t.gen_budget[slots] = self.max_gen_len

    def _add_stub_inputs(self, batch: Dict, k: int) -> None:
        cfg = self.model.cfg
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (k, cfg.num_stub_positions, cfg.d_model), cfg.compute_dtype)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (k, cfg.num_stub_positions, cfg.d_model), cfg.compute_dtype)

    # -- prefill shape bucketing ----------------------------------------------

    def _bucket_width(self, width: int) -> int:
        assert width <= self.max_total_len, (width, self.max_total_len)
        if self.model.padding_side == "right":
            # padded positions beyond prompt_lens are masked via kv_len, so
            # inflating the width is free
            return min(next_pow2(width), self.max_total_len)
        # left padding: valid tokens END at the bucketed width, so kv_len =
        # width and every padded column eats generation headroom out of the
        # fixed cache.  Bucket only while the full gen budget still fits;
        # past that, fall back to the exact width (seed behaviour).
        safe = self.max_total_len - self.max_gen_len - 1
        return max(width, min(next_pow2(width), max(safe, 1)))

    def _bucket_batch(self, k: int) -> int:
        return min(next_pow2(k), self.capacity)

    def _prefill(self, params, batch, cache, width, kb):
        fn = self._prefill_cache.get((width, kb))
        if fn is None:
            fn = jax.jit(self.model.prefill)
            self._prefill_cache[(width, kb)] = fn
        return fn(params, batch, cache)

    # -- decode ---------------------------------------------------------------

    def _decode_fn(self, params, token, cache, kv_len, kv_start, key):
        logits, cache = self.model.decode_step(params, token, cache, kv_len,
                                               kv_start=kv_start)
        logits = logits.astype(jnp.float32)
        if self.temperature > 0:
            sampled = jax.random.categorical(key, logits / self.temperature,
                                             axis=-1)
        else:
            sampled = jnp.argmax(logits, axis=-1)
        logprobs = jax.nn.log_softmax(logits, axis=-1)
        lp = jnp.take_along_axis(logprobs, sampled[:, None], axis=1)[:, 0]
        return sampled.astype(jnp.int32), lp, cache

    def step(self) -> List[StepEvent]:
        t = self.slots
        act = t.active_indices()
        if act.size == 0:
            return []
        params = self.params_fn()
        self._key, sub = jax.random.split(self._key)
        kv_len = np.where(t.active, t.kv_len, 0).astype(np.int32)
        sampled, lp, self.cache = self._decode_jit(
            params, jnp.asarray(t.next_token), self.cache,
            jnp.asarray(kv_len), jnp.asarray(t.kv_start), sub)
        sampled = np.asarray(sampled)
        lp = np.asarray(lp)

        # vectorized bookkeeping over the active slots (ascending order)
        t.kv_len[act] += 1
        t.gen_count[act] += 1
        toks = sampled[act]
        eos = toks == self.eos_id
        over = ((t.gen_count[act] >= t.gen_budget[act])
                | (t.kv_len[act] >= self.max_total_len - 1))
        done = eos | over
        reasons = np.where(eos, "eos", np.where(over, "length", None))

        uids = t.uid[act].tolist()          # read before batched release
        t.release(act[done])
        cont = act[~done]
        t.next_token[cont] = toks[~done]

        return [StepEvent(uid=u, token=tk, logprob=l, done=d, finish_reason=r)
                for u, tk, l, d, r in zip(uids, toks.tolist(), lp[act].tolist(),
                                          done.tolist(), reasons.tolist())]

    def interrupt(self, uids: Optional[Sequence[int]] = None) -> List[int]:
        sel = self.slots.select(uids)
        out = [int(u) for u in self.slots.uid[sel]]
        self.slots.release(sel)
        return out
