"""Slot-based JAX rollout engine.

TPU adaptation of the paper's SGLang/CUDA-graph setup: a *fixed* slot count
means the jitted ``decode_step`` has one static shape — the XLA analogue of
graph capture.  Oversubscription (the controller refilling slots every
step) keeps the engine at its saturation batch; early termination frees
slots at harvest boundaries.  Inactive slots decode garbage that is masked
out — exactly the padding waste the bubble ratio (Eq. 4) measures.

Weight sync is O(1): the engine reads params through a callback, so the
trainer's latest state is always visible (colocated / stage-fused setup).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffer import BufferEntry
from repro.core.engine_api import StepEvent
from repro.models.model import Model

# per-family cache batch-axis maps (see Model cache layouts)
CACHE_BATCH_AXIS = {
    "k": 1, "v": 1, "k_local": 1, "v_local": 1, "k_global": 1, "v_global": 1,
    "k_x": 1, "v_x": 1,
    "ssm_main": 2, "conv_x_main": 2, "conv_bc_main": 2, "ssm_tail": 1,
    "conv_x_tail": 1, "conv_bc_tail": 1,
    "attn_k": 1, "attn_v": 1,
    "mlstm_C": 1, "mlstm_n": 1, "mlstm_conv": 1,
    "slstm_c": 1, "slstm_n": 1, "slstm_h": 1, "slstm_m": 1,
}


def cache_put(cache: Dict[str, jnp.ndarray], sub: Dict[str, jnp.ndarray],
              slots: np.ndarray) -> Dict[str, jnp.ndarray]:
    """Write per-slot sub-cache (batch k) into the engine cache at `slots`."""
    out = {}
    for name, arr in cache.items():
        ax = CACHE_BATCH_AXIS[name]
        sl = sub[name]
        idx = (slice(None),) * ax + (slots,)
        out[name] = arr.at[idx].set(sl.astype(arr.dtype))
    return out


class SlotEngine:
    def __init__(self, model: Model, params_fn: Callable[[], Dict],
                 capacity: int, max_total_len: int, max_gen_len: int,
                 eos_id: int, pad_id: int = 0, temperature: float = 1.0,
                 seed: int = 0):
        self.model = model
        self.params_fn = params_fn
        self.capacity = capacity
        self.max_total_len = max_total_len
        self.max_gen_len = max_gen_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)
        self._t0 = time.monotonic()
        self.version = 0

        # host-side slot state
        self.slot_uid = np.full(capacity, -1, np.int64)
        self.slot_active = np.zeros(capacity, bool)
        self.slot_next_token = np.zeros(capacity, np.int32)
        self.slot_kv_len = np.zeros(capacity, np.int32)
        self.slot_kv_start = np.zeros(capacity, np.int32)
        self.slot_gen_count = np.zeros(capacity, np.int32)
        self.slot_gen_budget = np.zeros(capacity, np.int32)

        self.cache = model.init_cache(capacity, max_total_len)
        self._decode_jit = jax.jit(self._decode_fn)
        self._prefill_cache: Dict[int, Callable] = {}

    # -- time ---------------------------------------------------------------

    @property
    def clock(self) -> float:
        return time.monotonic() - self._t0

    # -- slot queries ---------------------------------------------------------

    def free_slots(self) -> int:
        return int((~self.slot_active).sum())

    def active_uids(self) -> List[int]:
        return [int(u) for u in self.slot_uid[self.slot_active]]

    def sync_weights(self, version: int) -> None:
        self.version = version   # params_fn always reads the latest state

    # -- submit: batched prefill of new entries into free slots ---------------

    def submit(self, entries: Sequence[BufferEntry], version: int) -> None:
        if not entries:
            return
        free = np.flatnonzero(~self.slot_active)
        assert len(entries) <= len(free), "not enough free slots"
        slots = free[:len(entries)]
        params = self.params_fn()

        seqs = [list(e.prompt) + list(e.generated) for e in entries]
        # prefill everything but the last token; it is fed on the next step
        pre = [s[:-1] for s in seqs]
        width = max(1, max(len(p) for p in pre))
        k = len(entries)
        toks = np.full((k, width), self.pad_id, np.int32)
        plens = np.zeros(k, np.int32)
        for i, p in enumerate(pre):
            plens[i] = len(p)
            if self.model.padding_side == "right":
                toks[i, :len(p)] = p
            else:
                toks[i, width - len(p):] = p

        batch = {"tokens": jnp.asarray(toks), "prompt_lens": jnp.asarray(plens)}
        self._add_stub_inputs(batch, k)
        sub_cache = self.model.init_cache(k, self.max_total_len)
        _, sub_cache = self._prefill(params, batch, sub_cache, width)
        self.cache = cache_put(self.cache, sub_cache, slots)

        for i, (slot, e) in enumerate(zip(slots, entries)):
            self.slot_uid[slot] = e.uid
            self.slot_active[slot] = True
            self.slot_next_token[slot] = seqs[i][-1]
            if self.model.padding_side == "right":
                self.slot_kv_len[slot] = plens[i] + self.model.prefill_extra
                self.slot_kv_start[slot] = 0
            else:
                self.slot_kv_len[slot] = width
                self.slot_kv_start[slot] = width - plens[i]
            self.slot_gen_count[slot] = len(e.generated)
            self.slot_gen_budget[slot] = self.max_gen_len

    def _add_stub_inputs(self, batch: Dict, k: int) -> None:
        cfg = self.model.cfg
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (k, cfg.num_stub_positions, cfg.d_model), cfg.compute_dtype)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (k, cfg.num_stub_positions, cfg.d_model), cfg.compute_dtype)

    def _prefill(self, params, batch, cache, width):
        fn = self._prefill_cache.get((width, batch["tokens"].shape[0]))
        if fn is None:
            fn = jax.jit(self.model.prefill)
            self._prefill_cache[(width, batch["tokens"].shape[0])] = fn
        return fn(params, batch, cache)

    # -- decode ---------------------------------------------------------------

    def _decode_fn(self, params, token, cache, kv_len, kv_start, key):
        logits, cache = self.model.decode_step(params, token, cache, kv_len,
                                               kv_start=kv_start)
        logits = logits.astype(jnp.float32)
        if self.temperature > 0:
            sampled = jax.random.categorical(key, logits / self.temperature,
                                             axis=-1)
        else:
            sampled = jnp.argmax(logits, axis=-1)
        logprobs = jax.nn.log_softmax(logits, axis=-1)
        lp = jnp.take_along_axis(logprobs, sampled[:, None], axis=1)[:, 0]
        return sampled.astype(jnp.int32), lp, cache

    def step(self) -> List[StepEvent]:
        if not self.slot_active.any():
            return []
        params = self.params_fn()
        self._key, sub = jax.random.split(self._key)
        kv_len = np.where(self.slot_active, self.slot_kv_len, 0)
        sampled, lp, self.cache = self._decode_jit(
            params, jnp.asarray(self.slot_next_token), self.cache,
            jnp.asarray(kv_len.astype(np.int32)),
            jnp.asarray(self.slot_kv_start), sub)
        sampled = np.asarray(sampled)
        lp = np.asarray(lp)
        events: List[StepEvent] = []
        for slot in np.flatnonzero(self.slot_active):
            self.slot_kv_len[slot] += 1
            self.slot_gen_count[slot] += 1
            tok = int(sampled[slot])
            done, reason = False, None
            if tok == self.eos_id:
                done, reason = True, "eos"
            elif (self.slot_gen_count[slot] >= self.slot_gen_budget[slot]
                  or self.slot_kv_len[slot] >= self.max_total_len - 1):
                done, reason = True, "length"
            events.append(StepEvent(uid=int(self.slot_uid[slot]), token=tok,
                                    logprob=float(lp[slot]), done=done,
                                    finish_reason=reason))
            if done:
                self._free(slot)
            else:
                self.slot_next_token[slot] = tok
        return events

    def _free(self, slot: int) -> None:
        self.slot_active[slot] = False
        self.slot_uid[slot] = -1

    def interrupt(self, uids: Optional[Sequence[int]] = None) -> List[int]:
        out = []
        for slot in np.flatnonzero(self.slot_active):
            uid = int(self.slot_uid[slot])
            if uids is None or uid in uids:
                out.append(uid)
                self._free(slot)
        return out
