"""Slot-based JAX rollout engine.

TPU adaptation of the paper's SGLang/CUDA-graph setup: a *fixed* slot count
means the jitted ``decode_step`` has one static shape — the XLA analogue of
graph capture.  Oversubscription (the controller refilling slots every
step) keeps the engine at its saturation batch; early termination frees
slots at harvest boundaries.  Inactive slots decode garbage that is masked
out — exactly the padding waste the bubble ratio (Eq. 4) measures.

Weight sync is O(1): the engine reads params through a callback, so the
trainer's latest state is always visible (colocated / stage-fused setup).

Memory model
------------
For standard right-padding attention caches (keys ``{"k", "v"}``) the
engine is **paged**: physical KV storage is a pool of fixed-size pages
``(L, num_pages, page_size, Kh, D)`` and each sequence owns a refcounted
page table (:mod:`repro.core.kv_cache`).  This buys what a dense
``capacity x max_total_len`` cache cannot:

* **GRPO prefix sharing** — entries submitted with an identical prefill
  prefix (group members share one prompt) prefill ONCE; the other G-1
  members map the same prefix pages.  Divergence is copy-on-write at the
  page written by decode.
* **Resume without re-prefill** — interrupted sequences keep their pages
  resident, so a scavenged ``partial``-mode entry (or the prompt of an
  on-policy re-roll) resumes by remapping pages instead of re-running
  prefill.
The decode step materialises a dense per-slot view by gathering pages
through the block tables (bucketed to a power-of-two table width), runs
the model's unchanged ``decode_step`` on it, and scatters each slot's
written page back.  The TPU-ready decode attention that reads pages
*without* the gather is
``kernels/ragged_decode_attention.paged_decode_attention`` (block tables
as scalar-prefetch operands); it is validated cell-for-cell against the
same gather view (``kernels/ref.gather_pages``) and is the drop-in for
the model's attention layer when deploying on hardware — the engine's
gather path stays as the CPU/test oracle.

Families with exotic cache layouts (ssm/hybrid state, local/global ring
buffers, cross-attention) fall back to the dense layout (``paged=False``).

Hot-path notes
--------------
* ``step()`` stays loop-free on the host for slot bookkeeping: EOS/budget
  masking, event construction, and slot retirement are numpy array ops
  over the :class:`SlotTable`.  Page bookkeeping (COW planning, block
  tables, committed-token appends) is O(active) python per step inside
  :class:`~repro.core.kv_cache.PagedKVCache` — same order as event
  construction, and small next to the device step.
* Prefill shapes are bucketed — width to the next power of two (clamped
  to ``max_total_len``) and batch to the next power of two (clamped to
  ``capacity``) — so ``_prefill_cache`` holds at most
  O(log max_total_len · log capacity) compiled functions.  The paged
  decode compiles one variant per power-of-two block-table width,
  bounded by O(log pages_per_seq).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffer import BufferEntry
from repro.core.engine_api import SlotTable, StepEvent
from repro.core.kv_cache import PagedKVCache, PoolExhausted
from repro.models.model import Model

# per-family cache batch-axis maps (see Model cache layouts)
CACHE_BATCH_AXIS = {
    "k": 1, "v": 1, "k_local": 1, "v_local": 1, "k_global": 1, "v_global": 1,
    "k_x": 1, "v_x": 1,
    "ssm_main": 2, "conv_x_main": 2, "conv_bc_main": 2, "ssm_tail": 1,
    "conv_x_tail": 1, "conv_bc_tail": 1,
    "attn_k": 1, "attn_v": 1,
    "mlstm_C": 1, "mlstm_n": 1, "mlstm_conv": 1,
    "slstm_c": 1, "slstm_n": 1, "slstm_h": 1, "slstm_m": 1,
}

DEFAULT_PAGE_SIZE = 16


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << (n - 1).bit_length()


def cache_put(cache: Dict[str, jnp.ndarray], sub: Dict[str, jnp.ndarray],
              slots: np.ndarray) -> Dict[str, jnp.ndarray]:
    """Write per-slot sub-cache into the engine cache at `slots`.

    The sub-cache batch may be padded past ``len(slots)`` (batch-bucketed
    prefill); only the first ``len(slots)`` rows are real and written.
    """
    out = {}
    k = len(slots)
    for name, arr in cache.items():
        ax = CACHE_BATCH_AXIS[name]
        sl = sub[name]
        if sl.shape[ax] != k:
            sl = jax.lax.slice_in_dim(sl, 0, k, axis=ax)
        idx = (slice(None),) * ax + (slots,)
        out[name] = arr.at[idx].set(sl.astype(arr.dtype))
    return out


def supports_paging(model: Model) -> bool:
    """Paged layout needs right padding and a plain {k, v} cache."""
    if model.padding_side != "right":
        return False
    shapes = jax.eval_shape(lambda: model.init_cache(1, 1))
    return set(shapes) == {"k", "v"}


class SlotEngine:
    def __init__(self, model: Model, params_fn: Callable[[], Dict],
                 capacity: int, max_total_len: int, max_gen_len: int,
                 eos_id: int, pad_id: int = 0, temperature: float = 1.0,
                 seed: int = 0, paged: Optional[bool] = None,
                 page_size: int = DEFAULT_PAGE_SIZE,
                 num_pages: Optional[int] = None,
                 kv_retain_across_sync: bool = True,
                 packed_prefill: bool = False,
                 fused_sampling: bool = False,
                 kv_quant: Optional[str] = None):
        self.model = model
        self.params_fn = params_fn
        self.capacity = capacity
        self.max_total_len = max_total_len
        self.max_gen_len = max_gen_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)
        self._t0 = time.monotonic()
        self.version = 0

        if paged is None:
            paged = supports_paging(model)
        elif paged:
            assert supports_paging(model), \
                "paged KV cache requires right padding and a {k, v} cache"
        self.paged = paged
        assert kv_quant in (None, "int8"), kv_quant
        self.kv_quant = kv_quant
        if kv_quant:
            assert paged, "kv_quant requires the paged layout"
        self.packed_prefill = packed_prefill
        if packed_prefill:
            assert paged and model.prefill_packed is not None \
                and model.prefill_extra == 0, \
                "packed_prefill requires a paged engine, a family with " \
                "segment-masked prefill, and no stub frontend rows"
        self.fused_sampling = fused_sampling
        if fused_sampling:
            assert paged, "fused_sampling requires the paged layout"
        self.prefill_launches = 0       # one per prefill kernel launch
        self.slots = SlotTable(capacity)
        if paged:
            self.page_size = page_size
            self._pages_per_seq = -(-max_total_len // page_size)
            # default: dense-equivalent capacity + COW headroom + garbage
            self.num_pages = num_pages or (
                capacity * self._pages_per_seq + capacity + 1)
            self.cache = model.init_cache(self.num_pages, page_size)
            if kv_quant == "int8":
                # quantized page pool: int8 storage + one f32 scale per
                # (layer, page) plane — ~4x (f32) / ~2x (bf16) the token
                # capacity at equal bytes
                nl = self.cache["k"].shape[0]
                self.cache = {name: jnp.zeros(arr.shape, jnp.int8)
                              for name, arr in self.cache.items()}
                self.kv_scales = {
                    "k": jnp.ones((nl, self.num_pages), jnp.float32),
                    "v": jnp.ones((nl, self.num_pages), jnp.float32)}
            else:
                self.kv_scales = {}
            # retain=True keeps resident/shared KV across weight syncs
            # (PipelineRL/APRIL approximation, counted in stale_kv_reuses);
            # retain=False restores dense fresh-prefill-after-update
            # semantics — use it for on-policy re-rolls (see rl/session.py)
            self.kv = PagedKVCache(self.num_pages, page_size,
                                   extra_rows=model.prefill_extra,
                                   retain_across_sync=kv_retain_across_sync)
            self._paged_decode_cache: Dict[Tuple, Callable] = {}
        else:
            self.kv_scales = {}
            self.cache = model.init_cache(capacity, max_total_len)
            self.kv = None
            self._decode_jit = jax.jit(self._decode_fn)
        # int8 and fp cache configs must not collide on a (width, batch)
        # bucket — the KV dtype is part of every compile-cache key
        self._kv_dtype_key = kv_quant or jnp.dtype(
            model.cfg.compute_dtype).name
        self._prefill_cache: Dict[Tuple, Callable] = {}

    # -- time ---------------------------------------------------------------

    @property
    def clock(self) -> float:
        return time.monotonic() - self._t0

    # -- slot queries ---------------------------------------------------------

    def free_slots(self) -> int:
        return self.slots.free_count()

    def active_uids(self) -> List[int]:
        return self.slots.active_uids()

    def sync_weights(self, version: int) -> None:
        if self.paged:
            self.kv.sync_version(version)
        self.version = version   # params_fn always reads the latest state

    def cache_stats(self) -> Optional[Dict[str, float]]:
        """Page-pool gauges + prefix-sharing counters (None when dense)."""
        if not self.paged:
            return None
        d = self.kv.stats_dict()
        d["prefill_launches"] = float(self.prefill_launches)
        return d

    # -- submit: batched prefill of new entries into free slots ---------------

    def submit(self, entries: Sequence[BufferEntry], version: int) -> None:
        if not entries:
            return
        slots = self.slots.allocate(len(entries))
        seqs = [list(e.prompt) + list(e.generated) for e in entries]
        # prefill everything but the last token; it is fed on the next step
        pre = [s[:-1] for s in seqs]
        if self.paged:
            self._submit_paged(entries, slots, seqs, pre)
        else:
            self._submit_dense(entries, slots, seqs, pre)

    def _submit_dense(self, entries, slots, seqs, pre) -> None:
        k = len(entries)
        params = self.params_fn()
        width = self._bucket_width(max(1, max(len(p) for p in pre)))
        kb = self._bucket_batch(k)
        toks = np.full((kb, width), self.pad_id, np.int32)
        plens = np.zeros(kb, np.int32)
        for i, p in enumerate(pre):
            plens[i] = len(p)
            if self.model.padding_side == "right":
                toks[i, :len(p)] = p
            else:
                toks[i, width - len(p):] = p

        batch = {"tokens": jnp.asarray(toks), "prompt_lens": jnp.asarray(plens)}
        self._add_stub_inputs(batch, kb)
        sub_cache = self.model.init_cache(kb, self.max_total_len)
        _, sub_cache = self._prefill(params, batch, sub_cache, width, kb)
        self.cache = cache_put(self.cache, sub_cache, slots)

        t = self.slots
        t.uid[slots] = [e.uid for e in entries]
        t.active[slots] = True
        t.next_token[slots] = [s[-1] for s in seqs]
        if self.model.padding_side == "right":
            t.kv_len[slots] = plens[:k] + self.model.prefill_extra
            t.kv_start[slots] = 0
        else:
            t.kv_len[slots] = width
            t.kv_start[slots] = width - plens[:k]
        t.gen_count[slots] = [len(e.generated) for e in entries]
        t.gen_budget[slots] = self.max_gen_len

    def _submit_paged(self, entries, slots, seqs, pre) -> None:
        """Prefill only unique, non-resident prefixes; map everyone else
        onto existing pages (prefix sharing / resume-without-reprefill)."""
        kv = self.kv
        leaders: List[int] = []
        followers: List[Tuple[int, int]] = []   # (idx, leader idx)
        key_leader: Dict[Tuple[int, ...], int] = {}
        for i, e in enumerate(entries):
            key = tuple(pre[i])
            if kv.try_resume(e.uid, key):
                continue                        # pages still resident
            donor = kv.find_donor(key)
            if donor is not None:
                kv.share(e.uid, donor, key)     # cross-batch sharing
                continue
            li = key_leader.get(key)
            if li is None:
                key_leader[key] = i
                leaders.append(i)
            else:
                followers.append((i, li))       # in-batch sharing
        if leaders:
            self._prefill_to_pages([entries[i] for i in leaders],
                                   [pre[i] for i in leaders])
        for i, li in followers:
            kv.share(entries[i].uid, entries[li].uid, tuple(pre[i]))

        t = self.slots
        extra = self.model.prefill_extra
        t.uid[slots] = [e.uid for e in entries]
        t.active[slots] = True
        t.next_token[slots] = [s[-1] for s in seqs]
        t.kv_len[slots] = [len(p) + extra for p in pre]
        t.kv_start[slots] = 0
        t.gen_count[slots] = [len(e.generated) for e in entries]
        t.gen_budget[slots] = self.max_gen_len

    def _prefill_to_pages(self, entries, pres) -> None:
        """Run prefill over the unique prefixes and scatter the resulting
        KV rows into freshly allocated pages.  Default path: one bucketed
        dense launch per batch; with ``packed_prefill`` the prefixes are
        concatenated into rows (segment-masked attention), so one launch
        covers the whole fill wave without per-prompt padding waste."""
        if self.packed_prefill:
            self._prefill_to_pages_packed(entries, pres)
            return
        params = self.params_fn()
        P = self.page_size
        extra = self.model.prefill_extra
        width = self._bucket_width(max(1, max(len(p) for p in pres)))
        kb = self._bucket_batch(len(entries))
        cache_len = -(-(width + extra) // P) * P
        toks = np.full((kb, width), self.pad_id, np.int32)
        plens = np.zeros(kb, np.int32)
        for i, p in enumerate(pres):
            plens[i] = len(p)
            toks[i, :len(p)] = p                # paged => right padding
        batch = {"tokens": jnp.asarray(toks), "prompt_lens": jnp.asarray(plens)}
        self._add_stub_inputs(batch, kb)
        sub_cache = self.model.init_cache(kb, cache_len)
        _, sub_cache = self._prefill(params, batch, sub_cache, width, kb)

        rows, blks, phys = [], [], []
        for i, (e, p) in enumerate(zip(entries, pres)):
            table = self.kv.register_prefill(e.uid, tuple(p))
            for j, page in enumerate(table):
                rows.append(i)
                blks.append(j)
                phys.append(page)
        self._scatter_pages(sub_cache, np.asarray(rows), np.asarray(blks),
                            np.asarray(phys))

    def _prefill_to_pages_packed(self, entries, pres) -> None:
        """Packed ragged prefill: bin-pack page-aligned prefix spans into
        a few rows of concatenated segments and run ONE segment-masked
        launch for the whole wave.

        Each prefix occupies ``ceil(len/P)*P`` columns (page-aligned so
        its KV pages are whole row blocks); first-fit-decreasing packing
        into ``max_total_len``-column rows, then the usual pow2 width /
        batch bucketing on the packed shape.  Attention is masked by
        segment id and positions restart per segment, so the KV written
        for each prefix is identical to a solo prefill of that prefix.
        """
        params = self.params_fn()
        P = self.page_size
        span = [-(-max(len(p), 1) // P) * P for p in pres]
        order = sorted(range(len(pres)), key=lambda i: -span[i])
        row_of = [0] * len(pres)
        offset = [0] * len(pres)
        fill: List[int] = []                    # columns used per row
        for i in order:
            for r, used in enumerate(fill):
                if used + span[i] <= self.max_total_len:
                    row_of[i], offset[i] = r, used
                    fill[r] = used + span[i]
                    break
            else:
                row_of[i], offset[i] = len(fill), 0
                fill.append(span[i])
        width = self._bucket_width(max(fill))
        kb = self._bucket_batch(len(fill))
        cache_len = -(-width // P) * P

        toks = np.full((kb, width), self.pad_id, np.int32)
        seg = np.full((kb, width), -1, np.int32)
        pos = np.zeros((kb, width), np.int32)
        plens = np.zeros(kb, np.int32)
        for i, p in enumerate(pres):
            r, o = row_of[i], offset[i]
            toks[r, o:o + len(p)] = p
            seg[r, o:o + span[i]] = i           # pad tail shares the segment
            pos[r, o:o + span[i]] = np.arange(span[i])
            plens[r] = max(plens[r], o + len(p))
        batch = {"tokens": jnp.asarray(toks),
                 "prompt_lens": jnp.asarray(plens),
                 "seg_ids": jnp.asarray(seg),
                 "positions": jnp.asarray(pos)}
        sub_cache = self.model.init_cache(kb, cache_len)
        key = ("packed", width, kb, self._kv_dtype_key)
        fn = self._prefill_cache.get(key)
        if fn is None:
            fn = jax.jit(self.model.prefill_packed)
            self._prefill_cache[key] = fn
        _, sub_cache = fn(params, batch, sub_cache)
        self.prefill_launches += 1

        rows, blks, phys = [], [], []
        for i, (e, p) in enumerate(zip(entries, pres)):
            table = self.kv.register_prefill(e.uid, tuple(p))
            for j, page in enumerate(table):
                rows.append(row_of[i])
                blks.append(offset[i] // P + j)
                phys.append(page)
        self._scatter_pages(sub_cache, np.asarray(rows), np.asarray(blks),
                            np.asarray(phys))

    def _scatter_pages(self, sub_cache, rows, blks, phys) -> None:
        """Scatter prefilled KV page blocks into the pool at ``phys``
        (quantizing per page when the pool is int8)."""
        P = self.page_size
        cache = dict(self.cache)
        scales = dict(self.kv_scales)
        for name in ("k", "v"):
            sub = sub_cache[name]               # (L, kb, cache_len, Kh, D)
            nl, nb_, ns = sub.shape[:3]
            blocks = sub.reshape(nl, nb_, ns // P, P, *sub.shape[3:])
            sel = blocks[:, rows, blks]         # (L, n_pages, P, Kh, D)
            if self.kv_quant == "int8":
                sel = sel.astype(jnp.float32)
                amax = jnp.max(jnp.abs(sel), axis=(2, 3, 4))
                s = jnp.maximum(amax, 1e-8) / 127.0
                q = jnp.clip(jnp.round(sel / s[:, :, None, None, None]),
                             -127, 127).astype(jnp.int8)
                cache[name] = cache[name].at[:, phys].set(q)
                scales[name] = scales[name].at[:, phys].set(s)
            else:
                cache[name] = cache[name].at[:, phys].set(
                    sel.astype(cache[name].dtype))
        self.cache = cache
        self.kv_scales = scales

    def _add_stub_inputs(self, batch: Dict, k: int) -> None:
        cfg = self.model.cfg
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (k, cfg.num_stub_positions, cfg.d_model), cfg.compute_dtype)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (k, cfg.num_stub_positions, cfg.d_model), cfg.compute_dtype)

    # -- prefill shape bucketing ----------------------------------------------

    def _bucket_width(self, width: int) -> int:
        assert width <= self.max_total_len, (width, self.max_total_len)
        if self.model.padding_side == "right":
            # padded positions beyond prompt_lens are masked via kv_len, so
            # inflating the width is free
            return min(next_pow2(width), self.max_total_len)
        # left padding: valid tokens END at the bucketed width, so kv_len =
        # width and every padded column eats generation headroom out of the
        # fixed cache.  Bucket only while the full gen budget still fits;
        # past that, fall back to the exact width (seed behaviour).
        safe = self.max_total_len - self.max_gen_len - 1
        return max(width, min(next_pow2(width), max(safe, 1)))

    def _bucket_batch(self, k: int) -> int:
        return min(next_pow2(k), self.capacity)

    def _prefill(self, params, batch, cache, width, kb):
        key = (width, kb, self._kv_dtype_key)
        fn = self._prefill_cache.get(key)
        if fn is None:
            fn = jax.jit(self.model.prefill)
            self._prefill_cache[key] = fn
        self.prefill_launches += 1
        return fn(params, batch, cache)

    # -- decode ---------------------------------------------------------------

    def _sample(self, logits, key):
        logits = logits.astype(jnp.float32)
        if self.temperature > 0:
            sampled = jax.random.categorical(key, logits / self.temperature,
                                             axis=-1)
        else:
            sampled = jnp.argmax(logits, axis=-1)
        logprobs = jax.nn.log_softmax(logits, axis=-1)
        lp = jnp.take_along_axis(logprobs, sampled[:, None], axis=1)[:, 0]
        return sampled.astype(jnp.int32), lp

    def _decode_fn(self, params, token, cache, kv_len, kv_start, key):
        logits, cache = self.model.decode_step(params, token, cache, kv_len,
                                               kv_start=kv_start)
        sampled, lp = self._sample(logits, key)
        return sampled, lp, cache

    def _fused_greedy(self, params, hidden):
        """Fused greedy LM head: the token and its logprob come straight
        out of max / logsumexp reductions over the logits — no (B, V)
        log-softmax materialisation, no gather, and no variadic argmax
        reduce (the dominant cost of the two-pass path on CPU; on TPU the
        Pallas drop-in ``kernels.ops.fused_sample`` additionally streams
        the matmul so the (B, V) logits never round-trip through HBM).
        First-index-at-max reproduces argmax's tie-break, so tokens are
        bit-identical to the two-pass path."""
        cfg = self.model.cfg
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"]).astype(cfg.compute_dtype)
        logits = jnp.einsum("bd,dv->bv", hidden, w).astype(jnp.float32)
        if cfg.logit_softcap > 0:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
        v = logits.shape[1]
        m = jnp.max(logits, axis=-1)
        iota = jnp.arange(v)
        idx = jnp.min(jnp.where(logits == m[:, None], iota[None, :], v),
                      axis=-1)
        lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1))
        return idx.astype(jnp.int32), m - lse

    def _paged_decode_fn(self, params, token, cache, scales, bt, kv_len,
                         key):
        """One decode step over the page pool.

        Gathers a dense per-slot view through the block tables (the CPU
        analogue of the paged Pallas kernel's block-table reads), runs the
        model's decode step on it, then scatters each slot's written page
        back.  Host-side COW (``prepare_step``) guarantees write pages are
        exclusively owned, so the scatter indices never collide except on
        the shared garbage page of inactive slots.

        int8 pools dequantize on gather (per-page scales — the CPU
        analogue of the scalar-prefetched scales in
        ``kernels.ops.paged_decode_attention_int8``) and requantize the
        written page on scatter with a monotone-nondecreasing scale, so a
        page whose amax did not grow round-trips its old cells exactly.
        """
        P = self.page_size
        B, nb = bt.shape
        quant = self.kv_quant == "int8"

        def gather(pages, sc):
            g = jnp.take(pages, bt.reshape(-1), axis=1)
            g = g.reshape(pages.shape[0], B, nb, P, *pages.shape[3:])
            if quant:
                s = jnp.take(sc, bt.reshape(-1), axis=1)
                g = g.astype(jnp.float32) * s.reshape(
                    pages.shape[0], B, nb)[..., None, None, None]
                g = g.astype(self.model.cfg.compute_dtype)
            return g.reshape(pages.shape[0], B, nb * P, *pages.shape[3:])

        view = {"k": gather(cache["k"], scales.get("k")),
                "v": gather(cache["v"], scales.get("v"))}
        if self.fused_sampling and self.temperature == 0:
            hidden, view = self.model.decode_step(params, token, view,
                                                  kv_len, return_hidden=True)
            sampled, lp = self._fused_greedy(params, hidden)
        else:
            logits, view = self.model.decode_step(params, token, view,
                                                  kv_len)
            sampled, lp = self._sample(logits, key)
        blk = kv_len // P

        def take_page(x, b):                    # x: (L, S, Kh, D) one slot
            return jax.lax.dynamic_slice_in_dim(x, b * P, P, axis=1)

        k_new = jax.vmap(take_page, in_axes=(1, 0), out_axes=1)(view["k"], blk)
        v_new = jax.vmap(take_page, in_axes=(1, 0), out_axes=1)(view["v"], blk)
        phys = jnp.take_along_axis(bt, blk[:, None], axis=1)[:, 0]
        if quant:
            for name, new in (("k", k_new), ("v", v_new)):
                new = new.astype(jnp.float32)
                amax = jnp.max(jnp.abs(new), axis=(2, 3, 4))  # (L, B)
                old = scales[name][:, phys]
                s = jnp.maximum(old, amax / 127.0)  # monotone: old cells exact
                q = jnp.clip(jnp.round(new / s[:, :, None, None, None]),
                             -127, 127).astype(jnp.int8)
                cache = dict(cache)
                cache[name] = cache[name].at[:, phys].set(q)
                scales = dict(scales)
                scales[name] = scales[name].at[:, phys].set(s)
        else:
            cache = {
                "k": cache["k"].at[:, phys].set(
                    k_new.astype(cache["k"].dtype)),
                "v": cache["v"].at[:, phys].set(
                    v_new.astype(cache["v"].dtype)),
            }
        return sampled, lp, cache, scales

    def _paged_decode(self, params, token, cache, bt, kv_len, key):
        fused = self.fused_sampling and self.temperature == 0
        cache_key = (bt.shape[1], self._kv_dtype_key, fused)
        fn = self._paged_decode_cache.get(cache_key)
        if fn is None:
            fn = jax.jit(self._paged_decode_fn)
            self._paged_decode_cache[cache_key] = fn
        return fn(params, token, cache, self.kv_scales, bt, kv_len, key)

    def _copy_pages(self, copies: List[Tuple[int, int]]) -> None:
        """Apply host-planned copy-on-write page copies on device (scale
        planes travel with their pages on a quantized pool)."""
        src = np.asarray([s for s, _ in copies])
        dst = np.asarray([d for _, d in copies])
        self.cache = {name: arr.at[:, dst].set(arr[:, src])
                      for name, arr in self.cache.items()}
        if self.kv_quant:
            self.kv_scales = {name: arr.at[:, dst].set(arr[:, src])
                              for name, arr in self.kv_scales.items()}

    def step(self) -> List[StepEvent]:
        t = self.slots
        act = t.active_indices()
        if act.size == 0:
            return []
        params = self.params_fn()
        self._key, sub = jax.random.split(self._key)
        kv_len = np.where(t.active, t.kv_len, 0).astype(np.int32)
        if self.paged:
            uids_act = t.uid[act].tolist()
            copies = self.kv.prepare_step(uids_act, t.kv_len[act].tolist())
            if copies:
                self._copy_pages(copies)
            nb = min(next_pow2(max(1, self.kv.max_blocks(uids_act))),
                     self._pages_per_seq)
            bt = jnp.asarray(self.kv.block_table(t.uid.tolist(), nb))
            sampled, lp, self.cache, self.kv_scales = self._paged_decode(
                params, jnp.asarray(t.next_token), self.cache, bt,
                jnp.asarray(kv_len), sub)
            self.kv.append_tokens(uids_act, t.next_token[act].tolist())
        else:
            sampled, lp, self.cache = self._decode_jit(
                params, jnp.asarray(t.next_token), self.cache,
                jnp.asarray(kv_len), jnp.asarray(t.kv_start), sub)
        sampled = np.asarray(sampled)
        lp = np.asarray(lp)

        # vectorized bookkeeping over the active slots (ascending order)
        t.kv_len[act] += 1
        t.gen_count[act] += 1
        toks = sampled[act]
        eos = toks == self.eos_id
        over = ((t.gen_count[act] >= t.gen_budget[act])
                | (t.kv_len[act] >= self.max_total_len - 1))
        done = eos | over
        reasons = np.where(eos, "eos", np.where(over, "length", None))

        uids = t.uid[act].tolist()          # read before batched release
        if self.paged:
            self.kv.release_many(t.uid[act[done]].tolist())
        t.release(act[done])
        cont = act[~done]
        t.next_token[cont] = toks[~done]

        return [StepEvent(uid=u, token=tk, logprob=l, done=d, finish_reason=r)
                for u, tk, l, d, r in zip(uids, toks.tolist(), lp[act].tolist(),
                                          done.tolist(), reasons.tolist())]

    def interrupt(self, uids: Optional[Sequence[int]] = None) -> List[int]:
        sel = self.slots.select(uids)
        out = [int(u) for u in self.slots.uid[sel]]
        self.slots.release(sel)
        if self.paged:
            self.kv.deactivate_many(out)   # keep pages resident for resume
        return out

    def shutdown(self) -> None:
        """Fence the engine (killed or scaled-down replica): release
        every slot and purge the page pool, so the fleet holds no live
        references to this replica.  Counters survive — the work done
        before the fence was real."""
        self.slots.release(self.slots.active_indices())
        if self.paged:
            self.kv.purge()

    # -- migration capability (EngineGroup work stealing / tail packing) ------
    #
    # A migrated entry carries its resident KV across page pools (span
    # copy on device via the page tables), so a stolen or drain-packed
    # sequence resumes on the destination replica with ZERO re-prefill.
    # The three-call shape (export -> import -> discard) keeps the donor
    # copy intact until the importer has accepted, so a failed import
    # (full pool, no free slot) falls back without losing anything.

    def export_entry(self, uid: int) -> Optional[Dict]:
        """Snapshot an in-flight slot or a resident uid — page table
        bookkeeping from :meth:`PagedKVCache.export_pages` plus the
        physical KV rows pulled off the donor pool.  None when the engine
        cannot migrate (dense layout, or no trace of the uid)."""
        if not self.paged or uid not in self.kv.tables:
            return None
        ex = self.kv.export_pages(uid)
        handle = {
            "engine": "slot", "uid": uid, "active": ex.active, "kv": ex,
            "kv_quant": self.kv_quant,
            # span copy: the donor's physical rows for ex.pages (host
            # round-trip; a multi-host deployment would DMA these)
            "pages_k": np.asarray(self.cache["k"][:, ex.pages]),
            "pages_v": np.asarray(self.cache["v"][:, ex.pages]),
        }
        if self.kv_quant:
            handle["scales_k"] = np.asarray(self.kv_scales["k"][:, ex.pages])
            handle["scales_v"] = np.asarray(self.kv_scales["v"][:, ex.pages])
        if ex.active:
            sel = np.flatnonzero((self.slots.uid == uid) & self.slots.active)
            assert sel.size == 1, (uid, sel)
            i = int(sel[0])
            t = self.slots
            handle["slot"] = {"next_token": int(t.next_token[i]),
                              "kv_len": int(t.kv_len[i]),
                              "kv_start": int(t.kv_start[i]),
                              "gen_count": int(t.gen_count[i]),
                              "gen_budget": int(t.gen_budget[i])}
        return handle

    def import_entry(self, handle: Dict) -> bool:
        """Land a migrated entry with its KV: fresh pages from this pool
        (``import_pages``), donor rows copied in, and — for an active
        entry — a slot transplanted verbatim so greedy decode continues
        token-identically.  Returns False (engine unchanged) when it
        cannot accept: dense layout, stale KV under strict sync, no free
        slot, or an exhausted pool."""
        if handle.get("engine") != "slot" or not self.paged:
            return False
        if handle.get("kv_quant") != self.kv_quant:
            return False    # int8 and fp pools do not mix page bytes
        ex = handle["kv"]
        if not self.kv.retain_across_sync and ex.version != self.kv.version:
            return False    # strict sync: pre-sync KV must not cross pools
        if ex.active and self.free_slots() <= 0:
            return False
        try:
            pages = self.kv.import_pages(ex)
        except PoolExhausted:
            return False
        cache = dict(self.cache)
        for name, rows in (("k", handle["pages_k"]), ("v", handle["pages_v"])):
            cache[name] = cache[name].at[:, pages].set(
                jnp.asarray(rows, cache[name].dtype))
        self.cache = cache
        if self.kv_quant:
            sc = dict(self.kv_scales)
            for name, rows in (("k", handle["scales_k"]),
                               ("v", handle["scales_v"])):
                sc[name] = sc[name].at[:, pages].set(jnp.asarray(rows))
            self.kv_scales = sc
        if ex.active:
            s = handle["slot"]
            slot = self.slots.allocate(1)
            t = self.slots
            t.uid[slot] = ex.uid
            t.active[slot] = True
            t.next_token[slot] = s["next_token"]
            t.kv_len[slot] = s["kv_len"]
            t.kv_start[slot] = s["kv_start"]
            t.gen_count[slot] = s["gen_count"]
            t.gen_budget[slot] = s["gen_budget"]
        return True

    def discard_entry(self, uid: int) -> None:
        """Drop every local trace of a migrated-away uid (slot + pages)."""
        sel = self.slots.select([uid])
        if sel.size:
            self.slots.release(sel)
        if self.paged:
            self.kv.release_seq(uid)
