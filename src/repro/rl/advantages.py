"""Advantage estimators (paper §2.1, Eqs. 2-3).

* Reinforce++ (Eq. 3): batch-normalised terminal reward — the estimator
  whose batch statistics make *selective batching* matter (§3.1): a
  length-sorted update batch changes mu/sigma_batch, which is part of the
  micro-curriculum effect SortedRL exploits.
* GRPO-style group normalisation (per-prompt groups).
* PPO GAE (Eq. 2) with a value head.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def reinforce_pp(rewards: jnp.ndarray, loss_mask: jnp.ndarray,
                 eps: float = 1e-6) -> jnp.ndarray:
    """rewards: (B,) terminal rewards; loss_mask: (B, S) 1.0 on generated
    tokens.  Returns per-token advantages (B, S): every generated token of
    trajectory i gets (R_i - mu_batch) / sigma_batch."""
    mu = jnp.mean(rewards)
    sigma = jnp.std(rewards)
    adv = (rewards - mu) / (sigma + eps)
    return adv[:, None] * loss_mask


def grpo(rewards: jnp.ndarray, group_ids: jnp.ndarray,
         loss_mask: jnp.ndarray, num_groups: int,
         eps: float = 1e-6) -> jnp.ndarray:
    """Group-relative normalisation: per-prompt groups of k samples."""
    onehot = jax.nn.one_hot(group_ids, num_groups)              # (B, G)
    counts = jnp.maximum(onehot.sum(0), 1.0)                    # (G,)
    mu_g = (onehot * rewards[:, None]).sum(0) / counts
    var_g = (onehot * jnp.square(rewards[:, None] - mu_g[None])).sum(0) / counts
    adv = (rewards - onehot @ mu_g) / (jnp.sqrt(onehot @ var_g) + eps)
    return adv[:, None] * loss_mask


def gae(rewards_t: jnp.ndarray, values: jnp.ndarray, loss_mask: jnp.ndarray,
        gamma: float = 1.0, lam: float = 0.95) -> jnp.ndarray:
    """PPO GAE (Eq. 2).  rewards_t: (B, S) per-token rewards (usually the
    terminal reward at the last generated token); values: (B, S+1) value
    predictions (bootstrap column appended).  Returns advantages (B, S)."""
    B, S = rewards_t.shape
    deltas = rewards_t + gamma * values[:, 1:] * loss_mask - values[:, :-1]

    def step(carry, x):
        delta, mask = x
        carry = delta + gamma * lam * mask * carry
        return carry, carry

    # scan right-to-left over time
    deltas_T = jnp.moveaxis(deltas, 1, 0)[::-1]
    mask_T = jnp.moveaxis(loss_mask, 1, 0)[::-1]
    _, adv_T = jax.lax.scan(step, jnp.zeros(B), (deltas_T, mask_T))
    adv = jnp.moveaxis(adv_T[::-1], 0, 1)
    return adv * loss_mask


def whiten(adv: jnp.ndarray, loss_mask: jnp.ndarray,
           eps: float = 1e-6) -> jnp.ndarray:
    """Masked whitening over the batch (token level)."""
    n = jnp.maximum(loss_mask.sum(), 1.0)
    mu = (adv * loss_mask).sum() / n
    var = (jnp.square(adv - mu) * loss_mask).sum() / n
    return (adv - mu) * jax.lax.rsqrt(var + eps) * loss_mask
