"""The Trainer protocol: a typed, registry-backed front for the trainer.

Historically the orchestrator took a bare ``TrainFn`` callable and ran it
inline — `train_ready` blocked rollout until the update (and the weight
sync behind it) returned, so at scale the update step became the new
bubble.  This module replaces that hand-off with a small protocol,

    trainer.submit(req, now)   # hand a batch over; never blocks rollout
    trainer.poll(now)          # outcomes whose modeled time has passed
    trainer.flush(now)         # complete everything outstanding

plus capability flags (``supports_overlap``), behind a string registry
mirroring the engine / policy / admission registries::

    trainer = make_trainer("streaming", fn=train_fn, update_cost=2.0)

Two implementations ship:

* ``"sync"``  — the classical serialized hand-off.  ``submit`` runs the
  wrapped fn immediately and the outcome's modeled completion time is
  ``now + cost``: the orchestrator charges the full update as a rollout
  stall, exactly the pre-protocol behavior.
* ``"streaming"`` — PipelineRL-style overlap.  ``submit`` enqueues the
  batch on a modeled single-stream trainer timeline (``t_start = max(now,
  busy_until)``); ``poll(now)`` completes outcomes whose ``t_done`` has
  passed, so update compute runs *concurrently* with continued rollout
  and only the un-overlapped remainder ever stalls the engine clock.

**Deprecation note — bare callables:** passing a plain
``Callable[[UpdateRequest], Optional[UpdateResult]]`` where a Trainer is
expected still works everywhere (``as_trainer`` wraps it in a zero-cost
``SyncTrainer``), but it is a compatibility shim: new call sites should
build a trainer via ``make_trainer`` so overlap, cost modeling, and
capability flags compose.

This module is deliberately jax-free (the heavy batch assembly lives in
:mod:`repro.rl.trainer`, which re-exports this API), so the orchestrator
and the sim-only tests can import it without touching jax.
"""
from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Protocol,
                    Union, runtime_checkable)

if TYPE_CHECKING:   # import cycle: orchestrator imports as_trainer lazily
    from repro.core.orchestrator import UpdateRequest, UpdateResult

# modeled seconds of trainer compute for one update batch: a constant, or
# a callable of the request (e.g. tokens-proportional)
CostSpec = Union[float, Callable[["UpdateRequest"], float]]


@dataclasses.dataclass
class TrainOutcome:
    """One completed update on the trainer timeline."""
    request: "UpdateRequest"
    result: Optional["UpdateResult"]
    t_submit: float           # when the orchestrator handed the batch over
    t_start: float            # when trainer compute began (queue delay)
    t_done: float             # when the update (incl. compute) completed
    cost: float               # modeled trainer compute seconds


@runtime_checkable
class Trainer(Protocol):
    """Capability-flagged trainer front (see module docstring)."""

    name: str
    # True when poll() may complete submissions strictly after submit()
    # returned — the orchestrator requires this for overlap mode
    supports_overlap: bool

    def submit(self, req: "UpdateRequest", now: float) -> None:
        """Accept one update batch at modeled time ``now``."""
        ...

    def poll(self, now: float) -> List[TrainOutcome]:
        """Outcomes whose modeled completion time has passed ``now``."""
        ...

    def flush(self, now: float) -> List[TrainOutcome]:
        """Complete every outstanding submission (t_done may exceed now)."""
        ...

    @property
    def pending(self) -> int:
        """Submitted-but-uncompleted batch count."""
        ...


def _resolve_cost(cost: CostSpec, req: "UpdateRequest") -> float:
    c = cost(req) if callable(cost) else cost
    if c < 0:
        raise ValueError(f"trainer update cost must be >= 0, got {c}")
    return float(c)


class SyncTrainer:
    """Serialized hand-off: the update runs inside ``submit`` and its
    whole ``cost`` lands on the rollout clock as a stall."""

    name = "sync"
    supports_overlap = False

    def __init__(self, fn: Callable, update_cost: CostSpec = 0.0,
                 update_cost_per_token: float = 0.0):
        self.fn = fn
        self.update_cost = update_cost
        self.update_cost_per_token = update_cost_per_token
        self._done: List[TrainOutcome] = []

    def _cost(self, req: "UpdateRequest") -> float:
        c = _resolve_cost(self.update_cost, req)
        if self.update_cost_per_token:
            c += self.update_cost_per_token * sum(e.gen_len
                                                  for e in req.entries)
        return c

    def submit(self, req: "UpdateRequest", now: float) -> None:
        cost = self._cost(req)
        result = self.fn(req)
        self._done.append(TrainOutcome(request=req, result=result,
                                       t_submit=now, t_start=now,
                                       t_done=now + cost, cost=cost))

    def poll(self, now: float) -> List[TrainOutcome]:
        out, self._done = self._done, []
        return out

    def flush(self, now: float) -> List[TrainOutcome]:
        return self.poll(now)

    @property
    def pending(self) -> int:
        return len(self._done)


class StreamingTrainer(SyncTrainer):
    """Overlapped hand-off on a modeled single-stream trainer timeline.

    ``submit`` only enqueues; the wrapped fn runs when ``poll`` observes
    the modeled completion time passing (or at ``flush``), so the weight
    sync behind each outcome lands mid-rollout and rollout pays only the
    part of the update that did NOT overlap."""

    name = "streaming"
    supports_overlap = True

    def __init__(self, fn: Callable, update_cost: CostSpec = 0.0,
                 update_cost_per_token: float = 0.0):
        super().__init__(fn, update_cost, update_cost_per_token)
        self._queue: List[TrainOutcome] = []
        self._busy_until = 0.0

    def submit(self, req: "UpdateRequest", now: float) -> None:
        cost = self._cost(req)
        t_start = max(now, self._busy_until)
        self._busy_until = t_start + cost
        self._queue.append(TrainOutcome(request=req, result=None,
                                        t_submit=now, t_start=t_start,
                                        t_done=t_start + cost, cost=cost))

    def _complete(self, o: TrainOutcome) -> TrainOutcome:
        o.result = self.fn(o.request)
        return o

    def poll(self, now: float) -> List[TrainOutcome]:
        out = []
        while self._queue and self._queue[0].t_done <= now:
            out.append(self._complete(self._queue.pop(0)))
        return out

    def flush(self, now: float) -> List[TrainOutcome]:
        out = [self._complete(o) for o in self._queue]
        self._queue = []
        return out

    @property
    def pending(self) -> int:
        return len(self._queue)


# ---------------------------------------------------------------------------
# registry (mirrors repro.core.policy / rollout.group / serve.tenants)
# ---------------------------------------------------------------------------

_TRAINERS: Dict[str, Callable[..., Trainer]] = {}


def register_trainer(name: str, factory: Callable[..., Trainer]) -> None:
    _TRAINERS[name] = factory


def make_trainer(name: str, **kwargs) -> Trainer:
    """Build a registered trainer by name (``"sync"`` / ``"streaming"``).

    kwargs are forwarded to the factory — typically ``fn=`` (the update
    callable), ``update_cost=`` (seconds per batch, or a callable of the
    request) and ``update_cost_per_token=``.
    """
    if name not in _TRAINERS:
        raise KeyError(f"unknown trainer {name!r}; "
                       f"registered: {available_trainers()}")
    return _TRAINERS[name](**kwargs)


def available_trainers() -> List[str]:
    return sorted(_TRAINERS)


register_trainer("sync", SyncTrainer)
register_trainer("streaming", StreamingTrainer)


def as_trainer(obj: Union[Trainer, Callable]) -> Trainer:
    """Coerce a trainer-or-callable to the Trainer protocol.

    A Trainer passes through; a bare ``TrainFn`` callable (the deprecated
    pre-protocol hand-off) is wrapped in a zero-cost :class:`SyncTrainer`,
    which reproduces the old inline-call semantics exactly.
    """
    if hasattr(obj, "submit") and hasattr(obj, "poll"):
        return obj          # already a Trainer (duck-typed on purpose)
    if not callable(obj):
        raise TypeError(f"expected a Trainer or a TrainFn callable, "
                        f"got {type(obj).__name__}")
    return SyncTrainer(obj)


__all__ = ["CostSpec", "TrainOutcome", "Trainer", "SyncTrainer",
           "StreamingTrainer", "register_trainer", "make_trainer",
           "available_trainers", "as_trainer"]
