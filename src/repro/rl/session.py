"""One-call RL session builder: task generator -> engine -> buffer ->
orchestrator -> trainer -> eval from a single declarative config.

This replaces the two near-duplicate ~140-line drivers that used to live
in ``repro.train.loop`` (``run_logic_rl`` / ``run_math_rl``, kept there as
thin wrappers).  The session is task- and policy-agnostic: tasks come from
the :data:`TASKS` registry, scheduling strategies from the
:mod:`repro.core.policy` registry, and the rollout engine is either the
real JAX :class:`~repro.rollout.engine.SlotEngine` (``engine="slot"``) or
the discrete-event :class:`~repro.rollout.sim.SimEngine`
(``engine="sim"``, scheduling-only — no model, trainer, or eval).

    from repro.rl.session import RLSession, SessionConfig
    out = RLSession.from_config(SessionConfig(task="logic",
                                              policy="sorted")).run()
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttnConfig, ModelConfig
from repro.core.buffer import BufferEntry, Mode, StatefulRolloutBuffer
from repro.core.engine_api import FaultInjector
from repro.core.orchestrator import (RolloutOrchestrator, SortedRLConfig,
                                     UpdateRequest, UpdateResult)
from repro.core.policy import make_policy
from repro.data import logic, math_synth
from repro.data.loader import GroupedLoader, TaskGenerator
from repro.data.tokenizer import Vocab
from repro.models.model import Model, build_model
from repro.rl.losses import LossConfig
from repro.rl.trainer import RLTrainer, make_trainer
from repro.rollout.engine import SlotEngine
from repro.rollout.group import EngineGroup
from repro.rollout.sim import SimEngine
from repro.train.optimizer import AdamWConfig


def tiny_lm_config(vocab_size: int, d_model: int = 128, layers: int = 4,
                   heads: int = 4) -> ModelConfig:
    return ModelConfig(
        name="tiny-lm", family="dense", num_layers=layers, d_model=d_model,
        num_heads=heads, num_kv_heads=heads, d_ff=4 * d_model,
        vocab_size=vocab_size, attn=AttnConfig(rope_theta=10_000.0),
        tie_embeddings=True, param_dtype=jnp.float32,
        compute_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# SFT warm-up (plays the role of starting from an instruct checkpoint)
# ---------------------------------------------------------------------------

def sft_warmup(model: Model, params, examples: Sequence[Tuple[List[int],
                                                              List[int]]],
               pad_id: int, steps: int = 200, batch_size: int = 32,
               lr: float = 1e-3, seed: int = 0, width: int = 96):
    from repro.train.optimizer import adamw_update, init_opt_state
    opt_cfg = AdamWConfig(lr=lr, grad_clip=1.0)
    opt_state = init_opt_state(params, opt_cfg)
    rng = np.random.RandomState(seed)

    def loss_fn(p, tokens, mask):
        logits, _ = model.forward(p, {"tokens": tokens})
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        tgt = tokens[:, 1:]
        lp_t = jnp.take_along_axis(lp[:, :-1], tgt[:, :, None], 2)[..., 0]
        m = mask[:, 1:]
        return -(lp_t * m).sum() / jnp.maximum(m.sum(), 1.0)

    @jax.jit
    def step_fn(p, o, tokens, mask):
        loss, grads = jax.value_and_grad(loss_fn)(p, tokens, mask)
        p, o, _ = adamw_update(p, grads, o, opt_cfg)
        return p, o, loss

    losses = []
    for s in range(steps):
        idx = rng.randint(0, len(examples), batch_size)
        toks = np.full((batch_size, width), pad_id, np.int32)
        mask = np.zeros((batch_size, width), np.float32)
        for i, j in enumerate(idx):
            prompt, target = examples[j]
            seq = (prompt + target)[:width]
            toks[i, :len(seq)] = seq
            mask[i, len(prompt):len(seq)] = 1.0
        params, opt_state, loss = step_fn(params, opt_state,
                                          jnp.asarray(toks),
                                          jnp.asarray(mask))
        losses.append(float(loss))
    return params, losses


# ---------------------------------------------------------------------------
# Evaluation: greedy decode through the engine
# ---------------------------------------------------------------------------

def evaluate(model: Model, params, vocab: Vocab, prompts, metas,
             reward_fn, max_gen: int = 24, max_total: int = 128) -> Dict:
    eng = SlotEngine(model, lambda: params, capacity=len(prompts),
                     max_total_len=max_total, max_gen_len=max_gen,
                     eos_id=vocab.eos_id, pad_id=vocab.pad_id,
                     temperature=0.0)
    entries = [BufferEntry(uid=i, prompt=list(p), meta=m)
               for i, (p, m) in enumerate(zip(prompts, metas))]
    eng.submit(entries, version=0)
    gen: Dict[int, List[int]] = {e.uid: [] for e in entries}
    while eng.active_uids():
        for ev in eng.step():
            gen[ev.uid].append(ev.token)
    rewards = [reward_fn(gen[e.uid], e.meta) for e in entries]
    return {
        "reward_mean": float(np.mean(rewards)),
        "solve_rate": float(np.mean([r >= 1.2 for r in rewards])),
        "gen_len_mean": float(np.mean([len(g) for g in gen.values()])),
    }


# ---------------------------------------------------------------------------
# task registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """A verifiable task: vocab + generator factory + rule-based verifier."""
    vocab: Vocab
    make_generator: Callable[[int], TaskGenerator]
    verify: Callable[[Sequence[int], Any, Vocab], float]
    sft_width: int        # warm-up padding width (task-shaped)


TASKS: Dict[str, TaskSpec] = {
    "logic": TaskSpec(logic.VOCAB,
                      lambda seed: logic.LogicTaskGenerator(seed=seed),
                      logic.verify, sft_width=96),
    "math": TaskSpec(math_synth.MATH_VOCAB,
                     lambda seed: math_synth.MathTaskGenerator(seed=seed),
                     math_synth.verify, sft_width=64),
}


# ---------------------------------------------------------------------------
# session config + builder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SessionConfig:
    """Declarative description of a full RL run."""
    task: str = "logic"               # TASKS registry key
    policy: str = "sorted"            # scheduling-policy registry key
    policy_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    engine: str = "slot"              # slot (real decode) | sim (scheduling)
    # data-parallel rollout: shard rollout_batch slots over this many
    # engine replicas behind an EngineGroup (1 = plain single engine)
    num_replicas: int = 1
    balancer: str = "least_tokens"    # EngineGroup routing (group.py registry)
    async_step: bool = False          # per-replica dispatch, no step barrier
    drain_pack: bool = False          # tail packing via KV migration
    # chaos / elasticity: a deterministic fault plan the EngineGroup
    # applies per group step — FaultEvent instances or plain tuples
    # (step, replica, kind[, duration[, factor]]); requires
    # num_replicas > 1 (faults are injected per replica)
    fault_plan: Optional[List[Any]] = None
    elastic: bool = False             # enable scale_up / scale_down
    # feedback-driven autoscaling (repro.rollout.autoscaler): name an
    # AutoscalerPolicy ("bubble_target" | "queue_depth") and the session
    # builds an elastic EngineGroup plus an Autoscaler controller that
    # drives scale_down/scale_up from windowed metrics each group step.
    # scale_up mints warm replicas through the same per-replica builder
    # (rollout_batch // num_replicas slots each, synced to the group's
    # weight version); max_replicas=None caps the fleet at its starting
    # size (shed-and-regrow only — growth beyond it is opt-in).
    autoscaler: Optional[str] = None
    autoscaler_kwargs: Dict[str, Any] = dataclasses.field(
        default_factory=dict)         # policy knobs (high/low marks, ...)
    autoscaler_window: float = 1.0    # metrics window span, sim seconds
    min_replicas: int = 1             # never shed below this
    max_replicas: Optional[int] = None  # never grow above this
    # kernel/memory roofline knobs (see README §Kernel & memory roofline):
    # packed segment-masked prefill (one launch per fill wave), in-kernel
    # greedy sampling (no (B, V) logits round-trip at temperature 0), and
    # int8 KV pages (None | "int8"; ~2-4x pool capacity at equal bytes)
    packed_prefill: bool = False
    fused_sampling: bool = False
    kv_quant: Optional[str] = None
    mode: Mode = Mode.ON_POLICY
    rollout_batch: int = 32           # engine capacity (slots)
    group_size: int = 2
    update_batch: int = 32
    max_gen_len: int = 24
    max_total_len: int = 160
    n_groups: int = 4
    sft_steps: int = 150
    lr: float = 3e-4
    temperature: float = 1.0
    seed: int = 0
    d_model: int = 128
    layers: int = 4
    eval_every: int = 4               # updates between evals
    eval_size: int = 64
    # paper LogicRL setting: k responses per prompt (duplicated entries
    # sharing prompt_id -> grpo groups or reinforce++ batch stats)
    responses_per_prompt: int = 1
    advantage_kind: str = "reinforce_pp"   # reinforce_pp | grpo
    harvest_threshold: Optional[int] = None
    train_leftover: bool = True
    # trainer hand-off: "sync" (serialized, the classical behavior) or
    # "streaming" (rollout/update overlap — set overlap_updates too).
    # update_cost models the trainer's per-batch compute seconds on the
    # rollout clock (plus update_cost_per_token x generated tokens);
    # 0.0 keeps every pre-protocol run byte-identical.
    trainer: str = "sync"
    overlap_updates: bool = False
    update_cost: float = 0.0
    update_cost_per_token: float = 0.0
    sim_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # always-on serving tier (repro.serve): setting `arrival` switches the
    # session to continuous batching under a ServingOrchestrator — the
    # configured scheduling policy is wrapped by the "serving" policy and
    # prompts stream in through per-tenant admission-controlled queues
    # instead of epoch groups.
    tenants: Optional[List[Any]] = None    # TenantSpec | dict per tenant
    arrival: Optional[Any] = None          # {"kind": "poisson"|"bursty",
                                           #  "rates": {...}, ...} |
                                           # {"kind": "trace", "trace": [...]}
                                           # | a prebuilt arrival process
    admission: str = "fifo"                # fifo|weighted_fair|slo_aware
    serve_time: Optional[float] = None     # run_for sim-time bound
    serve_arrivals: Optional[int] = None   # run_for arrival-count bound
    serve_tick: Optional[float] = None     # serving-clock dt per step for
                                           # wall-clock engines (slot)


class RLSession:
    """A fully-wired RL run; build with :meth:`from_config`, drive with
    :meth:`run` (or step the parts manually via the public attributes)."""

    def __init__(self, cfg: SessionConfig, orchestrator: RolloutOrchestrator,
                 loader: GroupedLoader, vocab: Vocab,
                 model: Optional[Model] = None,
                 trainer: Optional[RLTrainer] = None,
                 reward_fn: Optional[Callable] = None,
                 eval_set: Optional[Tuple[List, List]] = None,
                 sft_losses: Optional[List[float]] = None,
                 evals: Optional[List[Dict]] = None,
                 sched_history: Optional[List[Dict]] = None):
        self.cfg = cfg
        self.orchestrator = orchestrator
        self.loader = loader
        self.vocab = vocab
        self.model = model
        self.trainer = trainer
        self.reward_fn = reward_fn
        self.eval_set = eval_set
        self.sft_losses = sft_losses or []
        self.evals = evals if evals is not None else []
        self.sched_history = sched_history if sched_history is not None else []

    # convenience pass-throughs
    @property
    def engine(self):
        return self.orchestrator.engine

    @property
    def buffer(self):
        return self.orchestrator.buffer

    @property
    def policy(self):
        return self.orchestrator.policy

    @property
    def metrics(self):
        return self.orchestrator.metrics

    @classmethod
    def from_config(cls, cfg: SessionConfig) -> "RLSession":
        if cfg.task not in TASKS:
            raise KeyError(f"unknown task {cfg.task!r}; "
                           f"registered: {sorted(TASKS)}")
        spec = TASKS[cfg.task]
        vocab = spec.vocab
        policy = make_policy(cfg.policy, **cfg.policy_kwargs)
        buffer = StatefulRolloutBuffer(cfg.mode)
        scfg = SortedRLConfig(mode=cfg.mode, rollout_batch=cfg.rollout_batch,
                              group_size=cfg.group_size,
                              update_batch=cfg.update_batch,
                              max_gen_len=cfg.max_gen_len,
                              harvest_threshold=cfg.harvest_threshold,
                              train_leftover=cfg.train_leftover,
                              num_replicas=cfg.num_replicas,
                              async_step=cfg.async_step,
                              drain_pack=cfg.drain_pack,
                              overlap_updates=cfg.overlap_updates)
        evals: List[Dict] = []
        sched_history: List[Dict] = []

        # the per-replica builder is kept for the autoscaler's replica
        # factory: scale_up mints warm replicas through the same closure
        # that built the starting fleet (same shard size, seed offset by
        # the new index)
        replica_builder: List[Any] = [None]

        def replicated(build_one):
            """`rollout_batch` slots as one engine or an EngineGroup of
            `num_replicas` equal shards (each with its own KV memory)."""
            replica_builder[0] = build_one
            n = cfg.num_replicas
            if n < 1 or cfg.rollout_batch % n != 0:
                raise ValueError(
                    f"rollout_batch={cfg.rollout_batch} must split evenly "
                    f"over num_replicas={n}")
            if n == 1 and cfg.fault_plan:
                raise ValueError(
                    "fault_plan requires num_replicas > 1 (faults are "
                    "injected per replica of an EngineGroup)")
            if n == 1 and not cfg.autoscaler:
                return build_one(0, cfg.rollout_batch)
            injector = (FaultInjector(cfg.fault_plan)
                        if cfg.fault_plan else None)
            return EngineGroup([build_one(i, cfg.rollout_batch // n)
                                for i in range(n)], balancer=cfg.balancer,
                               async_step=cfg.async_step,
                               drain_pack=cfg.drain_pack or None,
                               fault_injector=injector,
                               elastic=cfg.elastic or bool(cfg.autoscaler),
                               spread_tenants=cfg.arrival is not None)

        def build_autoscaler():
            if not cfg.autoscaler:
                return None
            from repro.rollout.autoscaler import Autoscaler
            build_one = replica_builder[0]
            shard = cfg.rollout_batch // max(1, cfg.num_replicas)
            return Autoscaler(
                cfg.autoscaler,
                factory=lambda idx: build_one(idx, shard),
                min_replicas=cfg.min_replicas,
                max_replicas=(cfg.max_replicas if cfg.max_replicas
                              is not None else cfg.num_replicas),
                window=cfg.autoscaler_window,
                policy_kwargs=cfg.autoscaler_kwargs)

        def make_orchestrator(engine, train_fn) -> RolloutOrchestrator:
            """Epoch-driven orchestrator, or — when `arrival` is set —
            the always-on serving tier: the configured policy wrapped by
            the admission-controlled ServingPolicy over a streaming
            ingress, driven by a ServingOrchestrator."""
            # the session's update callable rides behind the registered
            # Trainer front ("sync" serializes; "streaming" + overlap
            # hides trainer time behind continued rollout)
            front = make_trainer(
                cfg.trainer, fn=train_fn, update_cost=cfg.update_cost,
                update_cost_per_token=cfg.update_cost_per_token)
            if cfg.arrival is None:
                return RolloutOrchestrator(engine, buffer, scfg, policy,
                                           front,
                                           autoscaler=build_autoscaler())
            from repro.serve import (Ingress, ServingOrchestrator,
                                     ServingPolicy, coerce_specs,
                                     make_arrivals)
            specs = coerce_specs(cfg.tenants if cfg.tenants
                                 else [{"name": "default"}])
            arrival = cfg.arrival
            if isinstance(arrival, dict):
                arrival = dict(arrival)
                if arrival.get("kind", "poisson") != "trace":
                    arrival.setdefault("seed", cfg.seed)
                    arrival.setdefault("rates",
                                       {s.name: 1.0 for s in specs})
                    if "prompt_sampler" not in arrival:
                        # serving prompts come from the task generator,
                        # payload = the verifier meta (reward_fn unwraps
                        # it from ServeMeta.payload)
                        serve_gen = spec.make_generator(cfg.seed + 101)

                        def task_sampler(rng, tenant):
                            p, m = serve_gen.batch(1)
                            return list(p[0]), m[0]
                        arrival["prompt_sampler"] = task_sampler
                arrival = make_arrivals(arrival)
            ingress = Ingress(specs, arrival)
            serving_policy = ServingPolicy(inner=policy,
                                           admission=cfg.admission,
                                           ingress=ingress)
            tick = cfg.serve_tick
            if tick is None and cfg.engine == "slot":
                # wall-clock engine: a fixed per-step tick keeps every
                # scheduling decision on the simulated clock
                tick = 0.05
            return ServingOrchestrator(engine, buffer, scfg,
                                       serving_policy, front,
                                       ingress=ingress, tick=tick,
                                       autoscaler=build_autoscaler())

        if cfg.engine == "slot":
            model = build_model(tiny_lm_config(len(vocab), cfg.d_model,
                                               cfg.layers))
            params = model.init_params(jax.random.PRNGKey(cfg.seed))
            gen = spec.make_generator(cfg.seed)
            sft_examples = [gen.sft_example() for _ in range(2048)]
            params, sft_losses = sft_warmup(model, params, sft_examples,
                                            vocab.pad_id,
                                            steps=cfg.sft_steps,
                                            seed=cfg.seed,
                                            width=spec.sft_width)
            def reward_fn(toks, meta):
                # serving requests carry their task meta in
                # ServeMeta.payload; everything else passes through
                meta = getattr(meta, "payload", meta)
                return spec.verify(toks, meta, vocab)
            trainer = RLTrainer(model, params, reward_fn,
                                loss_cfg=LossConfig(),
                                opt_cfg=AdamWConfig(lr=cfg.lr),
                                pad_id=vocab.pad_id,
                                max_len=cfg.max_total_len,
                                advantage_kind=cfg.advantage_kind,
                                responses_per_prompt=cfg.responses_per_prompt)
            # partial mode keeps resident KV across weight syncs (the
            # paper's cache mechanism; recorded logprobs stay exact as
            # pi_old); on-policy re-rolls must re-prefill under the fresh
            # policy, or the prompt KV would bias the new rollouts
            engine = replicated(lambda i, cap: SlotEngine(
                model, trainer.params, capacity=cap,
                max_total_len=cfg.max_total_len,
                max_gen_len=cfg.max_gen_len,
                eos_id=vocab.eos_id, pad_id=vocab.pad_id,
                temperature=cfg.temperature, seed=cfg.seed + i,
                kv_retain_across_sync=(Mode(cfg.mode) == Mode.PARTIAL),
                packed_prefill=cfg.packed_prefill,
                fused_sampling=cfg.fused_sampling,
                kv_quant=cfg.kv_quant))
            eval_gen = spec.make_generator(9999)
            eval_set = eval_gen.batch(cfg.eval_size)

            def train_fn(req: UpdateRequest) -> UpdateResult:
                result = trainer.handle(req)
                if trainer.state.step % cfg.eval_every == 0:
                    ev = evaluate(model, trainer.params(), vocab,
                                  eval_set[0], eval_set[1], reward_fn,
                                  cfg.max_gen_len, cfg.max_total_len)
                    ev["step"] = trainer.state.step
                    evals.append(ev)
                return result

            orch = make_orchestrator(engine, train_fn)
            session = cls(cfg, orch, GroupedLoader(
                gen, cfg.rollout_batch, cfg.group_size,
                cfg.responses_per_prompt), vocab, model=model,
                trainer=trainer, reward_fn=reward_fn, eval_set=eval_set,
                sft_losses=sft_losses, evals=evals)
        elif cfg.engine == "sim":
            # scheduling-only: discrete-event engine, batch-stats trainer
            gen = spec.make_generator(cfg.seed)
            # mirror the slot path's sync semantics: modeled residency
            # survives weight syncs only in partial mode (explicit
            # sim_kwargs still win)
            sim_kwargs = dict(cfg.sim_kwargs)
            sim_kwargs.setdefault("kv_retain_across_sync",
                                  Mode(cfg.mode) == Mode.PARTIAL)
            engine = replicated(lambda i, cap: SimEngine(
                capacity=cap, max_gen_len=cfg.max_gen_len, seed=cfg.seed + i,
                **sim_kwargs))

            def train_fn(req: UpdateRequest) -> UpdateResult:
                lens = [e.gen_len for e in req.entries]
                rec = {"entries": len(req.entries),
                       "gen_len_mean": sum(lens) / len(lens),
                       "staleness_mean": req.staleness_mean,
                       "version": req.version}
                sched_history.append(rec)
                return UpdateResult(metrics=rec)

            orch = make_orchestrator(engine, train_fn)
            session = cls(cfg, orch, GroupedLoader(
                gen, cfg.rollout_batch, cfg.group_size,
                cfg.responses_per_prompt), vocab,
                sched_history=sched_history)
        else:
            raise ValueError(f"unknown engine {cfg.engine!r} "
                             "(expected 'slot' or 'sim')")

        # barrier-free policies stream prompts instead of taking groups
        # (under the serving tier prompts come from the ingress instead)
        if (cfg.arrival is None and hasattr(policy, "prompt_stream")
                and policy.prompt_stream is None):
            policy.prompt_stream = session.loader.stream()
        return session

    # -- driving ------------------------------------------------------------

    def run(self) -> Dict:
        """Drive the configured number of groups to consumption and return
        the result record (history, evals, final eval, rollout metrics)."""
        cfg = self.cfg
        orch = self.orchestrator
        t0 = time.monotonic()
        if cfg.arrival is not None:                     # always-on serving
            n_arr = cfg.serve_arrivals
            if n_arr is None and cfg.serve_time is None:
                # default bound: the epoch path's total prompt budget
                n_arr = cfg.n_groups * self.loader.prompts_per_group
            orch.run_for(sim_time=cfg.serve_time, n_arrivals=n_arr)
        elif hasattr(self.policy, "queue_group"):       # pipelined lookahead
            for _ in range(cfg.n_groups):
                prompts, metas = self.loader.next_group()
                self.policy.queue_group(prompts, metas)
            orch.run_queued()
        elif hasattr(self.policy, "prompt_stream"):     # ungrouped streaming
            total = cfg.n_groups * self.loader.prompts_per_group
            orch.run_steps(n_updates=max(1, total // cfg.update_batch))
        else:                                           # strict grouped
            for _ in range(cfg.n_groups):
                prompts, metas = self.loader.next_group()
                orch.run_group(prompts, metas)
        wall = round(time.monotonic() - t0, 1)

        out = {
            "task": cfg.task,
            "strategy": cfg.policy,
            "mode": cfg.mode.value,
            "rollout_metrics": orch.metrics.summary(),
            "wall_time_s": wall,
        }
        if cfg.arrival is not None:
            out["admission"] = cfg.admission
        if self.trainer is not None:
            out["sft_loss_final"] = (self.sft_losses[-1]
                                     if self.sft_losses else None)
            out["history"] = self.trainer.history
            out["evals"] = self.evals
            out["final_eval"] = evaluate(
                self.model, self.trainer.params(), self.vocab,
                self.eval_set[0], self.eval_set[1], self.reward_fn,
                cfg.max_gen_len, cfg.max_total_len)
        else:
            out["history"] = self.sched_history
        return out
