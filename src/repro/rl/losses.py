"""Policy-gradient losses (paper Eq. 1) with the DAPO tricks used in §4.1:
clip-higher (asymmetric clipping range), no KL term, no entropy bonus.

The importance ratio uses *cached behaviour log-probs* (pi_old) — in
partial mode these are stitched across policy versions per token, which is
exactly the paper's controlled-off-policiness mechanism (§3.2): every
token's ratio uses the log-prob of the policy version that generated it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LossConfig:
    clip_eps_low: float = 0.2
    clip_eps_high: float = 0.28      # DAPO clip-higher
    kl_coef: float = 0.0             # removed per §4.1
    entropy_coef: float = 0.0        # removed per §4.1
    aux_load_balance: float = 1e-2   # MoE router losses
    aux_router_z: float = 1e-3
    value_coef: float = 0.5          # PPO critic loss weight


def token_logprobs(logits: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """logits: (B, S, V) predicting token t+1 at position t.
    Returns log pi(tokens[t] | <t) aligned to positions (B, S): entry t is
    the log-prob OF token t (from logits at t-1); entry 0 is 0."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    lp_next = jnp.take_along_axis(lp[:, :-1], tokens[:, 1:, None],
                                  axis=2)[..., 0]          # (B, S-1)
    return jnp.pad(lp_next, ((0, 0), (1, 0)))


def ppo_clip_loss(new_logprobs: jnp.ndarray, old_logprobs: jnp.ndarray,
                  advantages: jnp.ndarray, loss_mask: jnp.ndarray,
                  cfg: LossConfig) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Eq. 1 with clip-higher.  All inputs (B, S); mask selects generated
    tokens.  Returns (scalar loss, metrics)."""
    ratio = jnp.exp(new_logprobs - old_logprobs)
    unclipped = ratio * advantages
    clipped = jnp.clip(ratio, 1.0 - cfg.clip_eps_low,
                       1.0 + cfg.clip_eps_high) * advantages
    obj = jnp.minimum(unclipped, clipped)
    n = jnp.maximum(loss_mask.sum(), 1.0)
    loss = -(obj * loss_mask).sum() / n
    clip_frac = ((jnp.abs(ratio - 1.0) > cfg.clip_eps_low)
                 * loss_mask).sum() / n
    metrics = {
        "policy_loss": loss,
        "ratio_mean": (ratio * loss_mask).sum() / n,
        "clip_frac": clip_frac,
        "kl_to_old": ((old_logprobs - new_logprobs) * loss_mask).sum() / n,
    }
    return loss, metrics


def value_loss(values: jnp.ndarray, returns: jnp.ndarray,
               loss_mask: jnp.ndarray) -> jnp.ndarray:
    n = jnp.maximum(loss_mask.sum(), 1.0)
    return 0.5 * (jnp.square(values - returns) * loss_mask).sum() / n


def total_loss(logits: jnp.ndarray, aux: Dict[str, jnp.ndarray],
               batch: Dict[str, jnp.ndarray], cfg: LossConfig,
               values: Optional[jnp.ndarray] = None,
               returns: Optional[jnp.ndarray] = None,
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: tokens (B,S), loss_mask (B,S), advantages (B,S),
    old_logprobs (B,S)."""
    new_lp = token_logprobs(logits, batch["tokens"])
    loss, metrics = ppo_clip_loss(new_lp, batch["old_logprobs"],
                                  batch["advantages"], batch["loss_mask"],
                                  cfg)
    if cfg.entropy_coef:
        p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        ent = -(p * jnp.log(p + 1e-9)).sum(-1)
        n = jnp.maximum(batch["loss_mask"].sum(), 1.0)
        ent_mean = (ent * batch["loss_mask"]).sum() / n
        loss = loss - cfg.entropy_coef * ent_mean
        metrics["entropy"] = ent_mean
    if values is not None and returns is not None:
        vl = value_loss(values, returns, batch["loss_mask"])
        loss = loss + cfg.value_coef * vl
        metrics["value_loss"] = vl
    loss = (loss + cfg.aux_load_balance * aux.get("load_balance", 0.0)
            + cfg.aux_router_z * aux.get("router_z", 0.0))
    metrics["total_loss"] = loss
    return loss, metrics
