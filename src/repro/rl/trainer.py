"""RL trainer: converts finished BufferEntries into padded update batches
and runs the jitted policy-gradient step.

The importance-sampling denominators come straight from the buffer's cached
per-token behaviour log-probs — the stitched pi_old of partial mode
(paper §3.2): a trajectory interrupted at version v and resumed at v+1 has
its first tokens' ratios computed against v and the rest against v+1.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffer import BufferEntry
from repro.models.model import Model
from repro.rl import advantages as A
from repro.rl.losses import LossConfig, total_loss
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


@dataclasses.dataclass
class TrainState:
    params: Dict
    opt_state: OptState
    step: int = 0


RewardFn = Callable[[Sequence[int], object], float]


def entries_to_batch(entries: Sequence[BufferEntry], reward_fn: RewardFn,
                     pad_id: int, max_len: int,
                     advantage_kind: str = "reinforce_pp",
                     responses_per_prompt: int = 1,
                     ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, float]]:
    """Pad trajectories to a common width and build the update batch.

    tokens = [prompt, generated]; loss_mask covers generated tokens;
    old_logprobs are the buffer's cached behaviour log-probs.
    """
    B = len(entries)
    width = max(e.total_len for e in entries)
    width = min(max_len, (width + 31) // 32 * 32)   # bucket: bounded recompiles
    tokens = np.full((B, width), pad_id, np.int32)
    loss_mask = np.zeros((B, width), np.float32)
    old_lp = np.zeros((B, width), np.float32)
    rewards = np.zeros(B, np.float32)
    staleness = np.zeros(B, np.float32)
    group_ids = np.zeros(B, np.int32)
    for i, e in enumerate(entries):
        seq = (list(e.prompt) + list(e.generated))[:width]
        tokens[i, :len(seq)] = seq
        p = min(len(e.prompt), width)
        g = len(seq) - p
        loss_mask[i, p:p + g] = 1.0
        old_lp[i, p:p + g] = e.logprobs[:g]
        rewards[i] = reward_fn(e.generated, e.meta)
        staleness[i] = e.staleness(max(v for v in e.versions)
                                   if e.versions else 0)
        group_ids[i] = getattr(e.meta, "prompt_id", i) % max(
            1, B // max(1, responses_per_prompt))
    lm = jnp.asarray(loss_mask)
    r = jnp.asarray(rewards)
    if advantage_kind == "reinforce_pp":
        adv = A.reinforce_pp(r, lm)
    elif advantage_kind == "grpo":
        adv = A.grpo(r, jnp.asarray(group_ids), lm,
                     num_groups=int(group_ids.max()) + 1)
    else:
        raise ValueError(advantage_kind)
    batch = {
        "tokens": jnp.asarray(tokens),
        "loss_mask": lm,
        "advantages": adv,
        "old_logprobs": jnp.asarray(old_lp),
    }
    info = {
        "reward_mean": float(rewards.mean()),
        "reward_std": float(rewards.std()),
        "gen_len_mean": float(np.mean([e.gen_len for e in entries])),
        "solve_rate": float(np.mean(rewards >= 1.2)),
    }
    return batch, info


def make_train_step(model: Model, loss_cfg: LossConfig, opt_cfg: AdamWConfig):
    """Returns jit-able (params, opt_state, batch) -> (params, opt_state,
    metrics).  This is also the function the dry-run lowers at full scale."""

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        if model.cfg.family == "vlm" and "patch_embeds" in batch:
            # logits cover [patches, tokens]; drop patch positions
            logits = logits[:, model.prefill_extra:]
        return total_loss(logits, aux, batch, loss_cfg)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


class RLTrainer:
    """Host-side wrapper the controller's train_fn hooks into."""

    def __init__(self, model: Model, params, reward_fn: RewardFn,
                 loss_cfg: Optional[LossConfig] = None,
                 opt_cfg: Optional[AdamWConfig] = None,
                 pad_id: int = 0, max_len: int = 512,
                 advantage_kind: str = "reinforce_pp",
                 responses_per_prompt: int = 1):
        self.model = model
        self.loss_cfg = loss_cfg or LossConfig()
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.state = TrainState(params, init_opt_state(params, self.opt_cfg))
        self.reward_fn = reward_fn
        self.pad_id = pad_id
        self.max_len = max_len
        self.advantage_kind = advantage_kind
        self.responses_per_prompt = responses_per_prompt
        self._step_jit = jax.jit(make_train_step(model, self.loss_cfg,
                                                 self.opt_cfg))
        self.history: List[Dict] = []

    def params(self):
        return self.state.params

    def update(self, entries: List[BufferEntry], version: int) -> Dict:
        batch, info = entries_to_batch(
            entries, self.reward_fn, self.pad_id, self.max_len,
            self.advantage_kind, self.responses_per_prompt)
        params, opt_state, metrics = self._step_jit(
            self.state.params, self.state.opt_state, batch)
        self.state = TrainState(params, opt_state, self.state.step + 1)
        rec = {k: float(v) for k, v in metrics.items()}
        rec.update(info)
        rec["version"] = version
        rec["step"] = self.state.step
        self.history.append(rec)
        return rec
