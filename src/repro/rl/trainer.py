"""RL trainer: converts finished BufferEntries into padded update batches
and runs the jitted policy-gradient step.

The importance-sampling denominators come straight from the buffer's cached
per-token behaviour log-probs — the stitched pi_old of partial mode
(paper §3.2): a trajectory interrupted at version v and resumed at v+1 has
its first tokens' ratios computed against v and the rest against v+1.

This module is also the home of the Trainer *protocol* surface
(``Trainer`` / ``make_trainer("sync"|"streaming")`` / ``as_trainer``),
re-exported from the jax-free :mod:`repro.rl.trainer_api` — see that
module for the overlap semantics and the deprecation note on bare
``TrainFn`` callables.

Batch assembly is mesh-aware: under an installed
:func:`repro.distributed.sharding.axis_rules` context the finished batch
is padded to the data-shard count and placed shard-per-device
(:func:`~repro.distributed.sharding.shard_update_batch`); outside any
context it stays a plain host batch.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffer import BufferEntry
from repro.core.orchestrator import UpdateRequest, UpdateResult
from repro.distributed.sharding import shard_update_batch
from repro.models.model import Model
from repro.rl import advantages as A
from repro.rl.losses import LossConfig, total_loss
# the typed trainer front (protocol + registry + callable shim) lives in
# the jax-free trainer_api module; re-exported here as the public surface
from repro.rl.trainer_api import (CostSpec, StreamingTrainer, SyncTrainer,
                                  TrainOutcome, Trainer, as_trainer,
                                  available_trainers, make_trainer,
                                  register_trainer)
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


@dataclasses.dataclass
class TrainState:
    params: Dict
    opt_state: OptState
    step: int = 0


RewardFn = Callable[[Sequence[int], object], float]


def entries_to_batch(entries: Sequence[BufferEntry], reward_fn: RewardFn,
                     pad_id: int, max_len: int,
                     advantage_kind: str = "reinforce_pp", *,
                     current_version: Optional[int] = None,
                     ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, float]]:
    """Pad trajectories to a common width and build the update batch.

    tokens = [prompt, generated]; loss_mask covers generated tokens;
    old_logprobs are the buffer's cached behaviour log-probs.  Staleness
    is measured against ``current_version`` — the trainer's policy version
    at update time (threaded from the orchestrator); entries whose prompt
    leaves no room for generated tokens are skipped with a warning (they
    would train on an all-zero loss mask).
    """
    kept, skipped = [], []
    for e in entries:
        (kept if len(e.prompt) < max_len else skipped).append(e)
    if skipped:
        warnings.warn(
            f"entries_to_batch: skipping {len(skipped)} "
            f"entr{'y' if len(skipped) == 1 else 'ies'} with prompt >= "
            f"max_len={max_len} (uids {[e.uid for e in skipped[:8]]}); "
            f"no generated token fits the update window")
    if not kept:
        raise ValueError(
            f"entries_to_batch: all {len(entries)} entries were skipped "
            f"(every prompt >= max_len={max_len})")
    if current_version is None:
        # fallback: newest version seen in the batch (lower bound)
        current_version = max((max(e.versions) for e in kept if e.versions),
                              default=0)
    B = len(kept)
    width = max(e.total_len for e in kept)
    width = min(max_len, (width + 31) // 32 * 32)   # bucket: bounded recompiles
    tokens = np.full((B, width), pad_id, np.int32)
    loss_mask = np.zeros((B, width), np.float32)
    old_lp = np.zeros((B, width), np.float32)
    rewards = np.zeros(B, np.float32)
    staleness = np.zeros(B, np.float32)
    group_ids = np.zeros(B, np.int32)
    # dense group indices: responses sharing a prompt_id form one GRPO
    # group; unrelated prompts never collide
    gid_of: Dict = {}
    for i, e in enumerate(kept):
        seq = (list(e.prompt) + list(e.generated))[:width]
        tokens[i, :len(seq)] = seq
        p = min(len(e.prompt), width)
        g = len(seq) - p
        loss_mask[i, p:p + g] = 1.0
        old_lp[i, p:p + g] = e.logprobs[:g]
        rewards[i] = reward_fn(e.generated, e.meta)
        staleness[i] = e.staleness(current_version)
        pid = getattr(e.meta, "prompt_id", None)
        key = pid if pid is not None else ("uid", e.uid)
        group_ids[i] = gid_of.setdefault(key, len(gid_of))
    lm = jnp.asarray(loss_mask)
    assert float(loss_mask.sum()) > 0, \
        "update batch has no trainable tokens (all-zero loss mask)"
    r = jnp.asarray(rewards)
    if advantage_kind == "reinforce_pp":
        adv = A.reinforce_pp(r, lm)
    elif advantage_kind == "grpo":
        adv = A.grpo(r, jnp.asarray(group_ids), lm,
                     num_groups=int(group_ids.max()) + 1)
    else:
        raise ValueError(advantage_kind)
    batch = {
        "tokens": jnp.asarray(tokens),
        "loss_mask": lm,
        "advantages": adv,
        "old_logprobs": jnp.asarray(old_lp),
    }
    # mesh-aware placement: pads to the data-shard count with inert rows
    # and device_puts shard-per-slice; identity outside an axis_rules
    # context, so host-only callers and token-identity pins are untouched
    batch = shard_update_batch(batch, pad_token=pad_id)
    info = {
        "reward_mean": float(rewards.mean()),
        "reward_std": float(rewards.std()),
        "gen_len_mean": float(np.mean([e.gen_len for e in kept])),
        "solve_rate": float(np.mean(rewards >= 1.2)),
        "staleness_mean": float(staleness.mean()),
        "staleness_max": float(staleness.max()),
        "entries_skipped": float(len(skipped)),
    }
    return batch, info


def make_train_step(model: Model, loss_cfg: LossConfig, opt_cfg: AdamWConfig):
    """Returns jit-able (params, opt_state, batch) -> (params, opt_state,
    metrics).  This is also the function the dry-run lowers at full scale."""

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        if model.cfg.family == "vlm" and "patch_embeds" in batch:
            # logits cover [patches, tokens]; drop patch positions
            logits = logits[:, model.prefill_extra:]
        return total_loss(logits, aux, batch, loss_cfg)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


class RLTrainer:
    """Host-side wrapper the controller's train_fn hooks into."""

    def __init__(self, model: Model, params, reward_fn: RewardFn,
                 loss_cfg: Optional[LossConfig] = None,
                 opt_cfg: Optional[AdamWConfig] = None,
                 pad_id: int = 0, max_len: int = 512,
                 advantage_kind: str = "reinforce_pp",
                 responses_per_prompt: int = 1):
        # responses_per_prompt is accepted for signature compatibility and
        # run metadata; GRPO grouping is keyed on meta.prompt_id, so the
        # loader-level duplication (GroupedLoader) is what actually
        # produces multi-response groups.
        self.model = model
        self.loss_cfg = loss_cfg or LossConfig()
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.state = TrainState(params, init_opt_state(params, self.opt_cfg))
        self.reward_fn = reward_fn
        self.pad_id = pad_id
        self.max_len = max_len
        self.advantage_kind = advantage_kind
        self.responses_per_prompt = responses_per_prompt
        self._step_jit = jax.jit(make_train_step(model, self.loss_cfg,
                                                 self.opt_cfg))
        self.history: List[Dict] = []

    def params(self):
        return self.state.params

    def update(self, entries: List[BufferEntry], version: int) -> Dict:
        batch, info = entries_to_batch(
            entries, self.reward_fn, self.pad_id, self.max_len,
            self.advantage_kind, current_version=version)
        params, opt_state, metrics = self._step_jit(
            self.state.params, self.state.opt_state, batch)
        self.state = TrainState(params, opt_state, self.state.step + 1)
        rec = {k: float(v) for k, v in metrics.items()}
        rec.update(info)
        rec["version"] = version
        rec["step"] = self.state.step
        self.history.append(rec)
        return rec

    def handle(self, request: UpdateRequest) -> UpdateResult:
        """Typed orchestrator entry point (UpdateRequest -> UpdateResult)."""
        rec = self.update(request.entries, request.version)
        return UpdateResult(metrics=rec)
