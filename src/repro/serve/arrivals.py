"""Deterministic, seeded arrival processes for the always-on serving tier.

Each process is an iterable of :class:`Arrival` events — ``(t, tenant,
prompt, group_size, length_hint)`` — ordered by arrival time on the
SIMULATED clock.  All randomness comes from string-seeded
``random.Random`` instances (one per tenant, independent of each other
and of prompt sampling), so the same seed produces the same event stream
on every platform and process: the serving loop's determinism regression
compares two same-seed runs' full per-tenant event logs.

Three shapes:

* :class:`PoissonArrivals` — per-tenant independent Poisson streams
  (exponential inter-arrival gaps at each tenant's rate), merged by time;
* :class:`BurstyArrivals` — on/off (interrupted Poisson) per tenant:
  bursts of ``on_time`` at ``rate``, silent for ``off_time``, with a
  seeded per-tenant phase offset so tenants don't burst in lockstep;
* :class:`TraceArrivals` — replay of a recorded workload, so two
  admission policies can be compared on the IDENTICAL arrival sequence
  (the ``bursty_slo`` benchmark pins slo_aware vs fifo this way).

``record_trace(process, n)`` materialises the first ``n`` events of any
process into the tuple form ``TraceArrivals`` accepts.
"""
from __future__ import annotations

import dataclasses
import heapq
import random
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request-group arrival at the ingress."""
    t: float                       # arrival time (simulated clock)
    tenant: str
    prompt: List[int]
    group_size: int = 1            # expanded into this many requests
    length_hint: Optional[int] = None   # expected generation length
    payload: Any = None            # opaque task data (e.g. verifier truth)


# prompt_sampler(rng, tenant) -> prompt | (prompt, payload)
PromptSampler = Callable[[random.Random, str], Any]


def default_prompt_sampler(rng: random.Random, tenant: str) -> List[int]:
    """Token-id filler with a varied length — enough for the simulator,
    where only prompt length matters.  Real runs pass their own sampler
    (tokenised tasks, verifier payloads)."""
    return [1] * rng.randint(4, 12)


def _sample_prompt(sampler: PromptSampler, rng: random.Random, tenant: str):
    out = sampler(rng, tenant)
    if isinstance(out, tuple) and len(out) == 2:
        return list(out[0]), out[1]
    return list(out), None


class _MergedProcess:
    """Shared shape: per-tenant generators merged by (t, tenant)."""

    def __init__(self, rates: Dict[str, float], seed: int = 0,
                 prompt_sampler: Optional[PromptSampler] = None,
                 group_size: "int | Dict[str, int]" = 1,
                 length_hint: Optional[Callable[[random.Random, str],
                                                int]] = None):
        assert rates, "need at least one tenant"
        for name, rate in rates.items():
            assert rate > 0, f"tenant {name!r}: rate must be > 0"
        self.rates = dict(rates)
        self.seed = seed
        self.prompt_sampler = prompt_sampler or default_prompt_sampler
        self.group_size = group_size
        self.length_hint = length_hint

    def _group(self, tenant: str) -> int:
        if isinstance(self.group_size, dict):
            return int(self.group_size.get(tenant, 1))
        return int(self.group_size)

    def _tenant_stream(self, tenant: str) -> Iterator[Arrival]:
        raise NotImplementedError

    def _emit(self, tenant: str, t: float, gap_rng: random.Random,
              prompt_rng: random.Random) -> Arrival:
        prompt, payload = _sample_prompt(self.prompt_sampler,
                                         prompt_rng, tenant)
        hint = (self.length_hint(gap_rng, tenant)
                if self.length_hint is not None else None)
        return Arrival(t=t, tenant=tenant, prompt=prompt,
                       group_size=self._group(tenant),
                       length_hint=hint, payload=payload)

    def __iter__(self) -> Iterator[Arrival]:
        streams = [self._tenant_stream(name)
                   for name in sorted(self.rates)]
        return heapq.merge(*streams, key=lambda a: (a.t, a.tenant))


class PoissonArrivals(_MergedProcess):
    """Independent Poisson stream per tenant, merged by time."""

    KIND = "poisson"

    def _tenant_stream(self, tenant: str) -> Iterator[Arrival]:
        gap_rng = random.Random(f"{self.KIND}:{self.seed}:{tenant}")
        prompt_rng = random.Random(f"prompt:{self.seed}:{tenant}")
        rate = self.rates[tenant]
        t = 0.0
        while True:
            t += gap_rng.expovariate(rate)
            yield self._emit(tenant, t, gap_rng, prompt_rng)


class BurstyArrivals(_MergedProcess):
    """Interrupted Poisson per tenant: arrivals at ``rate`` during
    ``on_time`` windows, silence for ``off_time``, repeating.  Each
    tenant gets a seeded phase offset inside the cycle so the fleet sees
    staggered (not synchronised) bursts — the workload the slo_aware
    admission policy exists for."""

    KIND = "bursty"

    def __init__(self, rates: Dict[str, float], seed: int = 0,
                 prompt_sampler: Optional[PromptSampler] = None,
                 group_size: "int | Dict[str, int]" = 1,
                 length_hint=None,
                 on_time: float = 1.0, off_time: float = 3.0):
        super().__init__(rates, seed, prompt_sampler, group_size,
                         length_hint)
        assert on_time > 0 and off_time >= 0
        self.on_time = on_time
        self.off_time = off_time

    def _tenant_stream(self, tenant: str) -> Iterator[Arrival]:
        gap_rng = random.Random(f"{self.KIND}:{self.seed}:{tenant}")
        prompt_rng = random.Random(f"prompt:{self.seed}:{tenant}")
        rate = self.rates[tenant]
        cycle = self.on_time + self.off_time
        t = gap_rng.uniform(0.0, cycle)          # per-tenant phase offset
        while True:
            t += gap_rng.expovariate(rate)
            # arrivals only inside on-windows: a draw landing in the off
            # part of the cycle is deferred to the next window's start
            into = t % cycle
            if into >= self.on_time:
                t += cycle - into
            yield self._emit(tenant, t, gap_rng, prompt_rng)


class TraceArrivals:
    """Replay a recorded workload verbatim.  Accepts :class:`Arrival`
    objects or plain tuples ``(t, tenant, prompt[, group_size[,
    length_hint[, payload]]])`` (the ``record_trace`` wire format)."""

    def __init__(self, trace: Sequence):
        events: List[Arrival] = []
        for item in trace:
            if not isinstance(item, Arrival):
                t, tenant, prompt = item[0], item[1], item[2]
                group = item[3] if len(item) > 3 else 1
                hint = item[4] if len(item) > 4 else None
                payload = item[5] if len(item) > 5 else None
                item = Arrival(t=float(t), tenant=tenant,
                               prompt=list(prompt), group_size=int(group),
                               length_hint=hint, payload=payload)
            events.append(item)
        self.events = sorted(events, key=lambda a: (a.t, a.tenant))

    def __iter__(self) -> Iterator[Arrival]:
        return iter(self.events)


def record_trace(process, n: int) -> List[tuple]:
    """Materialise the first ``n`` arrivals of a process as replayable
    tuples (so distinct admission policies can be benchmarked against the
    IDENTICAL seeded arrival sequence)."""
    out = []
    for arr in process:
        if len(out) >= n:
            break
        out.append((arr.t, arr.tenant, list(arr.prompt), arr.group_size,
                    arr.length_hint, arr.payload))
    return out


# declarative construction (SessionConfig.arrival wire format)
ARRIVAL_KINDS = {"poisson": PoissonArrivals, "bursty": BurstyArrivals}


def make_arrivals(spec: "dict | TraceArrivals | _MergedProcess"):
    """Build an arrival process from a config dict:
    ``{"kind": "poisson", "rates": {...}, "seed": 0, ...}`` or
    ``{"kind": "trace", "trace": [...]}``.  Already-built processes pass
    through unchanged."""
    if not isinstance(spec, dict):
        return spec
    spec = dict(spec)
    kind = spec.pop("kind", "poisson")
    if kind == "trace":
        return TraceArrivals(spec["trace"])
    if kind not in ARRIVAL_KINDS:
        raise KeyError(f"unknown arrival kind {kind!r}; expected one of "
                       f"{sorted(ARRIVAL_KINDS) + ['trace']}")
    return ARRIVAL_KINDS[kind](**spec)
