"""Multi-tenant ingress: tenant specs, bounded per-tenant queues, and the
admission-controller registry for the always-on serving tier.

A :class:`TenantSpec` declares a tenant's contract — arrival weight,
optional rate limit (token bucket), optional latency SLO, queue bound.
Arrivals land in per-tenant bounded :class:`TenantQueue`\\ s inside an
:class:`Ingress`; requests the bucket or the bound rejects are *shed*
(counted per tenant, logged).  An **admission policy** then decides which
queued requests enter the rollout buffer whenever the engine has spare
slots:

* ``fifo``          — global arrival order, tenant-blind (the baseline);
* ``weighted_fair`` — deficit round robin across tenants: each visit
  banks ``quantum * weight`` credit, admissions spend it, so long-run
  admission shares converge to the weight ratio and no backlogged tenant
  starves (the guarantee ``serving_conformance`` pins);
* ``slo_aware``     — earliest deadline first over the queue heads
  (deadline = arrival + the tenant's ``latency_slo``; no SLO = never
  urgent), which is what keeps a latency-sensitive tenant's p99 down
  while a batch tenant floods the queue.

Admission composes with — it does not replace — the scheduling policy:
:class:`ServingPolicy` wraps ANY registered
:class:`~repro.core.policy.SchedulerPolicy` (``DelegatingPolicy``) and
overrides only ``admit_next_group``, so fill order, harvesting, and
training order stay whatever the wrapped strategy says.  It is itself
registered as ``"serving"``.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.policy import (AdmitRequest, DelegatingPolicy, SchedView,
                               SchedulerPolicy, make_policy, register_policy)

# -----------------------------------------------------------------------------
# tenant specs
# -----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's service contract."""
    name: str
    weight: float = 1.0               # weighted_fair admission share
    rate_limit: Optional[float] = None   # req/s token bucket (None = open)
    burst: Optional[float] = None     # bucket depth (default max(1, rate))
    latency_slo: Optional[float] = None  # e2e deadline, arrival-relative
    queue_capacity: int = 64          # bounded queue; overflow is shed
    group_size: int = 1               # requests per arrival (GRPO group)

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")
        if self.queue_capacity < 1:
            raise ValueError(
                f"tenant {self.name!r}: queue_capacity must be >= 1")

    @property
    def bucket_depth(self) -> float:
        if self.burst is not None:
            return float(self.burst)
        return max(1.0, float(self.rate_limit or 1.0))


def coerce_specs(specs: Sequence) -> List[TenantSpec]:
    """Accept TenantSpec instances or plain dicts (the
    ``SessionConfig.tenants`` wire format)."""
    out = []
    for s in specs:
        if not isinstance(s, TenantSpec):
            s = TenantSpec(**s)
        out.append(s)
    names = [s.name for s in out]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names: {names}")
    return out


@dataclasses.dataclass
class ServeMeta:
    """Entry meta carried through the rollout buffer for serving
    requests.  ``payload`` holds the caller's opaque task data (e.g. the
    verifier ground truth) — reward plumbing unwraps it via
    ``getattr(meta, "payload", meta)``."""
    tenant: str
    seq: int                       # ingress-global request id
    t_arrival: float
    t_admit: Optional[float] = None
    deadline: Optional[float] = None
    length_hint: Optional[int] = None
    payload: Any = None
    prompt_id: Optional[int] = None   # arrival group id (GRPO grouping)


@dataclasses.dataclass
class QueuedRequest:
    """One request waiting in a tenant queue."""
    seq: int
    tenant: str
    prompt: List[int]
    t_arrival: float
    deadline: Optional[float] = None
    length_hint: Optional[int] = None
    payload: Any = None
    group_id: int = 0              # arrival index (group_size expansion)

    def sort_deadline(self) -> float:
        return self.deadline if self.deadline is not None else float("inf")


class TenantQueue:
    """Bounded FIFO with an optional token-bucket rate limit.  Both
    rejections (bucket empty, queue full) shed the request — the caller
    records which tenant shed what."""

    def __init__(self, spec: TenantSpec):
        self.spec = spec
        self._q: collections.deque = collections.deque()
        self.depth_peak = 0
        self.admitted = 0
        self._tokens = spec.bucket_depth
        self._bucket_t = 0.0

    def __len__(self) -> int:
        return len(self._q)

    def offer(self, req: QueuedRequest, now: float) -> bool:
        spec = self.spec
        if spec.rate_limit is not None:
            self._tokens = min(spec.bucket_depth,
                               self._tokens
                               + (now - self._bucket_t) * spec.rate_limit)
            self._bucket_t = now
            if self._tokens < 1.0:
                return False
        if len(self._q) >= spec.queue_capacity:
            return False
        if spec.rate_limit is not None:
            self._tokens -= 1.0
        self._q.append(req)
        self.depth_peak = max(self.depth_peak, len(self._q))
        return True

    def head(self) -> Optional[QueuedRequest]:
        return self._q[0] if self._q else None

    def pop(self) -> QueuedRequest:
        return self._q.popleft()


# -----------------------------------------------------------------------------
# admission registry
# -----------------------------------------------------------------------------

# an admission policy pops up to `budget` requests from the queues;
# whatever it returns is admitted into the rollout buffer
AdmissionPolicy = Callable  # select(queues, budget, now) -> List[QueuedRequest]

_ADMISSIONS: Dict[str, Callable[..., AdmissionPolicy]] = {}


def register_admission(name: str):
    def deco(factory):
        _ADMISSIONS[name] = factory
        return factory
    return deco


def make_admission(name: str, **kwargs) -> AdmissionPolicy:
    if name not in _ADMISSIONS:
        raise KeyError(f"unknown admission policy {name!r}; "
                       f"registered: {available_admissions()}")
    return _ADMISSIONS[name](**kwargs)


def available_admissions() -> List[str]:
    return sorted(_ADMISSIONS)


@register_admission("fifo")
class FifoAdmission:
    """Global arrival order, tenant-blind: repeatedly admit the earliest
    queue head.  The baseline every other policy is measured against."""

    name = "fifo"

    def select(self, queues: Dict[str, TenantQueue], budget: int,
               now: float) -> List[QueuedRequest]:
        picked: List[QueuedRequest] = []
        while budget > 0:
            heads = [(q.head().t_arrival, q.head().seq, name)
                     for name, q in queues.items() if len(q)]
            if not heads:
                break
            _, _, name = min(heads)
            picked.append(queues[name].pop())
            budget -= 1
        return picked


@register_admission("weighted_fair")
class WeightedFairAdmission:
    """Deficit round robin across tenants.  Each visit to a backlogged
    tenant banks ``quantum * weight`` credit; admitting one request
    spends 1.  Credit resets when a tenant's queue empties (no banking
    unbounded priority while idle), and the rotation pointer advances
    every call, so with any positive weight a backlogged tenant is
    admitted within a bounded number of calls — the no-starvation
    guarantee — while long-run shares converge to the weight ratio."""

    name = "weighted_fair"

    def __init__(self, quantum: float = 1.0):
        assert quantum > 0
        self.quantum = quantum
        self.deficits: Dict[str, float] = {}
        self._ptr = 0

    def select(self, queues: Dict[str, TenantQueue], budget: int,
               now: float) -> List[QueuedRequest]:
        picked: List[QueuedRequest] = []
        names = list(queues)
        if not names or budget <= 0:
            return picked
        self._ptr %= len(names)
        while budget > 0 and any(len(q) for q in queues.values()):
            for k in range(len(names)):
                name = names[(self._ptr + k) % len(names)]
                q = queues[name]
                if not len(q):
                    self.deficits[name] = 0.0
                    continue
                self.deficits[name] = (self.deficits.get(name, 0.0)
                                       + self.quantum * q.spec.weight)
                while len(q) and budget > 0 and self.deficits[name] >= 1.0:
                    picked.append(q.pop())
                    self.deficits[name] -= 1.0
                    budget -= 1
                if not len(q):
                    self.deficits[name] = 0.0
                if budget <= 0:
                    break
        self._ptr = (self._ptr + 1) % len(names)
        return picked


@register_admission("slo_aware")
class SloAwareAdmission:
    """Earliest deadline first over the queue heads.  A tenant's deadline
    is ``t_arrival + latency_slo``, constant per tenant, so each queue's
    head carries its earliest deadline and head-EDF is exact EDF over
    all queued requests.  Tenants without an SLO sort last (deadline
    +inf) — they are served from the slack the urgent tenants leave."""

    name = "slo_aware"

    def select(self, queues: Dict[str, TenantQueue], budget: int,
               now: float) -> List[QueuedRequest]:
        picked: List[QueuedRequest] = []
        while budget > 0:
            heads = [(q.head().sort_deadline(), q.head().t_arrival,
                      q.head().seq, name)
                     for name, q in queues.items() if len(q)]
            if not heads:
                break
            name = min(heads)[-1]
            picked.append(queues[name].pop())
            budget -= 1
        return picked


# -----------------------------------------------------------------------------
# ingress
# -----------------------------------------------------------------------------


class Ingress:
    """Streaming front door: pulls arrivals from a seeded process, shapes
    them through per-tenant bounded queues, and keeps the authoritative
    per-tenant event log ``(t, kind, tenant, seq)`` with kinds ``arrive``
    / ``shed`` / ``admit`` / ``done`` — the determinism regression
    compares two same-seed runs' full logs.

    All time comes from the caller (``pump(now)``) on the simulated
    clock; the ingress never reads a wall clock."""

    def __init__(self, specs: Sequence, arrivals,
                 max_arrivals: Optional[int] = None, metrics=None):
        specs = coerce_specs(specs)
        self.specs: Dict[str, TenantSpec] = {s.name: s for s in specs}
        self.queues: Dict[str, TenantQueue] = {
            s.name: TenantQueue(s) for s in specs}
        self._it = iter(arrivals)
        self._next = None
        self._exhausted = False
        self.max_arrivals = max_arrivals
        self.arrival_count = 0        # arrival EVENTS delivered (pre-expansion)
        self.closed = False
        self.now = 0.0
        self.events: List[tuple] = []
        self._seq = itertools.count()
        self.metrics = metrics        # RolloutMetrics (set by the orchestrator)

    # -- stream ------------------------------------------------------------

    def _peek(self):
        if (self._next is None and not self.closed and not self._exhausted
                and (self.max_arrivals is None
                     or self.arrival_count < self.max_arrivals)):
            self._next = next(self._it, None)
            if self._next is None:
                self._exhausted = True
        return self._next

    def next_arrival_time(self) -> Optional[float]:
        a = self._peek()
        return a.t if a is not None else None

    def close(self) -> None:
        """Stop accepting arrivals; a pending lookahead event is dropped
        (deterministically — it is beyond the serving window)."""
        self.closed = True
        self._next = None

    def pump(self, now: float) -> int:
        """Deliver every arrival with ``t <= now``; returns how many
        arrival events were delivered."""
        self.now = max(self.now, now)
        n = 0
        while True:
            a = self._peek()
            if a is None or a.t > self.now:
                break
            self._next = None
            self.arrival_count += 1
            n += 1
            self._deliver(a)
        return n

    def _deliver(self, a) -> None:
        if a.tenant not in self.queues:
            raise KeyError(f"arrival for unknown tenant {a.tenant!r}; "
                           f"declared: {sorted(self.queues)}")
        q = self.queues[a.tenant]
        slo = self.specs[a.tenant].latency_slo
        gid = self.arrival_count - 1
        for _ in range(max(1, a.group_size)):
            seq = next(self._seq)
            req = QueuedRequest(
                seq=seq, tenant=a.tenant, prompt=list(a.prompt),
                t_arrival=a.t,
                deadline=(a.t + slo) if slo is not None else None,
                length_hint=a.length_hint, payload=a.payload, group_id=gid)
            self.record("arrive", a.tenant, seq, a.t)
            st = self._stat(a.tenant)
            if st is not None:
                st.arrivals += 1
            if not q.offer(req, a.t):
                self.record("shed", a.tenant, seq, a.t)
                if st is not None:
                    st.shed += 1

    # -- accounting --------------------------------------------------------

    def _stat(self, tenant: str):
        return self.metrics.tenant(tenant) if self.metrics is not None else None

    def note_admit(self, req: QueuedRequest, now: float) -> None:
        self.queues[req.tenant].admitted += 1
        st = self._stat(req.tenant)
        if st is not None:
            st.admitted += 1
        self.record("admit", req.tenant, req.seq, now)

    def record(self, kind: str, tenant: str, seq: int, t: float) -> None:
        self.events.append((round(t, 9), kind, tenant, seq))

    # -- queries -----------------------------------------------------------

    def queued_total(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def drained(self) -> bool:
        """No future arrivals (closed / exhausted / budget spent) and
        nothing left queued."""
        return self._peek() is None and self.queued_total() == 0


# -----------------------------------------------------------------------------
# the SchedulerPolicy extension point
# -----------------------------------------------------------------------------


@register_policy("serving")
class ServingPolicy(DelegatingPolicy):
    """Admission-controlled serving over ANY scheduling strategy.

    Wraps a registered policy (``inner``, by name or instance) and
    overrides only ``admit_next_group``: whenever the engine has slots no
    pending entry will take, the admission policy picks which tenants'
    queued requests enter the buffer.  Everything else — fill order,
    harvest timing, training order, update gating — delegates to the
    wrapped strategy, so every (admission x scheduler) pair composes.

    Without an ingress the policy is a transparent proxy for ``inner``
    (this is what the no-args registry contract exercises); with one,
    the strict group barrier is dropped — continuous batching has no
    epoch boundary.
    """

    name = "serving"

    def __init__(self, inner: "str | SchedulerPolicy" = "sorted",
                 admission: "str | AdmissionPolicy" = "fifo",
                 ingress: Optional[Ingress] = None,
                 inner_kwargs: Optional[dict] = None,
                 admission_kwargs: Optional[dict] = None):
        if isinstance(inner, str):
            inner = make_policy(inner, **(inner_kwargs or {}))
        super().__init__(inner)
        if isinstance(admission, str):
            admission = make_admission(admission, **(admission_kwargs or {}))
        self.admission = admission
        self.ingress = ingress
        if ingress is not None:
            self.strict_group_barrier = False

    def admit_next_group(self, view: SchedView) -> Optional[AdmitRequest]:
        ing = self.ingress
        if ing is None:
            return self.inner.admit_next_group(view)
        # only admit what pending work will not already absorb: the
        # buffer's pending set is bounded by the engine's capacity
        budget = view.free_slots - view.pending
        if budget <= 0:
            return None
        picked = self.admission.select(ing.queues, budget, ing.now)
        if not picked:
            return None
        prompts, metas = [], []
        for req in picked:
            meta = ServeMeta(
                tenant=req.tenant, seq=req.seq, t_arrival=req.t_arrival,
                t_admit=ing.now, deadline=req.deadline,
                length_hint=req.length_hint, payload=req.payload,
                prompt_id=req.group_id)
            ing.note_admit(req, ing.now)
            prompts.append(req.prompt)
            metas.append(meta)
        return AdmitRequest(prompts, metas)
