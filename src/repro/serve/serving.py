"""Always-on serving orchestration: continuous batching with no epoch
boundary over the existing rollout mechanics.

:class:`ServingOrchestrator` subclasses
:class:`~repro.core.orchestrator.RolloutOrchestrator` and reuses its
fill / step / harvest / train machinery verbatim — the only new control
flow is the unbounded :meth:`run_for` loop:

* **admit-as-slots-free** — every iteration pumps the ingress up to the
  current simulated time and runs the normal ``_fill_engine`` path; the
  wrapped :class:`~repro.serve.tenants.ServingPolicy` admits queued
  requests through its admission controller whenever slots would
  otherwise idle;
* **harvest-as-groups-complete** — when the wrapped strategy says
  ``harvest_now`` (and runners exist), stragglers are interrupted and
  scavenged through the shared ``_harvest_stragglers`` path;
* **train-as-threshold-met** — whenever ``update_batch`` trajectories
  are DONE the trainer is fed through the normal ``train_ready`` path;
  consumed entries are pruned immediately (continuous batching never
  calls ``advance_group`` — there is no group to advance), so buffer
  memory stays bounded on an unbounded stream.

Time is simulated throughout.  Over a virtual-clock engine (SimEngine,
or an EngineGroup of them) the serving clock IS the engine clock, plus
the idle gaps the loop skips while waiting for the next arrival.  Real
wall-clock engines (SlotEngine) pass ``tick=<dt>`` instead: the serving
clock then advances by a fixed ``tick`` per decode step, so scheduling
decisions stay deterministic — no wall clock ever reaches them.

Works unchanged over :class:`~repro.rollout.group.EngineGroup` — every
balancer, ``async_step``, ``drain_pack``, and fault plans.  Fault plans
need no horizon: the loop polls ``due(step)`` forever and a plan step
beyond whatever the run reaches simply never fires.
"""
from __future__ import annotations

from typing import Optional

from repro.core.buffer import EntryState, StatefulRolloutBuffer
from repro.core.engine_api import EngineProtocol
from repro.core.metrics import MetricsSnapshot, RolloutMetrics
from repro.core.orchestrator import (RolloutOrchestrator, SortedRLConfig,
                                     TrainFn, UpdateRequest)
from repro.core.policy import SchedulerPolicy
from repro.serve.tenants import Ingress

# iterations with zero observable progress (no arrivals, tokens, updates,
# or clock movement) before the loop declares itself wedged.  Stall
# faults park replicas for a handful of steps; this is orders of
# magnitude above any legitimate quiet streak.
STAGNATION_LIMIT = 10_000


class ServingOrchestrator(RolloutOrchestrator):
    """Continuous batching forever (or until a time / arrival budget)."""

    def __init__(self, engine: EngineProtocol, buffer: StatefulRolloutBuffer,
                 cfg: SortedRLConfig, policy: SchedulerPolicy,
                 train_fn: TrainFn, ingress: Optional[Ingress] = None,
                 metrics: Optional[RolloutMetrics] = None,
                 tick: Optional[float] = None,
                 autoscaler: Optional[object] = None):
        super().__init__(engine, buffer, cfg, policy, train_fn, metrics,
                         autoscaler=autoscaler)
        self.ingress = ingress if ingress is not None else getattr(
            policy, "ingress", None)
        assert self.ingress is not None, (
            "ServingOrchestrator needs an Ingress — pass ingress= or a "
            "ServingPolicy built with one")
        if self.ingress.metrics is None:
            self.ingress.metrics = self.metrics
        self.tick = tick
        self._tick_now = 0.0
        self._idle_skipped = 0.0

    def snapshot(self) -> MetricsSnapshot:
        """The serving tier's typed observability record — the shared
        rollout gauges tagged ``source="serving"`` with the per-tenant
        records nested as children."""
        return self.metrics.snapshot(source="serving")

    # -- the serving clock -------------------------------------------------

    @property
    def now(self) -> float:
        """Simulated serving time: the engine's virtual clock plus skipped
        idle gaps, or the fixed-tick clock for wall-clock engines."""
        if self.tick is not None:
            return self._tick_now
        return self.engine.clock + self._idle_skipped

    def _advance_to(self, t: float) -> None:
        if self.tick is not None:
            self._tick_now = max(self._tick_now, t)
        else:
            self._idle_skipped += max(0.0, t - self.now)

    def _autoscale_queue_stats(self) -> tuple:
        """Backlog pressure for the queue_depth autoscaler: total queued
        requests, the oldest head wait, and the worst head wait as a
        fraction of its tenant's latency SLO — ages measured on the
        *serving* clock (arrivals live on it), not the engine clock."""
        now = self.now
        backlog, oldest, pressure = 0, 0.0, 0.0
        for name, q in self.ingress.queues.items():
            backlog += len(q)
            head = q.head()
            if head is None:
                continue
            wait = max(0.0, now - head.t_arrival)
            oldest = max(oldest, wait)
            slo = self.ingress.specs[name].latency_slo
            if slo:
                pressure = max(pressure, wait / slo)
        return backlog, oldest, pressure

    # -- the loop ----------------------------------------------------------

    def run_for(self, sim_time: Optional[float] = None,
                n_arrivals: Optional[int] = None) -> RolloutMetrics:
        """Serve until ``sim_time`` simulated seconds have passed and/or
        ``n_arrivals`` further arrival events have been taken, then drain:
        deliver + finish everything admitted, train every leftover, and
        return the metrics.  At least one bound is required — the loop is
        otherwise literally endless."""
        assert sim_time is not None or n_arrivals is not None, \
            "run_for needs a bound: sim_time and/or n_arrivals"
        ing = self.ingress
        if n_arrivals is not None:
            budget = ing.arrival_count + n_arrivals
            ing.max_arrivals = (budget if ing.max_arrivals is None
                                else min(ing.max_arrivals, budget))
        t_stop = self.now + sim_time if sim_time is not None else None
        stagnant = 0
        last_sig = None
        while True:
            if t_stop is not None and self.now >= t_stop and not ing.closed:
                ing.close()
            ing.pump(self.now)
            self._fill_engine()
            if self.engine.active_uids():
                t0 = self.engine.clock
                events = self.engine.step()
                if self.tick is not None:
                    self._tick_now += self.tick
                self._apply_events(events, t0)
                self._maybe_harvest()
                self._train_continuous()
            else:
                self._train_continuous()
                nt = ing.next_arrival_time()
                if nt is not None and (t_stop is None or nt <= t_stop):
                    self._advance_to(nt)     # idle until the next arrival
                elif t_stop is not None and self.now < t_stop:
                    self._advance_to(t_stop)  # idle out the serving window
                elif (ing.drained() and not self.buffer.pending()
                        and not self.buffer.running()):
                    break                    # stream over, engine drained
                elif self.engine.free_slots() <= 0:
                    break                    # fleet dead: nothing can decode
            sig = (ing.arrival_count, len(ing.events),
                   self.metrics.tokens_generated, self.metrics.updates,
                   self.metrics.harvests, len(self.buffer.entries), self.now)
            stagnant = stagnant + 1 if sig == last_sig else 0
            last_sig = sig
            if stagnant >= STAGNATION_LIMIT:
                raise RuntimeError(
                    f"serving loop wedged (no progress for {stagnant} "
                    f"iterations): {sig}")
        self._train_continuous(final=True)
        return self.metrics

    # -- harvest / train (continuous variants) -----------------------------

    def _maybe_harvest(self) -> None:
        if not self.policy.early_termination:
            return
        if not self.buffer.running():
            return        # nothing to interrupt — don't count a harvest
        threshold = min(self.cfg.resolved_threshold(),
                        len(self.buffer.unconsumed()))
        if self.policy.harvest_now(self._view(threshold)):
            self._harvest_stragglers()

    def _train_continuous(self, final: bool = False) -> int:
        if not final and len(self.buffer.done()) < self.cfg.update_batch:
            return 0
        n = self.train_ready(final=final)
        # prune consumed entries in place of advance_group (continuous
        # batching has no epoch): memory stays bounded, group_epoch
        # stays 0, and the buffer's lifecycle invariant holds trivially
        self.buffer.entries = {u: e for u, e in self.buffer.entries.items()
                               if e.state != EntryState.CONSUMED}
        return n

    # -- per-tenant accounting ---------------------------------------------

    def _apply_events(self, events, t0: float) -> None:
        super()._apply_events(events, t0)
        now = self.now
        ing = self.ingress
        for ev in events:
            e = self.buffer.entries.get(ev.uid)
            meta = e.meta if e is not None else None
            tenant = getattr(meta, "tenant", None)
            if tenant is None:
                continue
            st = self.metrics.tenant(tenant)
            st.tokens += 1
            if ev.done:
                st.completed += 1
                t_admit = (meta.t_admit if meta.t_admit is not None
                           else meta.t_arrival)
                st.queue_wait.add(t_admit - meta.t_arrival)
                st.latency.add(now - meta.t_arrival)
                if meta.deadline is not None and now > meta.deadline:
                    st.slo_misses += 1
                ing.record("done", tenant, meta.seq, now)
        # bubble attribution: idle-slot time is charged to the tenants
        # whose queued work COULD have filled those slots (equal split
        # across backlogged tenants); with no backlog the idle time is
        # nobody's fault — there was nothing to run
        # count distinct busy slots, not events: async micro-steps emit
        # >1 event per uid per group step, so len(events) overstates
        # occupancy and clamps idle to 0, under-charging bubble_time
        dt = self.engine.clock - t0
        busy = len({ev.uid for ev in events})
        idle = max(0, self.engine.capacity - busy)
        if idle and dt > 0:
            waiting = [n for n, q in ing.queues.items() if len(q)]
            if waiting:
                share = idle * dt / len(waiting)
                for name in waiting:
                    self.metrics.tenant(name).bubble_time += share

    def _update_request(self, entries, final: bool) -> UpdateRequest:
        for e in entries:
            tenant = getattr(e.meta, "tenant", None)
            if tenant is not None:
                self.metrics.tenant(tenant).consumed += 1
        return super()._update_request(entries, final)


__all__ = ["ServingOrchestrator", "STAGNATION_LIMIT"]
