"""Always-on serving tier: streaming ingress, multi-tenant admission
control, and continuous batching over the rollout orchestrator.

Importing this package registers the ``"serving"`` scheduler policy and
the admission controllers (``fifo`` / ``weighted_fair`` / ``slo_aware``);
``repro.core.policy`` loads it lazily on first registry use, so
``make_policy("serving")`` works without an explicit import.
"""
from repro.serve.arrivals import (Arrival, BurstyArrivals, PoissonArrivals,
                                  TraceArrivals, default_prompt_sampler,
                                  make_arrivals, record_trace)
from repro.serve.serving import ServingOrchestrator
from repro.serve.tenants import (Ingress, QueuedRequest, ServeMeta,
                                 ServingPolicy, TenantQueue, TenantSpec,
                                 available_admissions, coerce_specs,
                                 make_admission, register_admission)

__all__ = [
    "Arrival", "BurstyArrivals", "PoissonArrivals", "TraceArrivals",
    "default_prompt_sampler", "make_arrivals", "record_trace",
    "ServingOrchestrator",
    "Ingress", "QueuedRequest", "ServeMeta", "ServingPolicy",
    "TenantQueue", "TenantSpec", "available_admissions", "coerce_specs",
    "make_admission", "register_admission",
]
