"""End-to-end RL loop: wires task generator -> SortedRL controller ->
SlotEngine -> RLTrainer at CPU-trainable scale.  This is the live
counterpart of the paper's LogicRL experiment (§4.2): a small decoder LM,
Knights & Knaves puzzles, Reinforce++ with DAPO tricks, and the three
scheduling strategies (baseline / on-policy / partial).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttnConfig, ModelConfig
from repro.core.buffer import Mode, StatefulRolloutBuffer
from repro.core.controller import (CanonicalController, SortedRLConfig,
                                   SortedRLController)
from repro.data import logic
from repro.data.tokenizer import Vocab
from repro.models.model import Model, build_model
from repro.rl.losses import LossConfig
from repro.rl.trainer import RLTrainer
from repro.rollout.engine import SlotEngine
from repro.train.optimizer import AdamWConfig


def tiny_lm_config(vocab_size: int, d_model: int = 128, layers: int = 4,
                   heads: int = 4) -> ModelConfig:
    return ModelConfig(
        name="tiny-lm", family="dense", num_layers=layers, d_model=d_model,
        num_heads=heads, num_kv_heads=heads, d_ff=4 * d_model,
        vocab_size=vocab_size, attn=AttnConfig(rope_theta=10_000.0),
        tie_embeddings=True, param_dtype=jnp.float32,
        compute_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# SFT warm-up (plays the role of starting from an instruct checkpoint)
# ---------------------------------------------------------------------------

def sft_warmup(model: Model, params, examples: Sequence[Tuple[List[int],
                                                              List[int]]],
               pad_id: int, steps: int = 200, batch_size: int = 32,
               lr: float = 1e-3, seed: int = 0, width: int = 96):
    from repro.train.optimizer import adamw_update, init_opt_state
    opt_cfg = AdamWConfig(lr=lr, grad_clip=1.0)
    opt_state = init_opt_state(params, opt_cfg)
    rng = np.random.RandomState(seed)

    def loss_fn(p, tokens, mask):
        logits, _ = model.forward(p, {"tokens": tokens})
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        tgt = tokens[:, 1:]
        lp_t = jnp.take_along_axis(lp[:, :-1], tgt[:, :, None], 2)[..., 0]
        m = mask[:, 1:]
        return -(lp_t * m).sum() / jnp.maximum(m.sum(), 1.0)

    @jax.jit
    def step_fn(p, o, tokens, mask):
        loss, grads = jax.value_and_grad(loss_fn)(p, tokens, mask)
        p, o, _ = adamw_update(p, grads, o, opt_cfg)
        return p, o, loss

    losses = []
    for s in range(steps):
        idx = rng.randint(0, len(examples), batch_size)
        toks = np.full((batch_size, width), pad_id, np.int32)
        mask = np.zeros((batch_size, width), np.float32)
        for i, j in enumerate(idx):
            prompt, target = examples[j]
            seq = (prompt + target)[:width]
            toks[i, :len(seq)] = seq
            mask[i, len(prompt):len(seq)] = 1.0
        params, opt_state, loss = step_fn(params, opt_state,
                                          jnp.asarray(toks),
                                          jnp.asarray(mask))
        losses.append(float(loss))
    return params, losses


# ---------------------------------------------------------------------------
# Evaluation: greedy decode through the engine
# ---------------------------------------------------------------------------

def evaluate(model: Model, params, vocab: Vocab, prompts, metas,
             reward_fn, max_gen: int = 24, max_total: int = 128) -> Dict:
    eng = SlotEngine(model, lambda: params, capacity=len(prompts),
                     max_total_len=max_total, max_gen_len=max_gen,
                     eos_id=vocab.eos_id, pad_id=vocab.pad_id,
                     temperature=0.0)
    from repro.core.buffer import BufferEntry
    entries = [BufferEntry(uid=i, prompt=list(p), meta=m)
               for i, (p, m) in enumerate(zip(prompts, metas))]
    eng.submit(entries, version=0)
    gen: Dict[int, List[int]] = {e.uid: [] for e in entries}
    while eng.active_uids():
        for ev in eng.step():
            gen[ev.uid].append(ev.token)
    rewards = [reward_fn(gen[e.uid], e.meta) for e in entries]
    return {
        "reward_mean": float(np.mean(rewards)),
        "solve_rate": float(np.mean([r >= 1.2 for r in rewards])),
        "gen_len_mean": float(np.mean([len(g) for g in gen.values()])),
    }


# ---------------------------------------------------------------------------
# Full RL experiment
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RLExperimentConfig:
    strategy: str = "sorted"          # sorted | baseline | posthoc_sort
    mode: Mode = Mode.ON_POLICY
    rollout_batch: int = 32           # engine capacity (slots)
    group_size: int = 2
    update_batch: int = 32
    max_gen_len: int = 24
    max_total_len: int = 160
    n_groups: int = 4
    sft_steps: int = 150
    lr: float = 3e-4
    temperature: float = 1.0
    seed: int = 0
    d_model: int = 128
    layers: int = 4
    eval_every: int = 4               # updates between evals
    eval_size: int = 64
    # paper LogicRL setting: k responses per prompt (duplicated entries
    # sharing prompt_id -> grpo groups or reinforce++ batch stats)
    responses_per_prompt: int = 1
    advantage_kind: str = "reinforce_pp"   # reinforce_pp | grpo


def run_logic_rl(cfg: RLExperimentConfig) -> Dict:
    vocab = logic.VOCAB
    model = build_model(tiny_lm_config(len(vocab), cfg.d_model, cfg.layers))
    key = jax.random.PRNGKey(cfg.seed)
    params = model.init_params(key)

    gen = logic.LogicTaskGenerator(seed=cfg.seed)
    sft_examples = [gen.sft_example() for _ in range(2048)]
    params, sft_losses = sft_warmup(model, params, sft_examples,
                                    vocab.pad_id, steps=cfg.sft_steps,
                                    seed=cfg.seed)

    reward_fn = lambda toks, meta: logic.verify(toks, meta, vocab)
    trainer = RLTrainer(model, params, reward_fn,
                        loss_cfg=LossConfig(),
                        opt_cfg=AdamWConfig(lr=cfg.lr),
                        pad_id=vocab.pad_id, max_len=cfg.max_total_len,
                        advantage_kind=cfg.advantage_kind,
                        responses_per_prompt=cfg.responses_per_prompt)

    engine = SlotEngine(model, trainer.params, capacity=cfg.rollout_batch,
                        max_total_len=cfg.max_total_len,
                        max_gen_len=cfg.max_gen_len, eos_id=vocab.eos_id,
                        pad_id=vocab.pad_id, temperature=cfg.temperature,
                        seed=cfg.seed)
    buffer = StatefulRolloutBuffer(cfg.mode)
    scfg = SortedRLConfig(mode=cfg.mode, rollout_batch=cfg.rollout_batch,
                          group_size=cfg.group_size,
                          update_batch=cfg.update_batch,
                          max_gen_len=cfg.max_gen_len)

    eval_gen = logic.LogicTaskGenerator(seed=9999)
    eval_prompts, eval_metas = eval_gen.batch(cfg.eval_size)
    evals: List[Dict] = []

    def train_fn(entries, version):
        rec = trainer.update(entries, version)
        if trainer.state.step % cfg.eval_every == 0:
            ev = evaluate(model, trainer.params(), vocab, eval_prompts,
                          eval_metas, reward_fn, cfg.max_gen_len,
                          cfg.max_total_len)
            ev["step"] = trainer.state.step
            evals.append(ev)

    if cfg.strategy == "sorted":
        ctl = SortedRLController(engine, buffer, scfg, train_fn)
    else:
        ctl = CanonicalController(engine, buffer, scfg, train_fn,
                                  sort_post_hoc=(cfg.strategy
                                                 == "posthoc_sort"))

    t0 = time.monotonic()
    for g in range(cfg.n_groups):
        # equal data across strategies: every group consumes
        # rollout_batch * group_size prompts (the baseline submits them to
        # the same-capacity engine and runs group_size off-policy updates,
        # matching the paper's rollout-512/update-128 setting)
        n = scfg.rollout_batch * scfg.group_size
        k = max(1, cfg.responses_per_prompt)
        prompts, metas = gen.batch(n // k)
        prompts = [list(p) for p in prompts for _ in range(k)]
        metas = [m for m in metas for _ in range(k)]
        ctl.run_group(prompts, metas)

    final_eval = evaluate(model, trainer.params(), vocab, eval_prompts,
                          eval_metas, reward_fn, cfg.max_gen_len,
                          cfg.max_total_len)
    return {
        "strategy": cfg.strategy,
        "mode": cfg.mode.value,
        "sft_loss_final": sft_losses[-1] if sft_losses else None,
        "history": trainer.history,
        "evals": evals,
        "final_eval": final_eval,
        "rollout_metrics": ctl.metrics.summary(),
        "wall_time_s": round(time.monotonic() - t0, 1),
    }


# ---------------------------------------------------------------------------
# Math task variant (paper §4.3 analog, integer-answer verification)
# ---------------------------------------------------------------------------

def run_math_rl(cfg: RLExperimentConfig) -> Dict:
    """Same pipeline on the synthetic integer-math task (DAPO-Math analog):
    exact-match rule-based rewards, deeper expressions -> longer prompts,
    the same three scheduling strategies."""
    from repro.data import math_synth
    vocab = math_synth.MATH_VOCAB
    model = build_model(tiny_lm_config(len(vocab), cfg.d_model, cfg.layers))
    key = jax.random.PRNGKey(cfg.seed)
    params = model.init_params(key)

    gen = math_synth.MathTaskGenerator(seed=cfg.seed)
    sft_examples = [gen.sft_example() for _ in range(2048)]
    params, sft_losses = sft_warmup(model, params, sft_examples,
                                    vocab.pad_id, steps=cfg.sft_steps,
                                    seed=cfg.seed, width=64)

    reward_fn = lambda toks, meta: math_synth.verify(toks, meta, vocab)
    trainer = RLTrainer(model, params, reward_fn, loss_cfg=LossConfig(),
                        opt_cfg=AdamWConfig(lr=cfg.lr),
                        pad_id=vocab.pad_id, max_len=cfg.max_total_len,
                        advantage_kind=cfg.advantage_kind,
                        responses_per_prompt=cfg.responses_per_prompt)
    engine = SlotEngine(model, trainer.params, capacity=cfg.rollout_batch,
                        max_total_len=cfg.max_total_len,
                        max_gen_len=cfg.max_gen_len, eos_id=vocab.eos_id,
                        pad_id=vocab.pad_id, temperature=cfg.temperature,
                        seed=cfg.seed)
    buffer = StatefulRolloutBuffer(cfg.mode)
    scfg = SortedRLConfig(mode=cfg.mode, rollout_batch=cfg.rollout_batch,
                          group_size=cfg.group_size,
                          update_batch=cfg.update_batch,
                          max_gen_len=cfg.max_gen_len)
    from repro.data.loader import GroupedLoader
    loader = GroupedLoader(gen, cfg.rollout_batch, cfg.group_size,
                           cfg.responses_per_prompt)

    eval_gen = math_synth.MathTaskGenerator(seed=9999)
    eval_prompts, eval_metas = eval_gen.batch(cfg.eval_size)

    def train_fn(entries, version):
        trainer.update(entries, version)

    if cfg.strategy == "sorted":
        ctl = SortedRLController(engine, buffer, scfg, train_fn)
    else:
        ctl = CanonicalController(engine, buffer, scfg, train_fn,
                                  sort_post_hoc=(cfg.strategy
                                                 == "posthoc_sort"))
    t0 = time.monotonic()
    for g in range(cfg.n_groups):
        prompts, metas = loader.next_group()
        ctl.run_group(prompts, metas)
    final_eval = evaluate(model, trainer.params(), vocab, eval_prompts,
                          eval_metas, reward_fn, cfg.max_gen_len,
                          cfg.max_total_len)
    return {"strategy": cfg.strategy, "mode": cfg.mode.value,
            "history": trainer.history, "final_eval": final_eval,
            "rollout_metrics": ctl.metrics.summary(),
            "wall_time_s": round(time.monotonic() - t0, 1)}
