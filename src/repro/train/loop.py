"""End-to-end RL loop — back-compat wrappers over the one-call session
builder.

The two near-duplicate drivers this module used to contain
(``run_logic_rl`` / ``run_math_rl``) are now a single parameterized
pipeline in :mod:`repro.rl.session`; each wrapper here just maps the
historical :class:`RLExperimentConfig` onto a
:class:`~repro.rl.session.SessionConfig`.  ``tiny_lm_config``,
``sft_warmup``, and ``evaluate`` also moved there and are re-exported for
existing imports.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.buffer import Mode
from repro.rl.session import (RLSession, SessionConfig, evaluate,  # noqa: F401
                              sft_warmup, tiny_lm_config)

__all__ = ["RLExperimentConfig", "run_logic_rl", "run_math_rl",
           "tiny_lm_config", "sft_warmup", "evaluate"]


@dataclasses.dataclass
class RLExperimentConfig:
    strategy: str = "sorted"          # any repro.core.policy registry name
    mode: Mode = Mode.ON_POLICY
    rollout_batch: int = 32           # engine capacity (slots)
    group_size: int = 2
    update_batch: int = 32
    max_gen_len: int = 24
    max_total_len: int = 160
    n_groups: int = 4
    sft_steps: int = 150
    lr: float = 3e-4
    temperature: float = 1.0
    seed: int = 0
    d_model: int = 128
    layers: int = 4
    eval_every: int = 4               # updates between evals
    eval_size: int = 64
    # paper LogicRL setting: k responses per prompt (duplicated entries
    # sharing prompt_id -> grpo groups or reinforce++ batch stats)
    responses_per_prompt: int = 1
    advantage_kind: str = "reinforce_pp"   # reinforce_pp | grpo


def _session_config(cfg: RLExperimentConfig, task: str) -> SessionConfig:
    return SessionConfig(
        task=task, policy=cfg.strategy, mode=cfg.mode,
        rollout_batch=cfg.rollout_batch, group_size=cfg.group_size,
        update_batch=cfg.update_batch, max_gen_len=cfg.max_gen_len,
        max_total_len=cfg.max_total_len, n_groups=cfg.n_groups,
        sft_steps=cfg.sft_steps, lr=cfg.lr, temperature=cfg.temperature,
        seed=cfg.seed, d_model=cfg.d_model, layers=cfg.layers,
        eval_every=cfg.eval_every, eval_size=cfg.eval_size,
        responses_per_prompt=cfg.responses_per_prompt,
        advantage_kind=cfg.advantage_kind)


def run_logic_rl(cfg: RLExperimentConfig) -> Dict:
    """Paper §4.2 analog (Knights & Knaves) under any registered policy."""
    return RLSession.from_config(_session_config(cfg, "logic")).run()


def run_math_rl(cfg: RLExperimentConfig) -> Dict:
    """Paper §4.3 analog (integer-answer math) under any registered
    policy."""
    return RLSession.from_config(_session_config(cfg, "math")).run()
