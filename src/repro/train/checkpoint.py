"""Checkpointing: flat .npz of the (params, opt_state) pytree plus a JSON
manifest.  Dependency-free (no orbax in the container) but preserves the
tree structure exactly via path-encoded keys.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, params: Any, opt_state: Any = None,
         meta: Dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = {f"p/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        arrays.update({f"o/{k}": v for k, v in _flatten(opt_state).items()})
    np.savez(path, **arrays)
    with open(path + ".json", "w") as f:
        json.dump({"meta": meta or {},
                   "dtypes": {k: str(v.dtype) for k, v in arrays.items()}},
                  f)


def restore(path: str, params_template: Any,
            opt_template: Any = None) -> Tuple[Any, Any]:
    data = np.load(path if path.endswith(".npz") else path + ".npz")

    def rebuild(template, prefix):
        leaves_paths = jax.tree_util.tree_flatten_with_path(template)
        rebuilt = []
        for path_, leaf in leaves_paths[0]:
            key = prefix + "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
            arr = jnp.asarray(data[key]).astype(leaf.dtype)
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            rebuilt.append(arr)
        return jax.tree_util.tree_unflatten(leaves_paths[1], rebuilt)

    params = rebuild(params_template, "p/")
    opt = rebuild(opt_template, "o/") if opt_template is not None else None
    return params, opt
