"""AdamW from scratch (no optax in the container), with configurable state
dtype (bf16 moments for the >=100B archs so AdamW fits v5e HBM — see
DESIGN.md §5) and global-norm gradient clipping.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32     # bf16 for the huge configs
    warmup_steps: int = 0
    total_steps: int = 0               # 0: constant lr after warmup


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init_opt_state(params: Any, cfg: AdamWConfig) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=cfg.state_dtype)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def _schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.warmup_steps:
        lr = lr * jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    if cfg.total_steps:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
        lr = lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return lr


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params: Any, grads: Any, state: OptState, cfg: AdamWConfig
                 ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.where(gnorm > cfg.grad_clip, cfg.grad_clip / (gnorm + 1e-9),
                      1.0) if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * jnp.square(g)
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
