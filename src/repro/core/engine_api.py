"""Engine protocol shared by the real JAX slot engine (repro.rollout.engine)
and the discrete-event simulator (repro.rollout.sim).

The controller only speaks this interface, so scheduling policies are
validated against the simulator and executed unchanged against the real
engine — the co-design the paper's infrastructure section describes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Protocol, Sequence

from repro.core.buffer import BufferEntry


@dataclasses.dataclass
class StepEvent:
    """One slot's outcome for one decode step."""
    uid: int
    token: int
    logprob: float
    done: bool
    finish_reason: Optional[str] = None   # set when done


class EngineProtocol(Protocol):
    capacity: int            # Q — max concurrent requests (slot count)

    @property
    def clock(self) -> float:                     # seconds (real or virtual)
        ...

    def free_slots(self) -> int: ...

    def active_uids(self) -> List[int]: ...

    def submit(self, entries: Sequence[BufferEntry], version: int) -> None:
        """Prefill prompts (plus any scavenged prefix in partial mode) into
        free slots.  Raises if not enough slots."""
        ...

    def step(self) -> List[StepEvent]:
        """Advance every active slot one token.  Completed slots are freed
        and reported with done=True."""
        ...

    def interrupt(self, uids: Optional[Sequence[int]] = None) -> List[int]:
        """Early termination: stop the given (default: all) active requests,
        free their slots, and return their uids.  Generated tokens were
        already reported through step()."""
        ...

    def sync_weights(self, version: int) -> None:
        """Make the engine generate with the given policy version (weight
        sync after a trainer update).  The real engine shares the
        TrainState so this is O(1); the simulator models a latency."""
        ...
