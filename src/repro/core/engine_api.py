"""Engine protocol shared by the real JAX slot engine (repro.rollout.engine)
and the discrete-event simulator (repro.rollout.sim).

The controller only speaks this interface, so scheduling policies are
validated against the simulator and executed unchanged against the real
engine — the co-design the paper's infrastructure section describes.

Beyond the required surface, engines may implement an optional
**migration capability** discovered by duck typing (used by
``repro.rollout.group.EngineGroup`` for work stealing and drain-phase
tail packing):

  * ``export_entry(uid) -> Optional[dict]`` — snapshot an in-flight slot
    or resident KV for transfer (pure read; ``None`` = unsupported);
  * ``import_entry(handle) -> bool`` — land the snapshot here, False
    (engine unchanged) when it cannot accept;
  * ``discard_entry(uid)`` — drop the donor copy once accepted.

Engines without these methods simply never migrate (the group falls back
to release-and-re-prefill).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.buffer import BufferEntry


@dataclasses.dataclass
class StepEvent:
    """One slot's outcome for one decode step."""
    uid: int
    token: int
    logprob: float
    done: bool
    finish_reason: Optional[str] = None   # set when done


class SlotTable:
    """Structure-of-arrays host state for a fixed pool of decode slots.

    Shared by the real SlotEngine (where rows mirror the device KV cache)
    and the SimEngine (where ``kv_start``/``gen_budget`` double as the
    scavenged prefix and the hidden length target).  All mutators take
    index *arrays*, so an engine can retire or advance every slot of a
    step in a handful of numpy ops instead of a per-slot Python loop.

    Event-order contract: engines emit StepEvents in ascending slot
    order (the order of :meth:`active_indices`), which is stable across
    steps for as long as a request occupies its slot.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.uid = np.full(capacity, -1, np.int64)
        self.active = np.zeros(capacity, bool)
        self.next_token = np.zeros(capacity, np.int32)
        self.kv_len = np.zeros(capacity, np.int32)
        self.kv_start = np.zeros(capacity, np.int32)
        self.gen_count = np.zeros(capacity, np.int32)
        self.gen_budget = np.zeros(capacity, np.int32)

    # -- queries ----------------------------------------------------------

    def free_count(self) -> int:
        return int((~self.active).sum())

    def free_indices(self) -> np.ndarray:
        return np.flatnonzero(~self.active)

    def active_indices(self) -> np.ndarray:
        return np.flatnonzero(self.active)

    def active_uids(self) -> List[int]:
        return [int(u) for u in self.uid[self.active]]

    def select(self, uids: Optional[Sequence[int]] = None) -> np.ndarray:
        """Active slot indices, optionally filtered to the given uids."""
        act = self.active_indices()
        if uids is None:
            return act
        wanted = np.asarray(list(uids), np.int64)
        return act[np.isin(self.uid[act], wanted)]

    # -- mutators ---------------------------------------------------------

    def allocate(self, k: int) -> np.ndarray:
        """Lowest k free slot indices (raises if oversubscribed)."""
        free = self.free_indices()
        assert k <= len(free), "not enough free slots"
        return free[:k]

    def release(self, slots: np.ndarray) -> None:
        self.active[slots] = False
        self.uid[slots] = -1


@runtime_checkable
class EngineProtocol(Protocol):
    capacity: int            # Q — max concurrent requests (slot count)

    @property
    def clock(self) -> float:                     # seconds (real or virtual)
        ...

    def free_slots(self) -> int: ...

    def active_uids(self) -> List[int]: ...

    def submit(self, entries: Sequence[BufferEntry], version: int) -> None:
        """Prefill prompts (plus any scavenged prefix in partial mode) into
        free slots.  Raises if not enough slots."""
        ...

    def step(self) -> List[StepEvent]:
        """Advance every active slot one token.  Completed slots are freed
        and reported with done=True."""
        ...

    def interrupt(self, uids: Optional[Sequence[int]] = None) -> List[int]:
        """Early termination: stop the given (default: all) active requests,
        free their slots, and return their uids.  Generated tokens were
        already reported through step()."""
        ...

    def sync_weights(self, version: int) -> None:
        """Make the engine generate with the given policy version (weight
        sync after a trainer update).  The real engine shares the
        TrainState so this is O(1); the simulator models a latency."""
        ...
