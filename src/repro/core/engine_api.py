"""Engine protocol shared by the real JAX slot engine (repro.rollout.engine)
and the discrete-event simulator (repro.rollout.sim).

The controller only speaks this interface, so scheduling policies are
validated against the simulator and executed unchanged against the real
engine — the co-design the paper's infrastructure section describes.

Beyond the required surface, engines may implement an optional
**migration capability** discovered by duck typing (used by
``repro.rollout.group.EngineGroup`` for work stealing and drain-phase
tail packing):

  * ``export_entry(uid) -> Optional[dict]`` — snapshot an in-flight slot
    or resident KV for transfer (pure read; ``None`` = unsupported);
  * ``import_entry(handle) -> bool`` — land the snapshot here, False
    (engine unchanged) when it cannot accept;
  * ``discard_entry(uid)`` — drop the donor copy once accepted.

Engines without these methods simply never migrate (the group falls back
to release-and-re-prefill).

Two further optional capabilities support fault tolerance and
elasticity (again duck-typed, again optional):

  * ``throttle(factor)`` — scale the engine's decode step cost by
    ``factor`` (the simulator models a degraded replica; engines on a
    real wall clock may ignore it);
  * ``shutdown()`` — fence the engine: release every slot and drop all
    resident KV, so a killed or scaled-down replica holds no pages.

:class:`FaultInjector` is the deterministic fault plan the
:class:`~repro.rollout.group.EngineGroup` consults at each group step —
it decides WHEN a replica is killed / stalled / slowed; the group owns
HOW (re-homing, re-roll, accounting).
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.buffer import BufferEntry


# -----------------------------------------------------------------------------
# fault injection (chaos testing surface)
# -----------------------------------------------------------------------------

FAULT_KINDS = ("kill", "stall", "slow")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault against one replica of an EngineGroup.

    ``step`` is the 1-based group step index at which the fault fires
    (faults apply at the START of that ``step()`` call, before any
    replica is dispatched).  Kinds:

      * ``kill``  — the replica fails permanently (fail-stop, detected
        at the step boundary).  Its in-flight uids are re-homed to
        survivors (KV migrated when the group runs ``migrate_kv=True``)
        or released for a re-roll under the current policy version;
      * ``stall`` — the replica makes no progress for ``duration`` group
        steps, then resumes (a hung collective / network partition);
      * ``slow``  — the replica's decode step cost is multiplied by
        ``factor`` for ``duration`` group steps (thermal throttling, a
        degraded host).  Ignored by engines without ``throttle()``.
    """
    step: int
    replica: int
    kind: str
    duration: int = 1
    factor: float = 4.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.step < 1:
            raise ValueError(f"fault step must be >= 1, got {self.step}")


class FaultInjector:
    """A deterministic fault plan: which replica fails, how, and at which
    group step.  Purely declarative — the EngineGroup polls :meth:`due`
    once per ``step()`` and applies the returned faults itself, so the
    same plan replayed against the same workload produces the same run.

    Accepts :class:`FaultEvent` instances or plain tuples
    ``(step, replica, kind[, duration[, factor]])`` (the
    ``SessionConfig.fault_plan`` wire format).
    """

    def __init__(self, plan: Optional[Sequence] = None):
        events = []
        for item in (plan or []):
            if not isinstance(item, FaultEvent):
                item = FaultEvent(*item)
            events.append(item)
        self.plan: List[FaultEvent] = sorted(
            events, key=lambda f: (f.step, f.replica))
        # step -> events index: due() is polled every group step, and the
        # serving loop steps without a horizon, so the lookup must not
        # scan the whole plan each time
        self._by_step: dict = {}
        for f in self.plan:
            self._by_step.setdefault(f.step, []).append(f)

    @classmethod
    def random_plan(cls, seed: int, n_replicas: int,
                    horizon: Optional[int] = None,
                    n_faults: int = 1,
                    kinds: Sequence[str] = FAULT_KINDS,
                    max_duration: int = 4) -> "FaultInjector":
        """Seed-deterministic random plan: ``n_faults`` faults drawn
        against ``n_replicas`` replicas.  With a ``horizon`` the steps
        are uniform over ``[1, horizon]`` (finite runs); with
        ``horizon=None`` they are drawn from a geometric-shaped
        distribution with unbounded support (mean ~32 steps), so plans
        compose with the serving tier's unbounded continuous-batching
        loop — a step beyond whatever the run reaches simply never
        fires.  String seeding keeps the draw stable across processes
        and platforms."""
        rng = random.Random(f"fault-plan:{seed}")
        def draw_step() -> int:
            if horizon is None:
                return 1 + int(rng.expovariate(1.0 / 32.0))
            return rng.randint(1, max(1, horizon))
        plan = [FaultEvent(step=draw_step(),
                           replica=rng.randrange(n_replicas),
                           kind=kinds[rng.randrange(len(kinds))],
                           duration=rng.randint(1, max_duration))
                for _ in range(n_faults)]
        return cls(plan)

    def due(self, step: int) -> List[FaultEvent]:
        """Faults scheduled to fire at group step ``step`` (1-based)."""
        return self._by_step.get(step, [])


@dataclasses.dataclass
class StepEvent:
    """One slot's outcome for one decode step."""
    uid: int
    token: int
    logprob: float
    done: bool
    finish_reason: Optional[str] = None   # set when done


class SlotTable:
    """Structure-of-arrays host state for a fixed pool of decode slots.

    Shared by the real SlotEngine (where rows mirror the device KV cache)
    and the SimEngine (where ``kv_start``/``gen_budget`` double as the
    scavenged prefix and the hidden length target).  All mutators take
    index *arrays*, so an engine can retire or advance every slot of a
    step in a handful of numpy ops instead of a per-slot Python loop.

    Event-order contract: engines emit StepEvents in ascending slot
    order (the order of :meth:`active_indices`), which is stable across
    steps for as long as a request occupies its slot.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.uid = np.full(capacity, -1, np.int64)
        self.active = np.zeros(capacity, bool)
        self.next_token = np.zeros(capacity, np.int32)
        self.kv_len = np.zeros(capacity, np.int32)
        self.kv_start = np.zeros(capacity, np.int32)
        self.gen_count = np.zeros(capacity, np.int32)
        self.gen_budget = np.zeros(capacity, np.int32)

    # -- queries ----------------------------------------------------------

    def free_count(self) -> int:
        return int((~self.active).sum())

    def free_indices(self) -> np.ndarray:
        return np.flatnonzero(~self.active)

    def active_indices(self) -> np.ndarray:
        return np.flatnonzero(self.active)

    def active_uids(self) -> List[int]:
        return [int(u) for u in self.uid[self.active]]

    def select(self, uids: Optional[Sequence[int]] = None) -> np.ndarray:
        """Active slot indices, optionally filtered to the given uids."""
        act = self.active_indices()
        if uids is None:
            return act
        wanted = np.asarray(list(uids), np.int64)
        return act[np.isin(self.uid[act], wanted)]

    # -- mutators ---------------------------------------------------------

    def allocate(self, k: int) -> np.ndarray:
        """Lowest k free slot indices (raises if oversubscribed)."""
        free = self.free_indices()
        assert k <= len(free), "not enough free slots"
        return free[:k]

    def release(self, slots: np.ndarray) -> None:
        self.active[slots] = False
        self.uid[slots] = -1


@runtime_checkable
class EngineProtocol(Protocol):
    capacity: int            # Q — max concurrent requests (slot count)

    @property
    def clock(self) -> float:                     # seconds (real or virtual)
        ...

    def free_slots(self) -> int: ...

    def active_uids(self) -> List[int]: ...

    def submit(self, entries: Sequence[BufferEntry], version: int) -> None:
        """Prefill prompts (plus any scavenged prefix in partial mode) into
        free slots.  Raises if not enough slots."""
        ...

    def step(self) -> List[StepEvent]:
        """Advance every active slot one token.  Completed slots are freed
        and reported with done=True."""
        ...

    def interrupt(self, uids: Optional[Sequence[int]] = None) -> List[int]:
        """Early termination: stop the given (default: all) active requests,
        free their slots, and return their uids.  Generated tokens were
        already reported through step()."""
        ...

    def sync_weights(self, version: int) -> None:
        """Make the engine generate with the given policy version (weight
        sync after a trainer update).  The real engine shares the
        TrainState so this is O(1); the simulator models a latency."""
        ...
