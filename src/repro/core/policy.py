"""Scheduling policies: the part of the controller family that actually
differs between strategies, split out from the orchestration mechanics
(see repro.core.orchestrator).

A :class:`SchedulerPolicy` answers five questions; everything else
(engine feeding, event plumbing, scavenging, metrics, weight sync, group
advancement) is owned by the :class:`~repro.core.orchestrator.RolloutOrchestrator`:

  * ``select_fill(pending, free_slots)`` — which pending entries take the
    freed engine slots (oversubscription order);
  * ``harvest_now(view)`` — when to stop decoding and early-terminate the
    stragglers (paper §3.1 step 2; ``False`` forever = wait-for-all
    baseline);
  * ``train_order_key(entry)`` / ``order_ready(ready, view)`` — how ready
    trajectories are ordered into update batches (the micro-curriculum);
  * ``admit_next_group(view)`` — whether/what new prompts may enter the
    buffer outside the strict group barrier (ungrouped streaming, pipelined
    lookahead);
  * ``update_gate(request)`` — PipelineRL-style off-policy control: veto a
    too-stale update batch before it reaches the trainer.

Policies are registered by name so benchmarks, CLIs, and configs select
them declaratively::

    from repro.core.policy import make_policy
    policy = make_policy("sorted", fill_policy="fresh_first")

Writing a new strategy is ~30 lines: subclass :class:`BasePolicy`,
override the hooks that differ, and decorate with ``@register_policy``
(see :class:`LengthBinPackingPolicy` for a worked example).
"""
from __future__ import annotations

import dataclasses
import heapq
import random
from typing import (TYPE_CHECKING, Any, Callable, Dict, Iterator, List,
                    Optional, Protocol, Sequence, Tuple, runtime_checkable)

from repro.core.buffer import BufferEntry

if TYPE_CHECKING:   # avoid the policy<->orchestrator import cycle
    from repro.core.orchestrator import UpdateRequest


@dataclasses.dataclass(frozen=True)
class SchedView:
    """Read-only scheduling snapshot handed to policy hooks.

    Counts only — policies decide, the orchestrator mutates.
    """
    pending: int              # entries waiting for a slot
    running: int              # entries occupying slots
    done: int                 # finished, awaiting training
    unconsumed: int           # pending + running + done
    free_slots: int
    capacity: int
    group_epoch: int
    version: int              # trainer policy version
    update_batch: int
    harvest_threshold: int    # resolved target for this rollout phase
    next_epoch_load_allowed: bool = True   # lookahead budget not exhausted
    # current-epoch variants (== the totals unless a relaxed-barrier
    # policy admitted next-group entries early)
    done_current: int = 0
    unconsumed_current: int = 0


@dataclasses.dataclass
class AdmitRequest:
    """Prompts a policy wants loaded into the buffer outside run_group."""
    prompts: List[List[int]]
    metas: Optional[List[Any]] = None
    next_epoch: bool = False   # load as group_epoch + 1 (pipelined lookahead)


@runtime_checkable
class SchedulerPolicy(Protocol):
    """The hooks that differ between scheduling strategies."""

    name: str
    early_termination: bool     # harvest interrupts + scavenges stragglers
    strict_group_barrier: bool  # advance_group asserts full consumption
    ordered_training: bool      # order_ready is monotone in train_order_key

    def select_fill(self, pending: Sequence[BufferEntry],
                    free_slots: int) -> List[BufferEntry]: ...

    def harvest_now(self, view: SchedView) -> bool: ...

    def train_order_key(self, entry: BufferEntry) -> Any: ...

    def order_ready(self, ready: Sequence[BufferEntry],
                    view: SchedView) -> List[BufferEntry]: ...

    def admit_next_group(self, view: SchedView) -> Optional[AdmitRequest]: ...

    def update_gate(self, request: "UpdateRequest") -> bool: ...


class BasePolicy:
    """Default hook implementations: SortedRL-style behaviour.

    Subclasses override only what differs; the defaults are the paper's
    length-aware strategy (resume-first fill, threshold harvest,
    shortest-first training, strict group barrier, no gate).
    """

    name = "base"
    early_termination = True
    strict_group_barrier = True
    ordered_training = True

    # -- engine feeding ----------------------------------------------------

    def select_fill(self, pending: Sequence[BufferEntry],
                    free_slots: int) -> List[BufferEntry]:
        # top-free selection, not a full sort — this runs every decode step
        return heapq.nsmallest(free_slots, pending,
                               key=lambda e: (-e.gen_len, len(e.prompt)))

    # -- harvest -----------------------------------------------------------

    def harvest_now(self, view: SchedView) -> bool:
        return view.done >= view.harvest_threshold

    # -- training order ----------------------------------------------------

    def train_order_key(self, entry: BufferEntry) -> Any:
        return entry.gen_len

    def order_ready(self, ready: Sequence[BufferEntry],
                    view: SchedView) -> List[BufferEntry]:
        return sorted(ready, key=self.train_order_key)

    # -- admission beyond the group barrier --------------------------------

    def admit_next_group(self, view: SchedView) -> Optional[AdmitRequest]:
        return None

    # -- off-policy control ------------------------------------------------

    def update_gate(self, request: "UpdateRequest") -> bool:
        return True


class DelegatingPolicy(BasePolicy):
    """A policy wrapper: every hook forwards to a wrapped ``inner``
    policy, and the contract flags mirror the inner policy's.

    This is the extension point for layers that compose *with* any
    scheduling strategy instead of replacing it — the serving tier's
    admission controllers (``repro.serve.tenants.ServingPolicy``)
    override only ``admit_next_group`` on top of this base, so fill /
    harvest / training-order behaviour stays whatever the wrapped
    strategy says.
    """

    name = "delegating"

    def __init__(self, inner: SchedulerPolicy):
        self.inner = inner
        self.early_termination = inner.early_termination
        self.strict_group_barrier = inner.strict_group_barrier
        self.ordered_training = inner.ordered_training

    def select_fill(self, pending, free_slots):
        return self.inner.select_fill(pending, free_slots)

    def harvest_now(self, view: SchedView) -> bool:
        return self.inner.harvest_now(view)

    def train_order_key(self, entry: BufferEntry):
        return self.inner.train_order_key(entry)

    def order_ready(self, ready, view: SchedView):
        return self.inner.order_ready(ready, view)

    def admit_next_group(self, view: SchedView) -> Optional[AdmitRequest]:
        return self.inner.admit_next_group(view)

    def update_gate(self, request: "UpdateRequest") -> bool:
        return self.inner.update_gate(request)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., SchedulerPolicy]] = {}

# registry entries that live outside this module (they import it, so
# they cannot be imported at module-init time without a cycle); loaded
# on first registry use so `make_policy("serving")` works everywhere
_EXTENSION_MODULES = ("repro.serve",)
_extensions_loaded = False


def _load_extensions() -> None:
    global _extensions_loaded
    if _extensions_loaded:
        return
    _extensions_loaded = True
    import importlib
    for mod in _EXTENSION_MODULES:
        importlib.import_module(mod)


def register_policy(name: str):
    """Class/factory decorator adding a policy to the by-name registry."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def make_policy(name: str, **kwargs) -> SchedulerPolicy:
    _load_extensions()
    if name not in _REGISTRY:
        raise KeyError(f"unknown policy {name!r}; "
                       f"registered: {available_policies()}")
    return _REGISTRY[name](**kwargs)


def available_policies() -> List[str]:
    _load_extensions()
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# the paper strategies (+ the beyond-paper pipelined variant)
# ---------------------------------------------------------------------------

@register_policy("sorted")
class SortedPolicy(BasePolicy):
    """Paper §3.1/§3.3 length-aware strategy.  ``fill_policy`` is the
    beyond-paper slot-fill study: 'resume_first' (default) schedules
    scavenged partials before fresh prompts — bounds their staleness and
    finishes long stragglers early; 'fresh_first' defers partials; 'fifo'
    ignores progress."""

    name = "sorted"

    def __init__(self, fill_policy: str = "resume_first"):
        assert fill_policy in ("resume_first", "fresh_first", "fifo")
        self.fill_policy = fill_policy

    def select_fill(self, pending, free_slots):
        if self.fill_policy == "resume_first":
            return heapq.nsmallest(free_slots, pending,
                                   key=lambda e: (-e.gen_len, len(e.prompt)))
        if self.fill_policy == "fresh_first":
            return heapq.nsmallest(free_slots, pending,
                                   key=lambda e: (e.gen_len, len(e.prompt)))
        return list(pending[:free_slots])   # 'fifo': keep load order


@register_policy("baseline")
class BaselinePolicy(BasePolicy):
    """Canonical baseline: FIFO fill, wait for ALL to finish (no early
    termination — the bubble), then shuffled update batches over the same
    data (off-policy when update_batch < rollout size)."""

    name = "baseline"
    early_termination = False
    ordered_training = False

    def __init__(self, shuffle_seed: int = 0):
        self.shuffle_seed = shuffle_seed

    def select_fill(self, pending, free_slots):
        return list(pending[:free_slots])

    def harvest_now(self, view: SchedView) -> bool:
        return False   # decode until the engine drains

    def order_ready(self, ready, view):
        out = list(ready)
        random.Random(self.shuffle_seed + view.version).shuffle(out)
        return out


@register_policy("posthoc_sort")
class PostHocSortPolicy(BaselinePolicy):
    """Ablation §4.4.2: same data/timing as the baseline but batches sorted
    by length after the fact — the off-policiness stays baseline-high."""

    name = "posthoc_sort"
    ordered_training = True

    def order_ready(self, ready, view):
        return sorted(ready, key=self.train_order_key)


@register_policy("ungrouped")
class UngroupedPolicy(SortedPolicy):
    """Ablation §4.4.2 «disabled grouped rollout»: oversubscription and
    shortest-first harvesting WITHOUT the group barrier — new prompts are
    admitted from ``prompt_stream`` whenever slots free up, so short
    responses dominate and long prompts starve (the collapse the paper
    shows)."""

    name = "ungrouped"
    strict_group_barrier = False

    def __init__(self, prompt_stream: Optional[
            Iterator[Tuple[List[int], Any]]] = None,
            fill_policy: str = "resume_first"):
        super().__init__(fill_policy)
        self.prompt_stream = prompt_stream   # iterator of (prompt, meta)

    def admit_next_group(self, view: SchedView) -> Optional[AdmitRequest]:
        if self.prompt_stream is None:
            return None
        prompts, metas = [], []
        # keep pulling fresh prompts — no group barrier
        while view.pending + len(prompts) < view.free_slots:
            try:
                prompt, meta = next(self.prompt_stream)
            except StopIteration:
                self.prompt_stream = None
                break
            prompts.append(prompt)
            metas.append(meta)
        return AdmitRequest(prompts, metas) if prompts else None


@register_policy("pipelined")
class PipelinedPolicy(SortedPolicy):
    """BEYOND-PAPER extension: relaxed group barrier.

    The paper's grouped loading leaves a drain bubble at each group tail
    (the last update_batch of stragglers can't fill the engine).  This
    policy admits prompts of group g+1 into otherwise-idle slots while
    group g stragglers finish.  Group-g entries still train before any
    group-g+1 entry (``train_order_key`` leads with the lifecycle), so the
    curriculum and no-starvation guarantees are preserved; only the strict
    "no new prompts until clear" rule is relaxed."""

    name = "pipelined"
    strict_group_barrier = False

    def __init__(self, lookahead: int = 1,
                 fill_policy: str = "resume_first"):
        super().__init__(fill_policy)
        if lookahead != 1:
            # the buffer's lifecycle accounting (and check_invariants)
            # supports exactly one group of lookahead
            raise NotImplementedError("pipelined lookahead is fixed at 1")
        self.lookahead = lookahead
        self._next_groups: List[Tuple[List, Optional[List]]] = []

    def queue_group(self, prompts, metas=None) -> None:
        self._next_groups.append((list(prompts), metas))

    def has_queued(self) -> bool:
        return bool(self._next_groups)

    def pop_group(self) -> Tuple[List, Optional[List]]:
        return self._next_groups.pop(0)

    def admit_next_group(self, view: SchedView) -> Optional[AdmitRequest]:
        prompts: List = []
        metas: List = []
        pending = view.pending
        # admit next-group prompts only into slots the current group
        # cannot fill
        while (view.free_slots > pending and self._next_groups
               and view.next_epoch_load_allowed):
            g_prompts, g_metas = self._next_groups[0]
            take = min(view.free_slots - pending, len(g_prompts))
            prompts.extend(g_prompts[:take])
            metas.extend(g_metas[:take] if g_metas else [None] * take)
            del g_prompts[:take]
            if g_metas:
                del g_metas[:take]
            if not g_prompts:
                self._next_groups.pop(0)
            pending += take
        if not prompts:
            return None
        return AdmitRequest(prompts, metas, next_epoch=True)

    def train_order_key(self, entry: BufferEntry):
        # strictly lifecycle-ordered so group g trains before group g+1
        # (curriculum preserved)
        return (entry.lifecycle, entry.gen_len)

    def harvest_now(self, view: SchedView) -> bool:
        # count only current-epoch completions: deferred next-group DONE
        # entries must not satisfy the threshold, or the last current-group
        # stragglers would be interrupted forever without progress
        return view.done_current >= min(view.harvest_threshold,
                                        view.unconsumed_current)

    def order_ready(self, ready, view):
        # next-epoch entries may finish early (they fill idle slots) but
        # must not TRAIN before the current group is fully consumed —
        # defer them until the orchestrator advances the epoch
        current = [e for e in ready if e.lifecycle <= view.group_epoch]
        return sorted(current, key=self.train_order_key)


@register_policy("length_binned")
class LengthBinPackingPolicy(BasePolicy):
    """Registry demo (RollPacker-flavoured): pack update batches by
    power-of-two length bin so batch members pad to the same bucket, and
    gate batches whose mean staleness exceeds ``max_staleness``
    (PipelineRL-style off-policy cap).  A new strategy really is this
    small: two hook overrides on top of :class:`BasePolicy`."""

    name = "length_binned"

    def __init__(self, bin_width_log2: int = 5,
                 max_staleness: Optional[float] = None):
        self.bin_width_log2 = bin_width_log2
        self.max_staleness = max_staleness

    def train_order_key(self, entry: BufferEntry):
        # bin index first: batches cluster into shared padding buckets
        return (entry.gen_len >> self.bin_width_log2, entry.gen_len)

    def update_gate(self, request: "UpdateRequest") -> bool:
        if self.max_staleness is None or request.final:
            return True
        return request.staleness_mean <= self.max_staleness
