"""Stateful rollout buffer (paper §3.3).

Each entry stores: the prompt context, the current partial trajectory, the
behaviour-policy log-probs for every generated token, a completion flag,
and a lifecycle indicator (the group epoch it was loaded in).  Entries are
resumed (partial mode) or re-rolled from the prompt (on-policy mode) after
early termination, and cleared once fed to the trainer.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Dict, Iterable, List, Optional, Sequence


class Mode(str, enum.Enum):
    ON_POLICY = "on_policy"   # discard partial generations; re-roll prompts
    PARTIAL = "partial"       # scavenge tokens + logprobs; resume generation


class EntryState(str, enum.Enum):
    PENDING = "pending"       # waiting to be scheduled into the engine
    RUNNING = "running"       # currently occupies an engine slot
    DONE = "done"             # finished (eos / max len); awaiting training
    CONSUMED = "consumed"     # fed to the trainer; kept only for accounting


@dataclasses.dataclass
class BufferEntry:
    uid: int
    prompt: List[int]
    meta: Any = None                       # e.g. ground truth for the verifier
    generated: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    # policy version that generated each token — the off-policiness record
    versions: List[int] = dataclasses.field(default_factory=list)
    state: EntryState = EntryState.PENDING
    finish_reason: Optional[str] = None    # "eos" | "length"
    lifecycle: int = 0                     # group epoch loaded in
    interruptions: int = 0                 # times scavenged

    @property
    def gen_len(self) -> int:
        return len(self.generated)

    @property
    def total_len(self) -> int:
        return len(self.prompt) + len(self.generated)

    def staleness(self, current_version: int) -> float:
        """Mean number of policy updates between generation and now."""
        if not self.versions:
            return 0.0
        return sum(current_version - v for v in self.versions) / len(self.versions)


class StatefulRolloutBuffer:
    """Coordinates entry lifecycles across rollout iterations.

    Invariants (property-tested):
      * conservation — every loaded prompt is eventually consumed exactly once
      * partial mode — len(generated) == len(logprobs) == len(versions)
      * on-policy mode — after scavenging, generated/logprobs are empty
      * grouped loading — no entry of group g+1 exists while any entry of
        group g is not CONSUMED (enforced by the controller, checked here)
    """

    def __init__(self, mode: Mode = Mode.ON_POLICY):
        self.mode = Mode(mode)
        self.entries: Dict[int, BufferEntry] = {}
        self._uid = itertools.count()
        self.group_epoch = 0

    # -- loading ---------------------------------------------------------

    def load_prompts(self, prompts: Sequence[Sequence[int]],
                     metas: Optional[Sequence[Any]] = None) -> List[int]:
        if metas is None:
            metas = [None] * len(prompts)
        uids = []
        for prompt, meta in zip(prompts, metas):
            uid = next(self._uid)
            self.entries[uid] = BufferEntry(
                uid=uid, prompt=list(prompt), meta=meta,
                lifecycle=self.group_epoch)
            uids.append(uid)
        return uids

    # -- queries ---------------------------------------------------------

    def pending(self) -> List[BufferEntry]:
        return [e for e in self.entries.values()
                if e.state == EntryState.PENDING]

    def running(self) -> List[BufferEntry]:
        return [e for e in self.entries.values()
                if e.state == EntryState.RUNNING]

    def done(self) -> List[BufferEntry]:
        return [e for e in self.entries.values()
                if e.state == EntryState.DONE]

    def unconsumed(self) -> List[BufferEntry]:
        return [e for e in self.entries.values()
                if e.state != EntryState.CONSUMED]

    def group_clear(self) -> bool:
        """True when every loaded prompt has been fed to the trainer —
        the cache-aware loading condition for admitting the next group."""
        return not self.unconsumed()

    def current_group_clear(self) -> bool:
        """Pipelined variant: every entry of the *current* epoch consumed
        (next-epoch entries may already be in flight)."""
        return not any(e.lifecycle == self.group_epoch
                       for e in self.unconsumed())

    # -- pipelined (beyond-paper) loading ---------------------------------

    def load_prompts_next_group(self, prompts, metas=None):
        """Admit prompts belonging to the NEXT group epoch (lookahead=1)."""
        uids = self.load_prompts(prompts, metas)
        for uid in uids:
            self.entries[uid].lifecycle = self.group_epoch + 1
        return uids

    def group_epoch_load_allowed(self) -> bool:
        """Allow at most one group of lookahead."""
        return all(e.lifecycle <= self.group_epoch + 1
                   for e in self.unconsumed())

    # -- scheduling transitions -------------------------------------------

    def mark_running(self, uids: Iterable[int]) -> None:
        for uid in uids:
            e = self.entries[uid]
            assert e.state == EntryState.PENDING, (uid, e.state)
            e.state = EntryState.RUNNING

    def record_tokens(self, uid: int, tokens: Sequence[int],
                      logprobs: Sequence[float], version: int) -> None:
        e = self.entries[uid]
        assert e.state == EntryState.RUNNING
        e.generated.extend(int(t) for t in tokens)
        e.logprobs.extend(float(l) for l in logprobs)
        e.versions.extend([version] * len(tokens))

    def mark_done(self, uid: int, finish_reason: str) -> None:
        e = self.entries[uid]
        assert e.state == EntryState.RUNNING
        e.state = EntryState.DONE
        e.finish_reason = finish_reason

    def scavenge(self, uid: int) -> None:
        """Early termination hit this entry: return it to PENDING.

        on-policy: the partial generation is *discarded* — only the prompt
        is kept, to be re-rolled by the updated policy.
        partial  : generated tokens and their behaviour log-probs are kept;
        generation resumes from the prefix under the new policy, and the
        stitched log-probs serve as pi_old for importance sampling.
        """
        e = self.entries[uid]
        assert e.state == EntryState.RUNNING
        if self.mode == Mode.ON_POLICY:
            e.generated.clear()
            e.logprobs.clear()
            e.versions.clear()
        e.interruptions += 1
        e.state = EntryState.PENDING

    def consume(self, uids: Iterable[int]) -> List[BufferEntry]:
        out = []
        for uid in uids:
            e = self.entries[uid]
            assert e.state == EntryState.DONE, (uid, e.state)
            e.state = EntryState.CONSUMED
            out.append(e)
        return out

    def advance_group(self, strict: bool = True) -> None:
        if strict:
            assert self.group_clear(), "grouped loading: group not done"
        else:
            assert self.current_group_clear(), "pipelined: group not done"
        # drop consumed entries of the finished group to bound memory
        self.entries = {u: e for u, e in self.entries.items()
                        if e.state != EntryState.CONSUMED}
        self.group_epoch += 1

    # -- integrity ---------------------------------------------------------

    def check_invariants(self) -> None:
        for e in self.entries.values():
            assert len(e.generated) == len(e.logprobs) == len(e.versions), \
                f"uid={e.uid}: token/logprob/version misalignment"
            if e.state == EntryState.DONE:
                assert e.finish_reason in ("eos", "length"), e.finish_reason
            assert e.lifecycle <= self.group_epoch + 1  # +1: pipelined lookahead
