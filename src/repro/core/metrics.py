"""Rollout utilisation metrics — paper Eq. 4:

    BubbleRatio = sum_k (Q - r_k) * dt_k / (T * Q)

where Q is the engine queue (slot) capacity, r_k the number of running
requests during interval k, dt_k its duration, and T total elapsed time.

Also hosts the serving tier's per-tenant accounting: each tenant gets a
:class:`TenantStat` (arrival / shed / admitted / completed / consumed
counters, token throughput, bubble attribution) whose queue-wait and
end-to-end latency distributions are tracked by :class:`ReservoirQuantile`
— a fixed-size streaming reservoir (Vitter's Algorithm R) with seeded,
platform-stable sampling and no external dependencies.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Any, Dict, Iterator, List, Optional, Tuple


@dataclasses.dataclass
class MetricsSnapshot:
    """One typed observability record, shared across tiers.

    The orchestrator (``RolloutMetrics.snapshot``), the EngineGroup
    (``cache_stats`` / ``replica_stats``) and the serving tier
    (``ServingOrchestrator.snapshot``) all used to emit ad-hoc duck-typed
    dicts; this unifies them: a ``source`` tag, one flat ordered scalar
    map, and optional nested child records.  ``to_dict()`` is the stable
    wire format benchmarks and ``compare.py`` consume.

    The read-only Mapping surface (``get`` / ``[]`` / ``in`` / ``keys`` /
    iteration / truthiness) covers the flat scalars, so every legacy
    caller that indexed these records as plain dicts — including
    ``dict.update(snapshot)`` and ``RolloutMetrics.record_cache`` — keeps
    working unchanged.
    """
    source: str
    values: Dict[str, float] = dataclasses.field(default_factory=dict)
    children: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- read-only Mapping over the flat scalars ----------------------------

    def __getitem__(self, key: str) -> float:
        return self.values[key]

    def get(self, key: str, default=None):
        return self.values.get(key, default)

    def __contains__(self, key: str) -> bool:
        return key in self.values

    def __iter__(self) -> Iterator[str]:
        return iter(self.values)

    def __len__(self) -> int:
        return len(self.values)

    def __bool__(self) -> bool:
        return bool(self.values or self.children)

    def keys(self):
        return self.values.keys()

    def items(self):
        return self.values.items()

    def to_dict(self) -> dict:
        """Plain-dict rendering (scalars first, children nested), stable
        across runs — the benchmark/JSON wire format."""
        out: dict = dict(self.values)
        for key, child in self.children.items():
            out[key] = _render(child)
        return out


def _render(x):
    if isinstance(x, MetricsSnapshot):
        return x.to_dict()
    if isinstance(x, dict):
        return {k: _render(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_render(v) for v in x]
    return x


class ReservoirQuantile:
    """Streaming quantile estimator over a fixed-size uniform reservoir.

    Memory is bounded by ``size`` floats regardless of stream length.
    Up to ``size`` observations the quantiles are exact; beyond that the
    reservoir is a uniform sample (Algorithm R) and quantiles are
    estimates.  Count, mean, min, and max stay exact forever.  The
    replacement draw is seeded by a string, so the same stream produces
    the same reservoir on every platform and process.
    """

    def __init__(self, size: int = 512, seed: "str | int" = 0):
        assert size >= 1
        self.size = size
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._items: List[float] = []
        self._rng = random.Random(f"reservoir:{seed}")

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.total += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)
        if len(self._items) < self.size:
            self._items.append(x)
        else:
            j = self._rng.randrange(self.count)
            if j < self.size:
                self._items[j] = x

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Linear-interpolated quantile of the reservoir, q in [0, 1]."""
        if not self._items:
            return 0.0
        xs = sorted(self._items)
        pos = min(max(q, 0.0), 1.0) * (len(xs) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def merge(self, other: "ReservoirQuantile") -> None:
        """Fold another reservoir in (approximate beyond ``size``: the
        merged reservoir is a seeded uniform subsample of the union)."""
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        items = self._items + other._items
        if len(items) > self.size:
            items = self._rng.sample(items, self.size)
        self._items = items

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean": round(self.mean, 6),
            "p50": round(self.quantile(0.50), 6),
            "p95": round(self.quantile(0.95), 6),
            "p99": round(self.quantile(0.99), 6),
            "max": round(self.max, 6) if self.count else 0.0,
        }


def _wait_reservoir() -> ReservoirQuantile:
    return ReservoirQuantile(seed="queue_wait")


def _latency_reservoir() -> ReservoirQuantile:
    return ReservoirQuantile(seed="latency")


@dataclasses.dataclass
class TenantStat:
    """Per-tenant serving accounting (conservation:
    ``arrivals == admitted + queued + shed`` at the ingress, and every
    admitted request is eventually completed and consumed)."""
    arrivals: int = 0               # requests delivered to the ingress
    shed: int = 0                   # rejected (queue full / rate limit)
    admitted: int = 0               # moved from tenant queue into the buffer
    completed: int = 0              # finished decoding (eos / length)
    consumed: int = 0               # fed to the trainer
    tokens: int = 0                 # generated tokens kept
    slo_misses: int = 0             # completions past their deadline
    bubble_time: float = 0.0        # idle-slot time while this tenant queued
    queue_wait: ReservoirQuantile = dataclasses.field(
        default_factory=_wait_reservoir)
    latency: ReservoirQuantile = dataclasses.field(
        default_factory=_latency_reservoir)

    def merge(self, other: "TenantStat") -> None:
        self.arrivals += other.arrivals
        self.shed += other.shed
        self.admitted += other.admitted
        self.completed += other.completed
        self.consumed += other.consumed
        self.tokens += other.tokens
        self.slo_misses += other.slo_misses
        self.bubble_time += other.bubble_time
        self.queue_wait.merge(other.queue_wait)
        self.latency.merge(other.latency)

    def summary(self) -> dict:
        return {
            "arrivals": self.arrivals,
            "shed": self.shed,
            "admitted": self.admitted,
            "completed": self.completed,
            "consumed": self.consumed,
            "tokens": self.tokens,
            "slo_misses": self.slo_misses,
            "bubble_time": round(self.bubble_time, 4),
            "queue_wait": self.queue_wait.summary(),
            "latency": self.latency.summary(),
        }


@dataclasses.dataclass
class RolloutMetrics:
    capacity: int
    intervals: List[Tuple[int, float]] = dataclasses.field(default_factory=list)
    tokens_generated: int = 0
    prompts_prefilled: int = 0
    tokens_discarded: int = 0       # on-policy scavenging waste
    harvests: int = 0
    updates: int = 0
    updates_gated: int = 0          # batches vetoed by policy.update_gate
    batch_skipped: int = 0          # entries dropped from update batches
                                    # (entries_to_batch prompt >= max_len)
    # trainer-busy accounting (modeled trainer compute seconds): total is
    # every update's cost; stalled is the un-overlapped part rollout
    # actually waited for.  Serialized hand-off => stalled == total.
    update_time_total: float = 0.0
    update_time_stalled: float = 0.0
    # paged-KV-cache gauges (zero for engines without a page pool)
    prefill_tokens_saved: int = 0   # prefix sharing + resume-without-reprefill
    page_occupancy_peak: float = 0.0
    # multi-replica (EngineGroup) gauges — zero for single engines
    steal_count: int = 0            # resumes migrated off their home replica
    steal_migrations: int = 0       # steals that carried their KV along
    migrated_pages: int = 0         # KV pages moved across replica pools
    packed_entries: int = 0         # drain-phase tail-pack consolidations
    replica_busy: float = 0.0       # time-weighted mean busy-replica count
    replica_bubble_ratio: float = 0.0   # per-replica Eq. 4 on busy replicas
    # chaos / elasticity gauges (fault injection, scale_up/scale_down)
    replica_deaths: int = 0         # replicas lost to injected kills
    rehomed_entries: int = 0        # in-flight entries migrated off a dying
                                    # or scaled-down replica (zero re-prefill)
    rerolled_entries: int = 0       # entries released for a re-roll (no
                                    # survivor could take them)
    scale_events: int = 0           # elastic scale_down + scale_up calls
    residency_dropped: int = 0      # resident KV released with no survivor
                                    # pool to take it (re-prefill on resume)
    # serving-tier per-tenant accounting (empty outside serving runs)
    tenants: Dict[str, TenantStat] = dataclasses.field(default_factory=dict)

    def tenant(self, name: str) -> TenantStat:
        """Get-or-create the per-tenant stat record."""
        st = self.tenants.get(name)
        if st is None:
            st = self.tenants[name] = TenantStat()
        return st

    def record(self, running: int, dt: float, new_tokens: int = 0) -> None:
        if dt > 0:
            self.intervals.append((running, dt))
        self.tokens_generated += new_tokens

    def record_cache(self, stats: Optional[dict]) -> None:
        """Fold an engine's cache_stats() snapshot into the gauges.

        ``prefill_tokens_saved`` mirrors the engine's cumulative counter
        (max, not sum — snapshots of the same counter); occupancy keeps
        its peak."""
        if not stats:
            return
        self.prefill_tokens_saved = max(
            self.prefill_tokens_saved, int(stats.get("prefill_tokens_saved", 0)))
        self.page_occupancy_peak = max(
            self.page_occupancy_peak, float(stats.get("page_occupancy", 0.0)))
        # EngineGroup gauges: cumulative counter (max of snapshots) and
        # running ratios (latest snapshot wins)
        self.steal_count = max(self.steal_count,
                               int(stats.get("steal_count", 0)))
        self.steal_migrations = max(self.steal_migrations,
                                    int(stats.get("steal_migrations", 0)))
        self.migrated_pages = max(self.migrated_pages,
                                  int(stats.get("migrated_pages", 0)))
        self.packed_entries = max(self.packed_entries,
                                  int(stats.get("packed_entries", 0)))
        self.replica_deaths = max(self.replica_deaths,
                                  int(stats.get("replica_deaths", 0)))
        self.rehomed_entries = max(self.rehomed_entries,
                                   int(stats.get("rehomed_entries", 0)))
        self.rerolled_entries = max(self.rerolled_entries,
                                    int(stats.get("rerolled_entries", 0)))
        self.scale_events = max(self.scale_events,
                                int(stats.get("scale_events", 0)))
        self.residency_dropped = max(self.residency_dropped,
                                     int(stats.get("residency_dropped", 0)))
        if "replica_busy" in stats:
            self.replica_busy = float(stats["replica_busy"])
        if "replica_bubble_ratio" in stats:
            self.replica_bubble_ratio = float(stats["replica_bubble_ratio"])

    @property
    def elapsed(self) -> float:
        return sum(dt for _, dt in self.intervals)

    @property
    def bubble_ratio(self) -> float:
        T = self.elapsed
        if T <= 0 or self.capacity <= 0:
            return 0.0
        wasted = sum((self.capacity - r) * dt for r, dt in self.intervals)
        return wasted / (T * self.capacity)

    @property
    def throughput(self) -> float:
        """Output tokens per unit time (kept tokens only)."""
        T = self.elapsed
        return self.tokens_generated / T if T > 0 else 0.0

    @property
    def update_overlap_frac(self) -> float:
        """Share of trainer compute hidden behind continued rollout
        (0 for the serialized hand-off, > 0 under overlap mode)."""
        if self.update_time_total <= 0:
            return 0.0
        return 1.0 - self.update_time_stalled / self.update_time_total

    @property
    def trainer_busy_frac(self) -> float:
        """Trainer compute as a fraction of total rollout wall time."""
        T = self.elapsed
        return self.update_time_total / T if T > 0 else 0.0

    def merge(self, other: "RolloutMetrics") -> None:
        assert other.capacity == self.capacity
        self.intervals.extend(other.intervals)
        self.tokens_generated += other.tokens_generated
        self.prompts_prefilled += other.prompts_prefilled
        self.tokens_discarded += other.tokens_discarded
        self.harvests += other.harvests
        self.updates += other.updates
        self.updates_gated += other.updates_gated
        self.batch_skipped += other.batch_skipped
        self.update_time_total += other.update_time_total
        self.update_time_stalled += other.update_time_stalled
        self.prefill_tokens_saved += other.prefill_tokens_saved
        self.page_occupancy_peak = max(self.page_occupancy_peak,
                                       other.page_occupancy_peak)
        self.steal_count += other.steal_count
        self.steal_migrations += other.steal_migrations
        self.migrated_pages += other.migrated_pages
        self.packed_entries += other.packed_entries
        self.replica_deaths += other.replica_deaths
        self.rehomed_entries += other.rehomed_entries
        self.rerolled_entries += other.rerolled_entries
        self.scale_events += other.scale_events
        self.residency_dropped += other.residency_dropped
        self.replica_busy = max(self.replica_busy, other.replica_busy)
        self.replica_bubble_ratio = max(self.replica_bubble_ratio,
                                        other.replica_bubble_ratio)
        for name, st in other.tenants.items():
            self.tenant(name).merge(st)

    def tenant_summary(self) -> Dict[str, dict]:
        """Per-tenant record incl. throughput over this run's elapsed."""
        T = self.elapsed
        out = {}
        for name in sorted(self.tenants):
            rec = self.tenants[name].summary()
            rec["throughput_tok_per_s"] = round(
                self.tenants[name].tokens / T, 1) if T > 0 else 0.0
            out[name] = rec
        return out

    def snapshot(self, source: str = "rollout") -> MetricsSnapshot:
        """The typed observability record for this run (``summary()`` is
        its plain-dict rendering)."""
        values = {
            "elapsed": round(self.elapsed, 3),
            "bubble_ratio": round(self.bubble_ratio, 4),
            "throughput_tok_per_s": round(self.throughput, 1),
            "tokens_generated": self.tokens_generated,
            "tokens_discarded": self.tokens_discarded,
            "harvests": self.harvests,
            "updates": self.updates,
            "updates_gated": self.updates_gated,
            "batch_skipped": self.batch_skipped,
            "update_time_s": round(self.update_time_total, 4),
            "update_overlap_frac": round(self.update_overlap_frac, 4),
            "trainer_busy_frac": round(self.trainer_busy_frac, 4),
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "page_occupancy_peak": round(self.page_occupancy_peak, 4),
            "steal_count": self.steal_count,
            "steal_migrations": self.steal_migrations,
            "migrated_pages": self.migrated_pages,
            "packed_entries": self.packed_entries,
            "replica_deaths": self.replica_deaths,
            "rehomed_entries": self.rehomed_entries,
            "rerolled_entries": self.rerolled_entries,
            "scale_events": self.scale_events,
            "residency_dropped": self.residency_dropped,
            "replica_busy": round(self.replica_busy, 3),
            "replica_bubble_ratio": round(self.replica_bubble_ratio, 4),
        }
        # only serving runs carry tenants — keep non-serving summaries
        # (quickstart output, benchmark rows) byte-stable
        children = ({"tenants": self.tenant_summary()}
                    if self.tenants else {})
        return MetricsSnapshot(source=source, values=values,
                               children=children)

    def summary(self) -> dict:
        return self.snapshot().to_dict()
