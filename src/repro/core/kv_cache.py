"""Paged KV-cache bookkeeping: block pool, page tables, prefix sharing.

The paper's cache mechanism made concrete.  Physical KV storage is a pool
of fixed-size pages; each sequence owns an ordered *page table* (logical
block -> physical page).  Pages are refcounted so that

  * a GRPO group prefills its shared prompt ONCE — every member's table
    maps the same prefix pages (Seer-style context sharing);
  * divergence is handled by copy-on-write: before a slot writes into a
    page whose refcount > 1, it gets a private copy;
  * interrupted sequences keep their pages *resident* (APRIL-style active
    partial rollouts), so resuming after early termination skips
    re-prefill entirely — in partial mode the whole prefix, in on-policy
    mode the prompt prefix survives the re-roll.

This module is pure host-side bookkeeping (numpy + python), shared by any
engine backend; device page arrays and the attention over them live in
the engine (``repro.rollout.engine``) and the kernels
(``repro.kernels.ragged_decode_attention``).  It never imports jax, so
the simulator and CPU-only tests stay kernel-free.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

TokenKey = Tuple[int, ...]

# physical page 0 is reserved as the garbage page: inactive decode slots
# read from and write to it, so real pages are never corrupted by the
# fixed-shape decode step.
GARBAGE_PAGE = 0


class PoolExhausted(RuntimeError):
    """No free page and nothing evictable — the pool is oversubscribed."""


class PagePool:
    """Refcounted pool of fixed-size KV pages (physical allocation only)."""

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 2 and page_size >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        self.refcount = np.zeros(num_pages, np.int64)
        # page 0 reserved (garbage); free list as a LIFO stack
        self._free: List[int] = list(range(num_pages - 1, 0, -1))

    # -- queries ----------------------------------------------------------

    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - 1 - len(self._free)

    def occupancy(self) -> float:
        return self.pages_in_use / (self.num_pages - 1)

    # -- alloc / refcounting ---------------------------------------------

    def alloc(self) -> int:
        if not self._free:
            raise PoolExhausted(
                f"page pool exhausted ({self.num_pages - 1} pages of "
                f"{self.page_size} rows)")
        page = self._free.pop()
        assert self.refcount[page] == 0, page
        self.refcount[page] = 1
        return page

    def retain(self, page: int) -> int:
        assert page != GARBAGE_PAGE and self.refcount[page] > 0, page
        self.refcount[page] += 1
        return page

    def release(self, page: int) -> bool:
        """Drop one reference; returns True when the page was freed."""
        assert page != GARBAGE_PAGE and self.refcount[page] > 0, page
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(page)
            return True
        return False


@dataclasses.dataclass
class CacheStats:
    """Cumulative counters + point-in-time pool gauges."""
    prefill_tokens_run: int = 0       # tokens actually pushed through prefill
    prefill_tokens_saved: int = 0     # tokens skipped via sharing / residency
    shared_prefills: int = 0          # sequences that mapped existing pages
    resumed_without_prefill: int = 0  # scavenged sequences resumed in place
    cow_copies: int = 0               # copy-on-write page copies
    evictions: int = 0                # resident sequences evicted for space
    stale_kv_reuses: int = 0          # resumes/shares of pre-sync KV (see
                                      # retain_across_sync)
    migrated_pages: int = 0           # pages imported from another pool
                                      # (cross-replica KV migration)
    resume_attempts: int = 0          # resubmits of previously interrupted
                                      # uids (hit -> resumed_without_prefill;
                                      # miss -> the entry was evicted or
                                      # invalidated and must re-prefill)

    def as_dict(self, pool: PagePool, resident: int) -> Dict[str, float]:
        return {
            "prefill_tokens_run": self.prefill_tokens_run,
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "shared_prefills": self.shared_prefills,
            "resumed_without_prefill": self.resumed_without_prefill,
            "cow_copies": self.cow_copies,
            "evictions": self.evictions,
            "stale_kv_reuses": self.stale_kv_reuses,
            "migrated_pages": self.migrated_pages,
            "resume_attempts": self.resume_attempts,
            # the zero-re-prefill hit rate under memory pressure — THE
            # gauge int8 KV pages exist to raise (more resident entries
            # per byte survive eviction on an oversubscribed pool)
            "resident_resume_rate": (self.resumed_without_prefill
                                     / max(self.resume_attempts, 1)),
            "pages_in_use": pool.pages_in_use,
            "pages_total": pool.num_pages - 1,
            "page_occupancy": pool.occupancy(),
            # token capacity of the pool (garbage page excluded) — for an
            # int8 pool this is ~2x (bf16) / ~4x (f32) the equal-byte fp
            # pool's figure
            "pool_capacity_tokens": (pool.num_pages - 1) * pool.page_size,
            "resident_seqs": resident,
        }


@dataclasses.dataclass
class PageExport:
    """Host-side record of one sequence's pages for cross-pool migration.

    Produced by :meth:`PagedKVCache.export_pages` WITHOUT mutating the
    donor: ``pages`` are donor-physical ids the engine must copy buffer
    contents from before the donor releases the sequence.  Consumed by
    :meth:`PagedKVCache.import_pages` on the destination pool, which
    allocates a fresh span and re-registers the sequence (active or
    resident) so a migrated entry resumes with zero re-prefill.
    """
    uid: int
    tokens: List[int]
    version: int          # policy version the KV was committed under
    pages: List[int]      # donor-physical page ids, logical order
    active: bool          # occupied an engine slot (vs resident-for-resume)
    donor_keys: List[TokenKey]    # prefix keys the uid served as donor for


class PagedKVCache:
    """Per-sequence page tables + prefix sharing over one :class:`PagePool`.

    Tracks, per uid: the physical page table (logical order), the token
    prefix whose KV is committed to those pages, and whether the sequence
    is *active* (occupies an engine slot) or *resident* (interrupted but
    kept warm for resume).  ``extra_rows`` models cache rows prepended by
    stub frontends (``Model.prefill_extra``): committed rows =
    len(tokens) + extra_rows.

    The engine calls, in order per step: :meth:`prepare_step` (COW +
    write-page allocation), decodes against :meth:`block_table` rows, then
    :meth:`append_tokens` for the fed tokens and :meth:`release_seq` for
    finished uids.

    **Weight sync.** Each sequence is stamped with the policy version its
    KV was committed under (:meth:`sync_version`).  With
    ``retain_across_sync=True`` (default) resident pages and donors
    survive weight updates — the PipelineRL/APRIL-style approximation:
    resumed continuations attend to pre-update KV while their recorded
    per-token log-probs stay exact, and each reuse is counted in
    ``stats.stale_kv_reuses``.  With ``retain_across_sync=False`` a
    version bump invalidates every pre-sync prefix (residents dropped,
    donors cleared, actives refused later resume), restoring the dense
    engine's fresh-prefill-after-update semantics — the right setting for
    on-policy re-rolls, where stale prompt KV would bias the new policy's
    rollouts.
    """

    def __init__(self, num_pages: int, page_size: int, extra_rows: int = 0,
                 retain_across_sync: bool = True):
        self.pool = PagePool(num_pages, page_size)
        self.page_size = page_size
        self.extra_rows = extra_rows
        self.retain_across_sync = retain_across_sync
        self.version = 0
        self.tables: Dict[int, List[int]] = {}
        self.tokens: Dict[int, List[int]] = {}
        self._seq_version: Dict[int, int] = {}
        self._active: Set[int] = set()
        self._resident: Dict[int, None] = {}          # insertion-ordered LRU
        # prefix donors: committed token key -> uids whose tables cover it
        self._donors: Dict[TokenKey, Set[int]] = {}
        self._donor_keys: Dict[int, Set[TokenKey]] = {}
        # uids interrupted at some point and not yet resubmitted — their
        # next submit is a *resume attempt* whether or not the pages
        # survived eviction (see CacheStats.resume_attempts)
        self._interrupted: Set[int] = set()
        self.stats = CacheStats()

    # -- helpers ----------------------------------------------------------

    def rows(self, uid: int) -> int:
        return len(self.tokens[uid]) + self.extra_rows

    def _pages_for_rows(self, rows: int) -> int:
        return max(1, -(-rows // self.page_size))

    def _alloc(self) -> int:
        while True:
            try:
                return self.pool.alloc()
            except PoolExhausted:
                if not self._evict_one():
                    raise

    def _evict_one(self) -> bool:
        for uid in self._resident:
            del self._resident[uid]
            self._drop(uid)
            self.stats.evictions += 1
            return True
        return False

    def _drop(self, uid: int) -> None:
        for page in self.tables.pop(uid):
            self.pool.release(page)
        del self.tokens[uid]
        self._seq_version.pop(uid, None)
        for key in self._donor_keys.pop(uid, ()):
            holders = self._donors.get(key)
            if holders is not None:
                holders.discard(uid)
                if not holders:
                    del self._donors[key]

    def _register_donor(self, uid: int, key: TokenKey) -> None:
        if not key:
            return
        self._donors.setdefault(key, set()).add(uid)
        self._donor_keys.setdefault(uid, set()).add(key)

    # -- weight sync ------------------------------------------------------

    def _stale(self, uid: int) -> bool:
        return self._seq_version.get(uid, self.version) != self.version

    def sync_version(self, version: int) -> None:
        """The engine synced weights.  Retaining mode keeps everything
        (reuses are counted); strict mode drops every resident prefix
        committed under an older version — actives keep decoding (in-
        flight version mixing is inherent to async RL) but are refused
        later resume/donor use by the stamp checks."""
        if version == self.version:
            return
        self.version = version
        if self.retain_across_sync:
            return
        for uid in [u for u in self._resident if self._stale(u)]:
            del self._resident[uid]
            self._drop(uid)

    # -- submit-time planning ---------------------------------------------

    def try_resume(self, uid: int, tokens: Sequence[int]) -> bool:
        """Resume a resident sequence without re-prefill.

        True when `uid` is resident and its committed prefix covers
        `tokens` (partial mode: exactly; on-policy re-roll: a prompt
        prefix of a longer resident sequence — trimmed down).  On False
        any stale residency for `uid` is dropped.
        """
        if uid in self._interrupted:
            # count the attempt even when the pages were already evicted
            # (uid absent from tables) — misses under memory pressure are
            # exactly what resident_resume_rate measures
            self._interrupted.discard(uid)
            self.stats.resume_attempts += 1
        if uid not in self.tables or uid in self._active:
            return False
        have = self.tokens[uid]
        n = len(tokens)
        if len(have) < n or have[:n] != list(tokens):
            self._resident.pop(uid, None)
            self._drop(uid)
            return False
        if self._stale(uid):
            if not self.retain_across_sync:
                self._resident.pop(uid, None)
                self._drop(uid)
                return False
            self.stats.stale_kv_reuses += 1
        self._trim(uid, n)
        self._resident.pop(uid, None)
        self._active.add(uid)
        self.stats.prefill_tokens_saved += n
        self.stats.resumed_without_prefill += 1
        return True

    def _trim(self, uid: int, n_tokens: int) -> None:
        keep = self._pages_for_rows(n_tokens + self.extra_rows)
        table = self.tables[uid]
        for page in table[keep:]:
            self.pool.release(page)
        del table[keep:]
        del self.tokens[uid][n_tokens:]

    def find_donor(self, key: TokenKey) -> Optional[int]:
        """A uid whose committed pages cover `key`, or None.  Strict-sync
        mode refuses donors whose KV predates the live version."""
        for uid in self._donors.get(key, ()):
            if self._stale(uid) and not self.retain_across_sync:
                continue
            have = self.tokens.get(uid)
            if have is not None and have[:len(key)] == list(key):
                return uid
        return None

    def share(self, uid: int, donor: int, key: TokenKey) -> None:
        """Map `uid` onto the donor's prefix pages (prefill skipped)."""
        assert uid not in self.tables, uid
        need = self._pages_for_rows(len(key) + self.extra_rows)
        src = self.tables[donor]
        assert len(src) >= need, (uid, donor, need, len(src))
        self.tables[uid] = [self.pool.retain(p) for p in src[:need]]
        self.tokens[uid] = list(key)
        self._seq_version[uid] = self._seq_version.get(donor, self.version)
        if self._stale(uid):
            self.stats.stale_kv_reuses += 1
        self._active.add(uid)
        self._register_donor(uid, key)
        self.stats.prefill_tokens_saved += len(key)
        self.stats.shared_prefills += 1

    def register_prefill(self, uid: int, key: TokenKey) -> List[int]:
        """Allocate fresh pages for a prefilled sequence; returns the
        physical page table (for the engine to copy KV rows into)."""
        assert uid not in self.tables, uid
        need = self._pages_for_rows(len(key) + self.extra_rows)
        pages: List[int] = []
        try:
            for _ in range(need):
                pages.append(self._alloc())
        except PoolExhausted:
            # roll back the partial allocation — a failed submit must not
            # leak pages (refcount > 0 with no owning table)
            for page in pages:
                self.pool.release(page)
            raise
        self.tables[uid] = pages
        self.tokens[uid] = list(key)
        self._seq_version[uid] = self.version
        self._active.add(uid)
        self._register_donor(uid, key)
        self.stats.prefill_tokens_run += len(key)
        return list(self.tables[uid])

    # -- cross-pool migration ---------------------------------------------

    def export_pages(self, uid: int) -> PageExport:
        """Snapshot `uid`'s span for migration to another pool.

        Pure read: the donor keeps its pages (and any sharers keep
        theirs) until the caller has copied the buffer contents and
        explicitly calls :meth:`release_seq`.  That ordering lets a
        failed import fall back without having destroyed the donor copy.
        """
        assert uid in self.tables, uid
        return PageExport(
            uid=uid, tokens=list(self.tokens[uid]),
            version=self._seq_version.get(uid, self.version),
            pages=list(self.tables[uid]),
            active=uid in self._active,
            donor_keys=sorted(self._donor_keys.get(uid, ())))

    def import_pages(self, export: PageExport) -> List[int]:
        """Land a migrated span in THIS pool: allocate len(export.pages)
        fresh pages (evicting residents under pressure, rolling back on
        exhaustion) and re-register the sequence — active if it occupied
        a slot on the donor, resident-for-resume otherwise.  Returns the
        new physical page table for the engine's buffer copy; counts the
        span in ``stats.migrated_pages``."""
        uid = export.uid
        assert uid not in self.tables, uid
        pages: List[int] = []
        try:
            for _ in range(len(export.pages)):
                pages.append(self._alloc())
        except PoolExhausted:
            # a failed import must not leak the partial span
            for page in pages:
                self.pool.release(page)
            raise
        self.tables[uid] = pages
        self.tokens[uid] = list(export.tokens)
        self._seq_version[uid] = export.version
        if export.active:
            self._active.add(uid)
        else:
            self._resident[uid] = None
            # a migrated resident entry's next submit here is a resume
            # attempt, same as on the donor pool
            self._interrupted.add(uid)
        # re-register the SOURCE pool's donor keys (typically the prefill
        # prefix), not the full committed sequence: a migrated GRPO member
        # must keep attracting its siblings' prompt key here
        for key in export.donor_keys:
            self._register_donor(uid, tuple(key))
        self.stats.migrated_pages += len(pages)
        return list(pages)

    # -- decode-time ------------------------------------------------------

    def prepare_step(self, uids: Sequence[int], positions: Sequence[int]
                     ) -> List[Tuple[int, int]]:
        """Make each uid's write page (covering `position`) exclusively
        owned, allocating/copying as needed.  Returns (src, dst) physical
        page pairs the engine must copy on device before decoding."""
        copies: List[Tuple[int, int]] = []
        for uid, pos in zip(uids, positions):
            table = self.tables[uid]
            blk = pos // self.page_size
            assert blk <= len(table), (uid, pos, len(table))
            if blk == len(table):
                table.append(self._alloc())
            elif self.pool.refcount[table[blk]] > 1:
                new = self._alloc()
                copies.append((table[blk], new))
                self.pool.release(table[blk])
                table[blk] = new
                self.stats.cow_copies += 1
        return copies

    def block_table(self, uids: Sequence[int], n_blocks: int) -> np.ndarray:
        """(len(uids), n_blocks) physical page ids, garbage-padded.  A uid
        of -1 (inactive slot) maps entirely to the garbage page."""
        out = np.full((len(uids), n_blocks), GARBAGE_PAGE, np.int32)
        for i, uid in enumerate(uids):
            if uid < 0:
                continue
            table = self.tables[uid]
            n = min(len(table), n_blocks)
            out[i, :n] = table[:n]
        return out

    def append_tokens(self, uids: Sequence[int], tokens: Sequence[int]
                      ) -> None:
        """Record the tokens fed this step (their KV is now committed)."""
        for uid, tok in zip(uids, tokens):
            self.tokens[uid].append(int(tok))

    # -- lifecycle --------------------------------------------------------

    def release_seq(self, uid: int) -> None:
        """Sequence finished: drop its pages entirely."""
        self._active.discard(uid)
        self._resident.pop(uid, None)
        self._interrupted.discard(uid)
        if uid in self.tables:
            self._drop(uid)

    def release_many(self, uids: Sequence[int]) -> None:
        for uid in uids:
            self.release_seq(uid)

    def deactivate(self, uid: int) -> None:
        """Sequence interrupted: keep pages resident for a later resume."""
        if uid in self._active:
            self._active.remove(uid)
            self._resident[uid] = None
            self._interrupted.add(uid)

    def deactivate_many(self, uids: Sequence[int]) -> None:
        for uid in uids:
            self.deactivate(uid)

    def purge(self) -> int:
        """Release every sequence — active and resident alike.  The
        fence for a killed or scaled-down replica's pool: afterwards no
        table, donor record, or refcount survives (the pool is as empty
        as at construction).  Returns the number of sequences dropped."""
        uids = list(self.tables)
        for uid in uids:
            self.release_seq(uid)
        return len(uids)

    # -- introspection ----------------------------------------------------

    def max_blocks(self, uids: Sequence[int]) -> int:
        return max((len(self.tables[u]) for u in uids), default=0)

    def resident_uids(self) -> List[int]:
        return list(self._resident)

    def stats_dict(self) -> Dict[str, float]:
        return self.stats.as_dict(self.pool, len(self._resident))

    def check_invariants(self) -> None:
        """Refcount conservation: every reference comes from some table."""
        counted = np.zeros(self.pool.num_pages, np.int64)
        for table in self.tables.values():
            for page in table:
                counted[page] += 1
        assert counted[GARBAGE_PAGE] == 0, "garbage page mapped by a table"
        assert (counted == self.pool.refcount).all(), \
            "page refcounts out of sync with tables"
        in_free = self.pool.free_pages()
        assert in_free + int((counted > 0).sum()) == self.pool.num_pages - 1
