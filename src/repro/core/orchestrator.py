"""Rollout orchestration mechanics (paper §3.1 Fig. 2a), policy-agnostic.

One :class:`RolloutOrchestrator` owns everything the old controller family
re-implemented four times: engine feeding (oversubscription), decode-event
plumbing, early termination + scavenging, utilisation metrics, trainer
hand-off, weight sync, and group advancement.  Strategy differences live
entirely in a :class:`~repro.core.policy.SchedulerPolicy`:

    policy = make_policy("sorted")
    orch = RolloutOrchestrator(engine, buffer, cfg, policy, train_fn)
    orch.run_group(prompts, metas)

The trainer hand-off is typed: the orchestrator talks to a
:class:`~repro.rl.trainer_api.Trainer` (``submit`` / ``poll`` / ``flush``)
carrying :class:`UpdateRequest` batches (entries, trainer version, group
epoch, per-batch staleness stats) and collecting :class:`UpdateResult`
outcomes.  A bare ``TrainFn`` callable is still accepted everywhere — the
:func:`~repro.rl.trainer_api.as_trainer` shim wraps it in a zero-cost
synchronous trainer (deprecated path; new call sites should pass a
trainer built by ``make_trainer``).  Before each hand-off the policy's
``update_gate`` may veto the batch (PipelineRL-style staleness cap);
vetoed batches are consumed but not trained.

With ``cfg.overlap_updates`` and a trainer whose ``supports_overlap`` is
True (``make_trainer("streaming")``), submitted update batches charge
their modeled trainer time *concurrently* with continued rollout: the
weight sync lands in-flight mid-rollout when ``poll`` observes the
modeled completion time passing, and only un-overlapped trainer time
stalls the rollout clock (``metrics.update_overlap_frac`` reports the
overlapped share).  Mode semantics are preserved per entry: partial mode
keeps decoding through the sync (the per-token version stamps build the
stitched pi_old), while on-policy mode invalidates every in-flight entry
at the sync point — exactly the retain-vs-invalidate rule the
version-stamped KV machinery applies.

Entry points mirror the strategies' driving patterns:

  * ``run_group(prompts)``   — strict grouped loading (sorted / baseline /
    posthoc_sort / length_binned);
  * ``run_steps(n_updates)`` — barrier-free streaming (ungrouped);
  * ``run_queued()``         — relaxed barrier over queued groups
    (pipelined lookahead).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.buffer import (BufferEntry, EntryState, Mode,
                               StatefulRolloutBuffer)
from repro.core.engine_api import EngineProtocol, StepEvent
from repro.core.metrics import MetricsSnapshot, RolloutMetrics
from repro.core.policy import SchedulerPolicy, SchedView


@dataclasses.dataclass
class SortedRLConfig:
    """Shared scheduling knobs (formerly on the controller family)."""
    mode: Mode = Mode.ON_POLICY
    rollout_batch: int = 128          # b — prompts loaded per batch
    group_size: int = 4               # n — batches per group (n*b prompts)
    update_batch: int = 128           # trajectories per trainer update
    max_gen_len: int = 4096
    # harvest when this many trajectories are ready.  None (default)
    # resolves to update_batch; an explicit 0 means "harvest after every
    # decode step" (maximum scavenging pressure — sensible only with
    # Mode.PARTIAL, where progress survives the interrupt; an on-policy
    # re-roll would discard its single new token forever).
    harvest_threshold: Optional[int] = None
    # train on leftover (< update_batch) trajectories at group end
    train_leftover: bool = True
    # engine replicas behind the orchestrator (EngineGroup when > 1);
    # consumed by session/benchmark builders — the orchestrator itself
    # only ever sees the merged EngineProtocol surface
    num_replicas: int = 1
    # EngineGroup tail knobs (ignored when num_replicas == 1): drop the
    # lockstep step barrier / consolidate the drain-phase tail onto the
    # fewest replicas via cross-replica KV migration
    async_step: bool = False
    drain_pack: bool = False
    # rollout/update overlap: update batches run on the trainer timeline
    # concurrently with continued rollout and the weight sync lands
    # mid-rollout; requires a Trainer with supports_overlap (streaming)
    overlap_updates: bool = False

    def __post_init__(self):
        if self.harvest_threshold is not None and self.harvest_threshold < 0:
            raise ValueError(
                f"harvest_threshold must be >= 0 or None, "
                f"got {self.harvest_threshold}")
        if self.harvest_threshold == 0 and Mode(self.mode) == Mode.ON_POLICY:
            # livelock: every step would interrupt all entries and the
            # on-policy scavenge discards their single new token, so no
            # prompt needing >1 token can ever finish
            raise ValueError(
                "harvest_threshold=0 requires Mode.PARTIAL (on-policy "
                "scavenging would discard every step's progress forever)")

    def resolved_threshold(self) -> int:
        # NOT `or`: an explicit harvest_threshold=0 must stay 0 instead of
        # silently coercing to update_batch
        if self.harvest_threshold is None:
            return self.update_batch
        return self.harvest_threshold


@dataclasses.dataclass
class UpdateRequest:
    """One update batch handed to the trainer."""
    entries: List[BufferEntry]
    version: int              # trainer policy version producing this update
    group_epoch: int
    final: bool               # leftover batch at group end
    staleness_mean: float     # mean per-entry staleness vs `version`
    staleness_max: float


@dataclasses.dataclass
class UpdateResult:
    """Trainer feedback for one update (losses, rewards, ...)."""
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)


# DEPRECATED hand-off shape: kept as the shim target for existing call
# sites — as_trainer wraps any such callable in a zero-cost SyncTrainer.
# New code should pass a Trainer (repro.rl.trainer_api.make_trainer).
TrainFn = Callable[[UpdateRequest], Optional[UpdateResult]]


class RolloutOrchestrator:
    """Drives any EngineProtocol + StatefulRolloutBuffer under a policy."""

    def __init__(self, engine: EngineProtocol, buffer: StatefulRolloutBuffer,
                 cfg: SortedRLConfig, policy: SchedulerPolicy,
                 train_fn: "TrainFn | object",
                 metrics: Optional[RolloutMetrics] = None,
                 autoscaler: Optional[object] = None):
        from repro.rl.trainer_api import as_trainer
        self.engine = engine
        self.buffer = buffer
        self.cfg = cfg
        self.policy = policy
        # bare callables ride through the deprecated-path shim; Trainer
        # instances pass through untouched
        self.train_fn = train_fn
        self.trainer = as_trainer(train_fn)
        self._overlap = bool(cfg.overlap_updates)
        if self._overlap and not self.trainer.supports_overlap:
            raise ValueError(
                f"overlap_updates=True needs a trainer with "
                f"supports_overlap (e.g. make_trainer('streaming')); "
                f"got {getattr(self.trainer, 'name', type(train_fn))!r}")
        self.version = 0
        self.metrics = metrics or RolloutMetrics(capacity=engine.capacity)
        self.update_results: List[UpdateResult] = []
        # rollout-clock stalls charged for un-overlapped trainer time —
        # self._now() (engine clock + stalls) is the shared timeline the
        # trainer's modeled compute is scheduled on
        self._stall_total = 0.0
        # skip the per-step view build when the policy never admits
        from repro.core.policy import BasePolicy
        self._policy_admits = (getattr(type(policy), "admit_next_group", None)
                               is not BasePolicy.admit_next_group)
        # paged engines expose page-pool gauges (occupancy, prefill saved)
        self._cache_stats = getattr(engine, "cache_stats", None)
        # fault-tolerant groups surface uids whose replica died without a
        # survivor able to take them; the orchestrator re-rolls those
        self._take_failed = getattr(engine, "take_failed_uids", None)
        # feedback-driven fleet control (repro.rollout.autoscaler): the
        # controller is ticked once per engine step, observing windowed
        # group metrics and driving scale_down/scale_up itself
        self.autoscaler = autoscaler
        if autoscaler is not None:
            if not (hasattr(engine, "scale_down")
                    and getattr(engine, "elastic", False)):
                raise ValueError(
                    "autoscaler requires an elastic EngineGroup "
                    "(EngineGroup(..., elastic=True)) as the engine")

    def snapshot(self) -> MetricsSnapshot:
        """The run's typed observability record (see MetricsSnapshot)."""
        return self.metrics.snapshot()

    # -- scheduling snapshot -------------------------------------------------

    def _view(self, harvest_threshold: int = 0) -> SchedView:
        # single pass over the buffer: state counts, current-epoch
        # variants, and the lookahead budget all come from one scan (this
        # runs every decode step)
        p = r = d = d_cur = u_cur = 0
        load_ok = True
        epoch = self.buffer.group_epoch
        for e in self.buffer.entries.values():
            s = e.state
            if s == EntryState.PENDING:
                p += 1
            elif s == EntryState.RUNNING:
                r += 1
            elif s == EntryState.DONE:
                d += 1
                if e.lifecycle <= epoch:
                    d_cur += 1
            else:
                continue
            if e.lifecycle <= epoch:
                u_cur += 1
            elif e.lifecycle > epoch + 1:
                load_ok = False
        return SchedView(
            pending=p, running=r, done=d, unconsumed=p + r + d,
            free_slots=self.engine.free_slots(),
            capacity=self.engine.capacity,
            group_epoch=epoch,
            version=self.version,
            update_batch=self.cfg.update_batch,
            harvest_threshold=harvest_threshold,
            next_epoch_load_allowed=load_ok,
            done_current=d_cur, unconsumed_current=u_cur)

    # -- engine feeding ------------------------------------------------------

    def _admit(self) -> None:
        if not self._policy_admits:
            return
        req = self.policy.admit_next_group(self._view())
        if req is None or not req.prompts:
            return
        if req.next_epoch:
            self.buffer.load_prompts_next_group(req.prompts, req.metas)
        else:
            self.buffer.load_prompts(req.prompts, req.metas)

    def _fill_engine(self) -> None:
        self._admit()
        free = self.engine.free_slots()
        if free <= 0:
            return
        batch = self.policy.select_fill(self.buffer.pending(), free)
        if not batch:
            return
        self.buffer.mark_running([e.uid for e in batch])
        self.engine.submit(batch, self.version)
        self.metrics.prompts_prefilled += len(batch)

    # -- event plumbing ------------------------------------------------------

    def _apply_events(self, events: Sequence[StepEvent], t0: float) -> None:
        for ev in events:
            self.buffer.record_tokens(ev.uid, [ev.token], [ev.logprob],
                                      self.version)
            if ev.done:
                self.buffer.mark_done(ev.uid, ev.finish_reason or "eos")
        dt = self.engine.clock - t0
        self.metrics.record(len(events), dt, new_tokens=len(events))
        if self._cache_stats is not None:
            self.metrics.record_cache(self._cache_stats())
        # autoscale BEFORE the re-roll drain: a scale_down that re-rolls
        # entries parks their uids in the group's failed list, and the
        # drain below scavenges them back to PENDING in the same step
        self._autoscale_tick()
        if self._take_failed is not None:
            self._reroll_failed()
        if self._overlap:
            # in-flight weight sync: completed updates land mid-rollout
            self._drain_trainer(flush=False)

    def _autoscale_queue_stats(self) -> tuple:
        """(queue_backlog, oldest_wait, slo_pressure) — backlog pressure
        for the autoscaler's serving signals.  The base orchestrator has
        no ingress, so there is never a backlog; ServingOrchestrator
        overrides this with per-tenant head ages vs SLO deadlines."""
        return 0, 0.0, 0.0

    def _autoscale_tick(self) -> None:
        asc = self.autoscaler
        if asc is None:
            return
        backlog, oldest, pressure = self._autoscale_queue_stats()
        asc.tick(self.engine,
                 pending=len(self.buffer.pending()),
                 running=len(self.buffer.running()),
                 queue_backlog=backlog, oldest_wait=oldest,
                 slo_pressure=pressure)

    def _reroll_failed(self) -> None:
        """Entries whose replica died without re-homing: their engine-side
        state is gone, so scavenge them back to PENDING — the next fill
        re-rolls them under the *current* policy version.  The buffer's
        mode decides what survives (on-policy discards their tokens,
        partial keeps them), exactly the early-termination rule, so group
        lifecycle barriers are untouched."""
        for uid in self._take_failed():
            e = self.buffer.entries[uid]
            if self.buffer.mode == Mode.ON_POLICY:
                self.metrics.tokens_discarded += e.gen_len
            self.buffer.scavenge(uid)

    # -- one rollout iteration: decode until harvest -------------------------

    def _harvest_stragglers(self) -> List[int]:
        """Early-terminate every in-flight straggler and scavenge it back
        to PENDING (on-policy discards its tokens, partial keeps them).
        Shared by the epoch harvest below and the serving tier's
        continuous-batching harvest.  Returns the interrupted uids."""
        interrupted = self.engine.interrupt()
        for uid in interrupted:
            e = self.buffer.entries[uid]
            if self.buffer.mode == Mode.ON_POLICY:
                self.metrics.tokens_discarded += e.gen_len
            self.buffer.scavenge(uid)
        self.metrics.harvests += 1
        return interrupted

    def rollout_until_harvest(self) -> None:
        while True:
            # recomputed every iteration: admitting policies (pipelined
            # lookahead, serving ingress) grow the unconsumed set
            # mid-loop, and a threshold frozen at entry would hand
            # harvest_now a stale cap for the rest of the epoch
            threshold = min(self.cfg.resolved_threshold(),
                            len(self.buffer.unconsumed()))
            self._fill_engine()
            if not self.engine.active_uids():
                break
            t0 = self.engine.clock
            events = self.engine.step()
            self._apply_events(events, t0)
            if self.policy.harvest_now(self._view(threshold)):
                break
        if not self.policy.early_termination:
            return   # wait-for-all: the loop above drained the engine
        # early termination of stragglers (both modes; on-policy discards)
        self._harvest_stragglers()

    # -- training ------------------------------------------------------------

    def _now(self) -> float:
        """The rollout timeline trainer compute is scheduled against:
        engine clock plus every stall already charged for un-overlapped
        trainer time (wall-clock engines just ride their own clock)."""
        return self.engine.clock + self._stall_total

    def train_ready(self, final: bool = False) -> int:
        """Order DONE trajectories per the policy and submit them to the
        trainer in update_batch batches.  Without overlap every submission
        completes (and stalls) inline — the classical serialized hand-off;
        with overlap submissions queue on the trainer timeline and land
        via ``poll`` during subsequent rollout steps.  Returns the number
        of updates completed during this call."""
        ready = self.policy.order_ready(self.buffer.done(), self._view())
        n_updates = 0
        while len(ready) >= self.cfg.update_batch or (
                final and ready and self.cfg.train_leftover):
            batch = ready[:self.cfg.update_batch]
            ready = ready[len(batch):]
            entries = self.buffer.consume([e.uid for e in batch])
            req = self._update_request(entries, final and not ready)
            if not self.policy.update_gate(req):
                self.metrics.updates_gated += 1
                continue
            self.trainer.submit(req, now=self._now())
            if not self._overlap:
                n_updates += self._drain_trainer(flush=True)
        if self._overlap:
            n_updates += self._drain_trainer(flush=final)
        return n_updates

    def _drain_trainer(self, flush: bool) -> int:
        """Apply completed trainer outcomes: charge un-overlapped trainer
        time as a rollout stall, bump the version, and sync weights.  With
        ``flush`` outstanding submissions are forced to completion (group
        boundary / serialized mode); otherwise only outcomes whose modeled
        time has already passed land (the in-flight mid-rollout path)."""
        now = self._now()
        outcomes = (self.trainer.flush(now) if flush
                    else self.trainer.poll(now))
        for o in outcomes:
            # stall = the part of this update's compute rollout had to
            # wait for.  Charging it advances self._now(), so a queued
            # successor's t_start can never exceed the advanced clock —
            # each outcome stalls at most its own cost.
            stall = max(0.0, o.t_done - self._now())
            if stall > 0:
                self._stall_total += stall
                self.metrics.record(0, stall)
            self.metrics.update_time_total += o.cost
            self.metrics.update_time_stalled += min(o.cost, stall)
            self._apply_outcome(o)
        return len(outcomes)

    def _apply_outcome(self, o) -> None:
        if o.result is not None:
            self.update_results.append(o.result)
            self.metrics.batch_skipped += int(
                o.result.metrics.get("entries_skipped", 0))
        self.version += 1
        self.engine.sync_weights(self.version)
        self.metrics.updates += 1
        if (self._overlap and self.buffer.mode == Mode.ON_POLICY
                and self.engine.active_uids()):
            # the sync landed mid-rollout: on-policy semantics demand
            # every in-flight entry's tokens come from the *current*
            # weights, so invalidate them all (interrupt + scavenge
            # discards their tokens; the next fill re-rolls them fresh).
            # Partial mode instead retains: decoding continues and the
            # per-token version stamps keep the stitched pi_old exact.
            self._harvest_stragglers()

    def _update_request(self, entries: List[BufferEntry],
                        final: bool) -> UpdateRequest:
        stales = [e.staleness(self.version) for e in entries]
        return UpdateRequest(
            entries=entries, version=self.version,
            group_epoch=self.buffer.group_epoch, final=final,
            staleness_mean=sum(stales) / len(stales) if stales else 0.0,
            staleness_max=max(stales, default=0.0))

    # -- driving patterns -----------------------------------------------------

    def run_group(self, prompts: Sequence[Sequence[int]],
                  metas: Optional[Sequence] = None) -> None:
        """Process one group of n*b prompts to full consumption (strict
        grouped loading, paper §3.1 step 5)."""
        assert self.buffer.group_clear(), "previous group not consumed"
        self.buffer.load_prompts(prompts, metas)
        while not self.buffer.group_clear():
            self.rollout_until_harvest()
            remaining = len(self.buffer.unconsumed()) - len(self.buffer.done())
            self.train_ready(final=(remaining == 0))
            self.buffer.check_invariants()
        self._drain_trainer(flush=True)   # no update crosses the barrier
        self.buffer.advance_group()

    def run_steps(self, n_updates: int) -> None:
        """Barrier-free driving (ungrouped ablation): keep harvesting and
        training until `n_updates` updates or the prompt source dries up."""
        while self.metrics.updates < n_updates:
            self.rollout_until_harvest()
            n = self.train_ready(final=False)
            if getattr(self.policy, "prompt_stream", None) is not None:
                continue   # more prompts may still arrive
            if not self.buffer.unconsumed():
                break
            if n == 0 and not (self.buffer.pending() or
                               self.buffer.running()):
                break   # leftover smaller than update_batch; final never
                        # comes without a group barrier
        self._drain_trainer(flush=True)   # deliver overlapped stragglers

    def run_queued(self) -> None:
        """Process every policy-queued group to consumption (pipelined
        lookahead: next-group prompts fill otherwise-idle slots)."""
        policy = self.policy
        assert hasattr(policy, "has_queued"), \
            f"policy {policy.name!r} does not queue groups"
        while policy.has_queued() or self.buffer.unconsumed():
            if not self.buffer.unconsumed() and policy.has_queued():
                prompts, metas = policy.pop_group()
                if prompts:
                    self.buffer.load_prompts(prompts, metas)
                continue
            self.rollout_until_harvest()
            # `final` judged on the CURRENT epoch: next-group entries in
            # flight must not block the current group's leftover batch
            epoch = self.buffer.group_epoch
            remaining = sum(1 for e in self.buffer.unconsumed()
                            if e.lifecycle <= epoch
                            and e.state != EntryState.DONE)
            self.train_ready(final=(remaining == 0))
            self.buffer.check_invariants()
            if self.buffer.current_group_clear() and not self.buffer.group_clear():
                self.buffer.advance_group(strict=False)
            elif self.buffer.group_clear():
                self.buffer.advance_group()
        self._drain_trainer(flush=True)   # deliver overlapped stragglers
