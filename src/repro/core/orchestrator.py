"""Rollout orchestration mechanics (paper §3.1 Fig. 2a), policy-agnostic.

One :class:`RolloutOrchestrator` owns everything the old controller family
re-implemented four times: engine feeding (oversubscription), decode-event
plumbing, early termination + scavenging, utilisation metrics, trainer
hand-off, weight sync, and group advancement.  Strategy differences live
entirely in a :class:`~repro.core.policy.SchedulerPolicy`:

    policy = make_policy("sorted")
    orch = RolloutOrchestrator(engine, buffer, cfg, policy, train_fn)
    orch.run_group(prompts, metas)

The trainer hand-off is typed: ``train_fn`` receives an
:class:`UpdateRequest` (entries, trainer version, group epoch, per-batch
staleness stats) and may return an :class:`UpdateResult`.  Before each
hand-off the policy's ``update_gate`` may veto the batch (PipelineRL-style
staleness cap); vetoed batches are consumed but not trained.

Entry points mirror the strategies' driving patterns:

  * ``run_group(prompts)``   — strict grouped loading (sorted / baseline /
    posthoc_sort / length_binned);
  * ``run_steps(n_updates)`` — barrier-free streaming (ungrouped);
  * ``run_queued()``         — relaxed barrier over queued groups
    (pipelined lookahead).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.buffer import (BufferEntry, EntryState, Mode,
                               StatefulRolloutBuffer)
from repro.core.engine_api import EngineProtocol, StepEvent
from repro.core.metrics import RolloutMetrics
from repro.core.policy import SchedulerPolicy, SchedView


@dataclasses.dataclass
class SortedRLConfig:
    """Shared scheduling knobs (formerly on the controller family)."""
    mode: Mode = Mode.ON_POLICY
    rollout_batch: int = 128          # b — prompts loaded per batch
    group_size: int = 4               # n — batches per group (n*b prompts)
    update_batch: int = 128           # trajectories per trainer update
    max_gen_len: int = 4096
    # harvest when this many trajectories are ready.  None (default)
    # resolves to update_batch; an explicit 0 means "harvest after every
    # decode step" (maximum scavenging pressure — sensible only with
    # Mode.PARTIAL, where progress survives the interrupt; an on-policy
    # re-roll would discard its single new token forever).
    harvest_threshold: Optional[int] = None
    # train on leftover (< update_batch) trajectories at group end
    train_leftover: bool = True
    # engine replicas behind the orchestrator (EngineGroup when > 1);
    # consumed by session/benchmark builders — the orchestrator itself
    # only ever sees the merged EngineProtocol surface
    num_replicas: int = 1
    # EngineGroup tail knobs (ignored when num_replicas == 1): drop the
    # lockstep step barrier / consolidate the drain-phase tail onto the
    # fewest replicas via cross-replica KV migration
    async_step: bool = False
    drain_pack: bool = False

    def __post_init__(self):
        if self.harvest_threshold is not None and self.harvest_threshold < 0:
            raise ValueError(
                f"harvest_threshold must be >= 0 or None, "
                f"got {self.harvest_threshold}")
        if self.harvest_threshold == 0 and Mode(self.mode) == Mode.ON_POLICY:
            # livelock: every step would interrupt all entries and the
            # on-policy scavenge discards their single new token, so no
            # prompt needing >1 token can ever finish
            raise ValueError(
                "harvest_threshold=0 requires Mode.PARTIAL (on-policy "
                "scavenging would discard every step's progress forever)")

    def resolved_threshold(self) -> int:
        # NOT `or`: an explicit harvest_threshold=0 must stay 0 instead of
        # silently coercing to update_batch
        if self.harvest_threshold is None:
            return self.update_batch
        return self.harvest_threshold


@dataclasses.dataclass
class UpdateRequest:
    """One update batch handed to the trainer."""
    entries: List[BufferEntry]
    version: int              # trainer policy version producing this update
    group_epoch: int
    final: bool               # leftover batch at group end
    staleness_mean: float     # mean per-entry staleness vs `version`
    staleness_max: float


@dataclasses.dataclass
class UpdateResult:
    """Trainer feedback for one update (losses, rewards, ...)."""
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)


TrainFn = Callable[[UpdateRequest], Optional[UpdateResult]]


class RolloutOrchestrator:
    """Drives any EngineProtocol + StatefulRolloutBuffer under a policy."""

    def __init__(self, engine: EngineProtocol, buffer: StatefulRolloutBuffer,
                 cfg: SortedRLConfig, policy: SchedulerPolicy,
                 train_fn: TrainFn,
                 metrics: Optional[RolloutMetrics] = None):
        self.engine = engine
        self.buffer = buffer
        self.cfg = cfg
        self.policy = policy
        self.train_fn = train_fn
        self.version = 0
        self.metrics = metrics or RolloutMetrics(capacity=engine.capacity)
        self.update_results: List[UpdateResult] = []
        # skip the per-step view build when the policy never admits
        from repro.core.policy import BasePolicy
        self._policy_admits = (getattr(type(policy), "admit_next_group", None)
                               is not BasePolicy.admit_next_group)
        # paged engines expose page-pool gauges (occupancy, prefill saved)
        self._cache_stats = getattr(engine, "cache_stats", None)
        # fault-tolerant groups surface uids whose replica died without a
        # survivor able to take them; the orchestrator re-rolls those
        self._take_failed = getattr(engine, "take_failed_uids", None)

    # -- scheduling snapshot -------------------------------------------------

    def _view(self, harvest_threshold: int = 0) -> SchedView:
        # single pass over the buffer: state counts, current-epoch
        # variants, and the lookahead budget all come from one scan (this
        # runs every decode step)
        p = r = d = d_cur = u_cur = 0
        load_ok = True
        epoch = self.buffer.group_epoch
        for e in self.buffer.entries.values():
            s = e.state
            if s == EntryState.PENDING:
                p += 1
            elif s == EntryState.RUNNING:
                r += 1
            elif s == EntryState.DONE:
                d += 1
                if e.lifecycle <= epoch:
                    d_cur += 1
            else:
                continue
            if e.lifecycle <= epoch:
                u_cur += 1
            elif e.lifecycle > epoch + 1:
                load_ok = False
        return SchedView(
            pending=p, running=r, done=d, unconsumed=p + r + d,
            free_slots=self.engine.free_slots(),
            capacity=self.engine.capacity,
            group_epoch=epoch,
            version=self.version,
            update_batch=self.cfg.update_batch,
            harvest_threshold=harvest_threshold,
            next_epoch_load_allowed=load_ok,
            done_current=d_cur, unconsumed_current=u_cur)

    # -- engine feeding ------------------------------------------------------

    def _admit(self) -> None:
        if not self._policy_admits:
            return
        req = self.policy.admit_next_group(self._view())
        if req is None or not req.prompts:
            return
        if req.next_epoch:
            self.buffer.load_prompts_next_group(req.prompts, req.metas)
        else:
            self.buffer.load_prompts(req.prompts, req.metas)

    def _fill_engine(self) -> None:
        self._admit()
        free = self.engine.free_slots()
        if free <= 0:
            return
        batch = self.policy.select_fill(self.buffer.pending(), free)
        if not batch:
            return
        self.buffer.mark_running([e.uid for e in batch])
        self.engine.submit(batch, self.version)
        self.metrics.prompts_prefilled += len(batch)

    # -- event plumbing ------------------------------------------------------

    def _apply_events(self, events: Sequence[StepEvent], t0: float) -> None:
        for ev in events:
            self.buffer.record_tokens(ev.uid, [ev.token], [ev.logprob],
                                      self.version)
            if ev.done:
                self.buffer.mark_done(ev.uid, ev.finish_reason or "eos")
        dt = self.engine.clock - t0
        self.metrics.record(len(events), dt, new_tokens=len(events))
        if self._cache_stats is not None:
            self.metrics.record_cache(self._cache_stats())
        if self._take_failed is not None:
            self._reroll_failed()

    def _reroll_failed(self) -> None:
        """Entries whose replica died without re-homing: their engine-side
        state is gone, so scavenge them back to PENDING — the next fill
        re-rolls them under the *current* policy version.  The buffer's
        mode decides what survives (on-policy discards their tokens,
        partial keeps them), exactly the early-termination rule, so group
        lifecycle barriers are untouched."""
        for uid in self._take_failed():
            e = self.buffer.entries[uid]
            if self.buffer.mode == Mode.ON_POLICY:
                self.metrics.tokens_discarded += e.gen_len
            self.buffer.scavenge(uid)

    # -- one rollout iteration: decode until harvest -------------------------

    def _harvest_stragglers(self) -> List[int]:
        """Early-terminate every in-flight straggler and scavenge it back
        to PENDING (on-policy discards its tokens, partial keeps them).
        Shared by the epoch harvest below and the serving tier's
        continuous-batching harvest.  Returns the interrupted uids."""
        interrupted = self.engine.interrupt()
        for uid in interrupted:
            e = self.buffer.entries[uid]
            if self.buffer.mode == Mode.ON_POLICY:
                self.metrics.tokens_discarded += e.gen_len
            self.buffer.scavenge(uid)
        self.metrics.harvests += 1
        return interrupted

    def rollout_until_harvest(self) -> None:
        threshold = min(self.cfg.resolved_threshold(),
                        len(self.buffer.unconsumed()))
        while True:
            self._fill_engine()
            if not self.engine.active_uids():
                break
            t0 = self.engine.clock
            events = self.engine.step()
            self._apply_events(events, t0)
            if self.policy.harvest_now(self._view(threshold)):
                break
        if not self.policy.early_termination:
            return   # wait-for-all: the loop above drained the engine
        # early termination of stragglers (both modes; on-policy discards)
        self._harvest_stragglers()

    # -- training ------------------------------------------------------------

    def train_ready(self, final: bool = False) -> int:
        """Order DONE trajectories per the policy and feed the trainer in
        update_batch batches.  Returns number of updates performed."""
        ready = self.policy.order_ready(self.buffer.done(), self._view())
        n_updates = 0
        while len(ready) >= self.cfg.update_batch or (
                final and ready and self.cfg.train_leftover):
            batch = ready[:self.cfg.update_batch]
            ready = ready[len(batch):]
            entries = self.buffer.consume([e.uid for e in batch])
            req = self._update_request(entries, final and not ready)
            if not self.policy.update_gate(req):
                self.metrics.updates_gated += 1
                continue
            result = self.train_fn(req)
            if result is not None:
                self.update_results.append(result)
            self.version += 1
            self.engine.sync_weights(self.version)
            self.metrics.updates += 1
            n_updates += 1
        return n_updates

    def _update_request(self, entries: List[BufferEntry],
                        final: bool) -> UpdateRequest:
        stales = [e.staleness(self.version) for e in entries]
        return UpdateRequest(
            entries=entries, version=self.version,
            group_epoch=self.buffer.group_epoch, final=final,
            staleness_mean=sum(stales) / len(stales) if stales else 0.0,
            staleness_max=max(stales, default=0.0))

    # -- driving patterns -----------------------------------------------------

    def run_group(self, prompts: Sequence[Sequence[int]],
                  metas: Optional[Sequence] = None) -> None:
        """Process one group of n*b prompts to full consumption (strict
        grouped loading, paper §3.1 step 5)."""
        assert self.buffer.group_clear(), "previous group not consumed"
        self.buffer.load_prompts(prompts, metas)
        while not self.buffer.group_clear():
            self.rollout_until_harvest()
            remaining = len(self.buffer.unconsumed()) - len(self.buffer.done())
            self.train_ready(final=(remaining == 0))
            self.buffer.check_invariants()
        self.buffer.advance_group()

    def run_steps(self, n_updates: int) -> None:
        """Barrier-free driving (ungrouped ablation): keep harvesting and
        training until `n_updates` updates or the prompt source dries up."""
        while self.metrics.updates < n_updates:
            self.rollout_until_harvest()
            n = self.train_ready(final=False)
            if getattr(self.policy, "prompt_stream", None) is not None:
                continue   # more prompts may still arrive
            if not self.buffer.unconsumed():
                break
            if n == 0 and not (self.buffer.pending() or
                               self.buffer.running()):
                break   # leftover smaller than update_batch; final never
                        # comes without a group barrier

    def run_queued(self) -> None:
        """Process every policy-queued group to consumption (pipelined
        lookahead: next-group prompts fill otherwise-idle slots)."""
        policy = self.policy
        assert hasattr(policy, "has_queued"), \
            f"policy {policy.name!r} does not queue groups"
        while policy.has_queued() or self.buffer.unconsumed():
            if not self.buffer.unconsumed() and policy.has_queued():
                prompts, metas = policy.pop_group()
                if prompts:
                    self.buffer.load_prompts(prompts, metas)
                continue
            self.rollout_until_harvest()
            # `final` judged on the CURRENT epoch: next-group entries in
            # flight must not block the current group's leftover batch
            epoch = self.buffer.group_epoch
            remaining = sum(1 for e in self.buffer.unconsumed()
                            if e.lifecycle <= epoch
                            and e.state != EntryState.DONE)
            self.train_ready(final=(remaining == 0))
            self.buffer.check_invariants()
            if self.buffer.current_group_clear() and not self.buffer.group_clear():
                self.buffer.advance_group(strict=False)
            elif self.buffer.group_clear():
                self.buffer.advance_group()
