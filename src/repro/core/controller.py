"""Length-aware controller (paper §3.1, §3.3) plus the canonical baseline
and the two ablation controllers from §4.4.2.

SortedRLController implements the five-step cycle of Fig. 2a:
  1) concatenate buffer and feed prompts (oversubscription: free slots are
     refilled from the pending pool at every step — the engine always runs
     at its saturation batch),
  2) early termination once the harvest threshold is met,
  3) collect and update rollout trajectories (scavenge per mode),
  4) sort ready trajectories by generated length and feed the trainer in
     update_batch-sized batches (selective batching / micro-curriculum),
  5) grouped loading: a new group of n*b prompts is admitted only when the
     current group is fully consumed.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, List, Optional, Sequence

from repro.core.buffer import BufferEntry, EntryState, Mode, StatefulRolloutBuffer
from repro.core.engine_api import EngineProtocol, StepEvent
from repro.core.metrics import RolloutMetrics


@dataclasses.dataclass
class SortedRLConfig:
    mode: Mode = Mode.ON_POLICY
    rollout_batch: int = 128          # b — prompts loaded per batch
    group_size: int = 4               # n — batches per group (n*b prompts)
    update_batch: int = 128           # trajectories per trainer update
    max_gen_len: int = 4096
    # harvest when this many trajectories are ready (defaults to
    # update_batch); `None` disables early termination (baseline).
    harvest_threshold: Optional[int] = None
    # train on leftover (< update_batch) trajectories at group end
    train_leftover: bool = True

    def resolved_threshold(self) -> int:
        return self.harvest_threshold or self.update_batch


# trainer callback: (entries, version) -> None.  The controller bumps the
# version after each call and syncs engine weights.
TrainFn = Callable[[List[BufferEntry], int], None]


class SortedRLController:
    """fill_policy (beyond-paper study, EXPERIMENTS §Claims/fig6a):
    'resume_first' (default) schedules scavenged partials before fresh
    prompts — bounds their staleness and finishes long stragglers early;
    'fresh_first' defers partials; 'fifo' ignores progress."""

    def __init__(self, engine: EngineProtocol, buffer: StatefulRolloutBuffer,
                 cfg: SortedRLConfig, train_fn: TrainFn,
                 metrics: Optional[RolloutMetrics] = None,
                 fill_policy: str = "resume_first"):
        self.engine = engine
        self.buffer = buffer
        self.cfg = cfg
        self.train_fn = train_fn
        self.version = 0
        self.metrics = metrics or RolloutMetrics(capacity=engine.capacity)
        self.fill_policy = fill_policy

    # -- engine feeding ----------------------------------------------------

    def _fill_engine(self) -> None:
        free = self.engine.free_slots()
        if free <= 0:
            return
        pending = self.buffer.pending()
        # top-free selection, not a full sort — this runs every decode step
        if self.fill_policy == "resume_first":
            batch = heapq.nsmallest(free, pending,
                                    key=lambda e: (-e.gen_len, len(e.prompt)))
        elif self.fill_policy == "fresh_first":
            batch = heapq.nsmallest(free, pending,
                                    key=lambda e: (e.gen_len, len(e.prompt)))
        else:   # 'fifo': keep load order
            batch = pending[:free]
        if not batch:
            return
        self.buffer.mark_running([e.uid for e in batch])
        self.engine.submit(batch, self.version)
        self.metrics.prompts_prefilled += len(batch)

    # -- event plumbing ------------------------------------------------------

    def _apply_events(self, events: Sequence[StepEvent], t0: float) -> int:
        done_count = 0
        for ev in events:
            self.buffer.record_tokens(ev.uid, [ev.token], [ev.logprob],
                                      self.version)
            if ev.done:
                self.buffer.mark_done(ev.uid, ev.finish_reason or "eos")
                done_count += 1
        dt = self.engine.clock - t0
        self.metrics.record(len(events), dt, new_tokens=len(events))
        return done_count

    # -- one rollout iteration: decode until harvest ------------------------

    def rollout_until_harvest(self) -> None:
        threshold = min(self.cfg.resolved_threshold(),
                        len(self.buffer.unconsumed()))
        while True:
            self._fill_engine()
            if not self.engine.active_uids():
                break
            t0 = self.engine.clock
            events = self.engine.step()
            self._apply_events(events, t0)
            if len(self.buffer.done()) >= threshold:
                break
        # early termination of stragglers (both modes; on-policy discards)
        interrupted = self.engine.interrupt()
        for uid in interrupted:
            e = self.buffer.entries[uid]
            if self.buffer.mode == Mode.ON_POLICY:
                self.metrics.tokens_discarded += e.gen_len
            self.buffer.scavenge(uid)
        self.metrics.harvests += 1

    # -- training ------------------------------------------------------------

    def _train_order_key(self, e: BufferEntry):
        return e.gen_len

    def train_ready(self, final: bool = False) -> int:
        """Sort DONE trajectories (by `_train_order_key`), feed in
        update_batch batches.  Returns number of updates performed."""
        done = sorted(self.buffer.done(), key=self._train_order_key)
        n_updates = 0
        while len(done) >= self.cfg.update_batch or (
                final and done and self.cfg.train_leftover):
            batch = done[:self.cfg.update_batch]
            done = done[len(batch):]
            entries = self.buffer.consume([e.uid for e in batch])
            self.train_fn(entries, self.version)
            self.version += 1
            self.engine.sync_weights(self.version)
            self.metrics.updates += 1
            n_updates += 1
        return n_updates

    # -- group loop ------------------------------------------------------------

    def run_group(self, prompts: Sequence[Sequence[int]],
                  metas: Optional[Sequence] = None) -> None:
        """Process one group of n*b prompts to full consumption."""
        assert self.buffer.group_clear(), "previous group not consumed"
        self.buffer.load_prompts(prompts, metas)
        while not self.buffer.group_clear():
            self.rollout_until_harvest()
            remaining = len(self.buffer.unconsumed()) - len(self.buffer.done())
            self.train_ready(final=(remaining == 0))
            self.buffer.check_invariants()
        self.buffer.advance_group()


class CanonicalController:
    """Baseline: submit a rollout batch, wait for ALL to finish (no early
    termination — the bubble), then run multiple updates over the same data
    (off-policy when update_batch < rollout size)."""

    def __init__(self, engine: EngineProtocol, buffer: StatefulRolloutBuffer,
                 cfg: SortedRLConfig, train_fn: TrainFn,
                 metrics: Optional[RolloutMetrics] = None,
                 sort_post_hoc: bool = False, shuffle_seed: int = 0):
        self.engine = engine
        self.buffer = buffer
        self.cfg = cfg
        self.train_fn = train_fn
        self.version = 0
        self.metrics = metrics or RolloutMetrics(capacity=engine.capacity)
        self.sort_post_hoc = sort_post_hoc   # ablation §4.4.2
        self.shuffle_seed = shuffle_seed

    def run_group(self, prompts, metas=None) -> None:
        import random
        self.buffer.load_prompts(prompts, metas)
        while self.buffer.pending() or self.engine.active_uids():
            free = self.engine.free_slots()
            if free:
                batch = self.buffer.pending()[:free]
                if batch:
                    self.buffer.mark_running([e.uid for e in batch])
                    self.engine.submit(batch, self.version)
                    self.metrics.prompts_prefilled += len(batch)
            if not self.engine.active_uids():
                break
            t0 = self.engine.clock
            events = self.engine.step()
            for ev in events:
                self.buffer.record_tokens(ev.uid, [ev.token], [ev.logprob],
                                          self.version)
                if ev.done:
                    self.buffer.mark_done(ev.uid, ev.finish_reason or "eos")
            self.metrics.record(len(events), self.engine.clock - t0,
                                new_tokens=len(events))
        # all trajectories ready: several (possibly off-policy) updates
        done = self.buffer.done()
        if self.sort_post_hoc:
            done = sorted(done, key=lambda e: e.gen_len)
        else:
            rng = random.Random(self.shuffle_seed + self.version)
            done = list(done)
            rng.shuffle(done)
        for i in range(0, len(done), self.cfg.update_batch):
            batch = done[i:i + self.cfg.update_batch]
            if len(batch) < self.cfg.update_batch and not self.cfg.train_leftover:
                break
            entries = self.buffer.consume([e.uid for e in batch])
            self.train_fn(entries, self.version)
            self.version += 1
            self.engine.sync_weights(self.version)
            self.metrics.updates += 1
        self.buffer.advance_group()


class UngroupedController(SortedRLController):
    """Ablation §4.4.2 «disabled grouped rollout»: oversubscription and
    shortest-first harvesting WITHOUT the group barrier — new prompts are
    admitted whenever slots free up, so short responses dominate and long
    prompts starve (the collapse the paper shows)."""

    def __init__(self, *args, prompt_stream=None, **kw):
        super().__init__(*args, **kw)
        self.prompt_stream = prompt_stream   # iterator of (prompt, meta)

    def _fill_engine(self) -> None:
        free = self.engine.free_slots()
        have = len(self.buffer.pending())
        # keep pulling fresh prompts — no group barrier
        while self.prompt_stream is not None and have < free:
            try:
                prompt, meta = next(self.prompt_stream)
            except StopIteration:
                break
            self.buffer.load_prompts([prompt], [meta])
            have += 1
        super()._fill_engine()

    def run_steps(self, n_updates: int) -> None:
        while self.metrics.updates < n_updates:
            self.rollout_until_harvest()
            self.train_ready(final=False)
            if not self.buffer.unconsumed() and self.prompt_stream is None:
                break


class PipelinedController(SortedRLController):
    """BEYOND-PAPER extension: relaxed group barrier.

    The paper's grouped loading leaves a drain bubble at each group tail
    (the last update_batch of stragglers can't fill the engine).  This
    controller admits prompts of group g+1 into otherwise-idle slots while
    group g stragglers finish.  Group-g entries still train before any
    group-g+1 entry (consume order is by lifecycle), so the curriculum and
    no-starvation guarantees are preserved; only the strict "no new prompts
    until clear" rule is relaxed.  Measured in benchmarks/bench_throughput
    as the beyond-paper row.
    """

    def __init__(self, *args, lookahead: int = 1, **kw):
        super().__init__(*args, **kw)
        self.lookahead = lookahead
        self._next_groups: List = []   # queued (prompts, metas)

    def queue_group(self, prompts, metas=None):
        self._next_groups.append((list(prompts), metas))

    def _fill_engine(self) -> None:
        free = self.engine.free_slots()
        pending = len(self.buffer.pending())
        # admit next-group prompts only into slots the current group
        # cannot fill
        while (free > pending and self._next_groups
               and self.buffer.group_epoch_load_allowed()):
            prompts, metas = self._next_groups[0]
            take = min(free - pending, len(prompts))
            self.buffer.load_prompts_next_group(prompts[:take],
                                                (metas[:take] if metas else None))
            del prompts[:take]
            if metas:
                del metas[:take]
            if not prompts:
                self._next_groups.pop(0)
            pending += take
        super()._fill_engine()

    def run_queued(self) -> None:
        """Process every queued group to consumption."""
        while self._next_groups or self.buffer.unconsumed():
            if not self.buffer.unconsumed() and self._next_groups:
                prompts, metas = self._next_groups.pop(0)
                if prompts:
                    self.buffer.load_prompts(prompts, metas)
                continue
            self.rollout_until_harvest()
            remaining = (len(self.buffer.unconsumed())
                         - len(self.buffer.done()))
            self.train_ready(final=(remaining == 0))
            self.buffer.check_invariants()
            if self.buffer.current_group_clear() and not self.buffer.group_clear():
                self.buffer.advance_group(strict=False)
            elif self.buffer.group_clear():
                self.buffer.advance_group()

    def _train_order_key(self, e: BufferEntry):
        # strictly lifecycle-ordered so group g trains before group g+1
        # (curriculum preserved)
        return (e.lifecycle, e.gen_len)
