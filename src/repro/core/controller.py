"""Back-compat controller shims over the policy/orchestrator split.

The controller family used to re-implement the fill/step/harvest/train
loop four times.  That loop now lives once in
:class:`repro.core.orchestrator.RolloutOrchestrator`; the strategies are
:class:`repro.core.policy.SchedulerPolicy` objects selected by name from
a registry.  New code should wire those directly::

    from repro.core.orchestrator import RolloutOrchestrator, SortedRLConfig
    from repro.core.policy import make_policy

    orch = RolloutOrchestrator(engine, buffer, cfg,
                               make_policy("sorted"), train_fn)
    orch.run_group(prompts, metas)

The classes below keep the historical constructor signatures (including
the bare ``(entries, version)`` train callback) and map 1:1 onto a
policy:

    SortedRLController    -> make_policy("sorted", fill_policy=...)
    CanonicalController   -> make_policy("baseline" | "posthoc_sort")
    UngroupedController   -> make_policy("ungrouped", prompt_stream=...)
    PipelinedController   -> make_policy("pipelined", lookahead=...)
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.core.buffer import BufferEntry, StatefulRolloutBuffer
from repro.core.engine_api import EngineProtocol
from repro.core.metrics import RolloutMetrics
from repro.core.orchestrator import (RolloutOrchestrator, SortedRLConfig,
                                     UpdateRequest)
from repro.core.policy import (BaselinePolicy, PipelinedPolicy,
                               PostHocSortPolicy, SortedPolicy,
                               UngroupedPolicy)

__all__ = ["SortedRLConfig", "TrainFn", "SortedRLController",
           "CanonicalController", "UngroupedController",
           "PipelinedController"]

# legacy trainer callback: (entries, version) -> None
TrainFn = Callable[[List[BufferEntry], int], None]


def _wrap_legacy(train_fn: TrainFn):
    def typed(req: UpdateRequest) -> None:
        train_fn(req.entries, req.version)
    return typed


class SortedRLController(RolloutOrchestrator):
    """Paper §3.1/§3.3 strategy (shim; see module docstring)."""

    def __init__(self, engine: EngineProtocol, buffer: StatefulRolloutBuffer,
                 cfg: SortedRLConfig, train_fn: TrainFn,
                 metrics: Optional[RolloutMetrics] = None,
                 fill_policy: str = "resume_first"):
        super().__init__(engine, buffer, cfg,
                         SortedPolicy(fill_policy=fill_policy),
                         _wrap_legacy(train_fn), metrics)
        self.fill_policy = fill_policy


class CanonicalController(RolloutOrchestrator):
    """Wait-for-all baseline / post-hoc-sort ablation (shim)."""

    def __init__(self, engine: EngineProtocol, buffer: StatefulRolloutBuffer,
                 cfg: SortedRLConfig, train_fn: TrainFn,
                 metrics: Optional[RolloutMetrics] = None,
                 sort_post_hoc: bool = False, shuffle_seed: int = 0):
        policy = (PostHocSortPolicy(shuffle_seed=shuffle_seed)
                  if sort_post_hoc else
                  BaselinePolicy(shuffle_seed=shuffle_seed))
        super().__init__(engine, buffer, cfg, policy,
                         _wrap_legacy(train_fn), metrics)
        self.sort_post_hoc = sort_post_hoc


class UngroupedController(RolloutOrchestrator):
    """No-group-barrier ablation §4.4.2 (shim)."""

    def __init__(self, engine: EngineProtocol, buffer: StatefulRolloutBuffer,
                 cfg: SortedRLConfig, train_fn: TrainFn,
                 metrics: Optional[RolloutMetrics] = None,
                 prompt_stream=None, fill_policy: str = "resume_first"):
        super().__init__(engine, buffer, cfg,
                         UngroupedPolicy(prompt_stream=prompt_stream,
                                         fill_policy=fill_policy),
                         _wrap_legacy(train_fn), metrics)

    @property
    def prompt_stream(self):
        return self.policy.prompt_stream


class PipelinedController(RolloutOrchestrator):
    """Beyond-paper relaxed group barrier (shim)."""

    def __init__(self, engine: EngineProtocol, buffer: StatefulRolloutBuffer,
                 cfg: SortedRLConfig, train_fn: TrainFn,
                 metrics: Optional[RolloutMetrics] = None,
                 lookahead: int = 1):
        super().__init__(engine, buffer, cfg,
                         PipelinedPolicy(lookahead=lookahead),
                         _wrap_legacy(train_fn), metrics)

    def queue_group(self, prompts, metas=None) -> None:
        self.policy.queue_group(prompts, metas)
