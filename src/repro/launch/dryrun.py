import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination with ShapeDtypeStruct stand-ins (no allocation), print
memory_analysis / cost_analysis, and extract the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out FILE]

NOTE the XLA_FLAGS line above MUST run before any jax import (device count
locks on first init).  Tests/benches must NOT import this module.
"""
import argparse
import json
import re
import sys
import time
from typing import Dict, Optional

import jax

from repro.launch.hlo_cost import analyse_hlo
from repro.configs.base import (ARCH_ALIASES, ARCH_IDS, SHAPES,
                                get_config, shape_by_name)
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.plans import SKIPS, get_plan
from repro.launch.steps import build_step

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
               "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op in the (SPMD,
    per-device) HLO.  Returns per-kind byte counts."""
    out: Dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        kind, dtype, dims = m.group(1), m.group(2), m.group(3)
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] = out.get(kind, 0.0) + n * DTYPE_BYTES[dtype]
    return out


def analyse(arch: str, shape_name: str, multi_pod: bool,
            verbose: bool = True, overrides: Optional[Dict] = None
            ) -> Optional[Dict]:
    import dataclasses as _dc
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    akey = ARCH_ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    plan = get_plan(akey, shape_name)
    if plan is None:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": SKIPS[(akey, shape_name)]}
    if overrides:
        plan = _dc.replace(plan, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256
    t0 = time.time()
    built = build_step(cfg, shape, plan, mesh, multi_pod)
    jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                     out_shardings=built.out_shardings,
                     donate_argnums=built.donate_argnums)
    lowered = jitted.lower(*built.in_specs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    # loop-aware analysis over the per-device SPMD HLO (XLA's own
    # cost_analysis counts while bodies once — see hlo_cost.py)
    t0 = time.time()
    hc = analyse_hlo(compiled.as_text())
    t_cost = time.time() - t0
    flops = hc["flops"]                   # per device
    bytes_accessed = hc["bytes"]
    coll = hc["collectives"]
    coll_total = hc["collective_bytes"]
    xla_cost = compiled.cost_analysis()

    # roofline terms (seconds, per device = per step on the critical path)
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_accessed / HBM_BW
    t_coll = coll_total / ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]

    # MODEL_FLOPS: useful math for this step, per device.
    # N from the actual parameter tree (exact); MoE active = top-k fraction
    # of the expert weights.
    import math as _math
    import jax.tree_util as jtu
    N = N_active = 0
    for path, leaf in jtu.tree_flatten_with_path(built.in_specs[0])[0]:
        size = _math.prod(leaf.shape)
        N += size
        names = [str(getattr(p, "key", "")) for p in path]
        if cfg.family == "moe" and names[-1] in ("w_in", "w_gate", "w_out") \
                and len(leaf.shape) >= 3:
            size = size * cfg.moe.experts_per_token / cfg.moe.num_experts
        N_active += size
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    if shape.kind == "train":
        model_flops = 6 * N_active * tokens
    else:
        model_flops = 2 * N_active * tokens
    model_flops_per_dev = model_flops / chips

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "plan": {"strategy": plan.strategy, "fsdp": plan.fsdp,
                 "seq_parallel": plan.seq_parallel, "remat": plan.remat,
                 "microbatches": plan.microbatches,
                 "decode_cache": plan.decode_cache},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost_s": round(t_cost, 1),
        "xla_flops_unrolled_once": float(xla_cost.get("flops", 0.0)),
        "per_device": {
            "flops": flops,
            "bytes_accessed": bytes_accessed,
            "collective_bytes": coll_total,
            "collectives": coll,
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_hbm_gb": round((mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - mem.alias_size_in_bytes) / 2**30, 2),
        },
        "roofline": {
            "compute_s": t_compute, "memory_s": t_memory,
            "collective_s": t_coll, "dominant": dominant,
            "model_flops_per_dev": model_flops_per_dev,
            "useful_flops_ratio": (model_flops_per_dev / flops
                                   if flops else 0.0),
        },
        "params_total": N, "params_active": N_active,
    }
    if verbose:
        print(f"== {arch} x {shape_name} ({rec['mesh']}) "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s")
        print(f"   memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
              f"alias={mem.alias_size_in_bytes/2**30:.2f}GiB "
              f"peak~{rec['per_device']['peak_hbm_gb']}GiB/device")
        print(f"   cost_analysis: flops/dev={flops:.3e} bytes/dev={bytes_accessed:.3e} "
              f"coll/dev={coll_total:.3e} {coll}")
        r = rec["roofline"]
        print(f"   roofline: compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms "
              f"dominant={dominant} useful={r['useful_flops_ratio']:.2f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--override", default=None,
                    help="plan overrides, e.g. 'microbatches=1,decode_2d=True'")
    args = ap.parse_args()

    results = []
    combos = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))
    failures = 0
    overrides = {}
    if args.override:
        import ast
        for kv in args.override.split(","):
            k, v = kv.split("=")
            overrides[k] = ast.literal_eval(v)
    for a, s, mp in combos:
        try:
            rec = analyse(a, s, mp, overrides=overrides or None)
            if rec.get("skipped"):
                print(f"== {a} x {s}: SKIPPED ({rec['reason']})")
            results.append(rec)
        except Exception as e:  # noqa
            failures += 1
            print(f"== {a} x {s} multi_pod={mp} FAILED: {type(e).__name__}: {e}")
            results.append({"arch": a, "shape": s, "multi_pod": mp,
                            "error": f"{type(e).__name__}: {e}"})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
