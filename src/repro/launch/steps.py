"""Build the jit-able, sharded train/prefill/serve steps for one
(architecture x shape x mesh) combination — the functions the dry-run
lowers and the production launcher would execute.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import axis_rules
from repro.launch.plans import (Plan, activation_rules, cache_specs_for,
                                param_specs)
from repro.models import model as model_lib
from repro.rl.losses import LossConfig, total_loss
from repro.train.optimizer import AdamWConfig, OptState, adamw_update


def _ns(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass
class Built:
    """Everything dryrun/train needs for one combination."""
    fn: Any                     # the python step function
    in_specs: Tuple             # ShapeDtypeStructs (positional)
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    rules: Dict[str, Any]
    mesh: Mesh
    model: Any


def _batch_axes(multi_pod: bool, plan: Plan):
    axes = ("pod", "data") if multi_pod else ("data",)
    if plan.strategy == "dp":
        axes = axes + ("model",)
    return axes


AXIS_SIZE = {"pod": 2, "data": 16, "model": 16}


def _fit_batch_axes(B: int, axes):
    """Trim trailing mesh axes until their product divides the batch."""
    axes = tuple(axes)
    while axes:
        size = 1
        for a in axes:
            size *= AXIS_SIZE[a]
        if B % size == 0:
            return axes
        axes = axes[:-1]
    return ()


def _round_len(n: int, align: int = 512) -> int:
    """Cache lengths rounded to a 512 multiple so the sequence axis shards
    cleanly over (data x model)."""
    return -(-n // align) * align


def _batch_spec(B: int, axes) -> P:
    fit = _fit_batch_axes(B, axes)
    if not fit:
        return P()
    return P(fit if len(fit) > 1 else fit[0])


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, plan: Plan,
                     mesh: Mesh, multi_pod: bool) -> Built:
    cfg = cfg.replace(remat=plan.remat)
    rules = activation_rules(plan, multi_pod, "train")
    baxes = _batch_axes(multi_pod, plan)
    model = model_lib.build_model(
        cfg, ep_mesh=(mesh if cfg.family == "moe" else None),
        data_axes=baxes)
    loss_cfg = LossConfig()
    opt_cfg = AdamWConfig(state_dtype=plan.opt_dtype)
    nmicro = plan.microbatches

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            logits = logits[:, model.prefill_extra:]
        return total_loss(logits, aux, batch, loss_cfg)

    def train_step(params, opt_state, batch):
        with axis_rules(mesh, rules):
            if nmicro == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                def split(x):
                    return x.reshape(nmicro, x.shape[0] // nmicro,
                                     *x.shape[1:])
                mbs = jax.tree.map(split, batch)

                def acc(carry, mb):
                    gsum, lsum = carry
                    (l, _), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb)
                    gsum = jax.tree.map(jnp.add, gsum, g)
                    return (gsum, lsum + l), None

                g0 = jax.tree.map(jnp.zeros_like, params)
                (grads, loss), _ = jax.lax.scan(acc, (g0, 0.0), mbs)
                grads = jax.tree.map(lambda g: g / nmicro, grads)
                loss = loss / nmicro
                metrics = {}
            params, opt_state, om = adamw_update(params, grads, opt_state,
                                                 opt_cfg)
            metrics = dict(metrics)
            metrics.update(om)
            metrics["loss"] = loss
            return params, opt_state, metrics

    # specs
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, cfg, plan)
    opt_shape = OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape,
                                                      plan.opt_dtype),
                       params_shape),
        v=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape,
                                                      plan.opt_dtype),
                       params_shape))
    ospecs = OptState(step=P(), m=pspecs, v=pspecs)
    batch_shape = model_lib.input_specs(cfg, shape.seq_len,
                                        shape.global_batch, "train")
    bspecs = {k: P(*(tuple(_batch_spec(v.shape[0], baxes)) +
                     (None,) * (len(v.shape) - 1)))
              for k, v in batch_shape.items()}

    return Built(
        fn=train_step,
        in_specs=(params_shape, opt_shape, batch_shape),
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs),
                      _ns(mesh, bspecs)),
        out_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs), None),
        donate_argnums=(0, 1),
        rules=rules, mesh=mesh, model=model)


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, plan: Plan,
                       mesh: Mesh, multi_pod: bool) -> Built:
    cfg = cfg.replace(remat=False)
    rules = activation_rules(plan, multi_pod, "prefill")
    baxes = _batch_axes(multi_pod, plan)
    model = model_lib.build_model(
        cfg, ep_mesh=(mesh if cfg.family == "moe" else None),
        data_axes=baxes)
    max_len = _round_len(shape.seq_len + model.prefill_extra + 8)

    def prefill_step(params, batch, cache):
        with axis_rules(mesh, rules):
            logits, cache = model.prefill(params, batch, cache)
            # serving returns the next-token distribution at each slot end
            last = logits[:, -1]
            return jnp.argmax(last, axis=-1).astype(jnp.int32), cache

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, cfg, plan)
    batch_shape = model_lib.input_specs(cfg, shape.seq_len,
                                        shape.global_batch, "prefill")
    bspecs = {k: P(*(tuple(_batch_spec(v.shape[0], baxes)) +
                     (None,) * (len(v.shape) - 1)))
              for k, v in batch_shape.items()}
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, max_len))
    cspecs = cache_specs_for(cache_shape, cfg, plan, shape.global_batch,
                             multi_pod)

    return Built(
        fn=prefill_step,
        in_specs=(params_shape, batch_shape, cache_shape),
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs),
                      _ns(mesh, cspecs)),
        out_shardings=(None, _ns(mesh, cspecs)),
        donate_argnums=(2,),
        rules=rules, mesh=mesh, model=model)


def build_serve_step(cfg: ModelConfig, shape: ShapeConfig, plan: Plan,
                     mesh: Mesh, multi_pod: bool) -> Built:
    """Decode: ONE new token against a seq_len KV cache."""
    cfg = cfg.replace(remat=False)
    rules = activation_rules(plan, multi_pod, "decode")
    baxes = _batch_axes(multi_pod, plan)
    model = model_lib.build_model(
        cfg, ep_mesh=None,   # decode uses the dense-dispatch MoE path
        data_axes=baxes)
    max_len = _round_len(shape.seq_len + model.prefill_extra + 8)

    def serve_step(params, token, cache, kv_len):
        with axis_rules(mesh, rules):
            logits, cache = model.decode_step(params, token, cache, kv_len)
            nxt = jnp.argmax(logits.astype(jnp.float32), axis=-1)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            lp = jnp.take_along_axis(lp, nxt[:, None], axis=1)[:, 0]
            return nxt.astype(jnp.int32), lp, cache

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, cfg, plan)
    B = shape.global_batch
    token_shape = jax.ShapeDtypeStruct((B,), jnp.int32)
    kv_shape = jax.ShapeDtypeStruct((B,), jnp.int32)
    cache_shape = jax.eval_shape(lambda: model.init_cache(B, max_len))
    cspecs = cache_specs_for(cache_shape, cfg, plan, B, multi_pod)
    tspec = _batch_spec(B, baxes)

    return Built(
        fn=serve_step,
        in_specs=(params_shape, token_shape, cache_shape, kv_shape),
        in_shardings=(_ns(mesh, pspecs), NamedSharding(mesh, tspec),
                      _ns(mesh, cspecs), NamedSharding(mesh, tspec)),
        out_shardings=(NamedSharding(mesh, tspec),
                       NamedSharding(mesh, tspec), _ns(mesh, cspecs)),
        donate_argnums=(2,),
        rules=rules, mesh=mesh, model=model)


def build_step(cfg: ModelConfig, shape: ShapeConfig, plan: Plan, mesh: Mesh,
               multi_pod: bool) -> Built:
    if shape.kind == "train":
        return build_train_step(cfg, shape, plan, mesh, multi_pod)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, plan, mesh, multi_pod)
    return build_serve_step(cfg, shape, plan, mesh, multi_pod)
