"""Production training launcher.

On a real TPU pod this executes the sharded train_step built by
launch/steps.py under make_production_mesh(); on this CPU container it
runs the same code path in local bring-up mode: the reduced (smoke) config
on a 1x1 mesh, real data, real optimizer — proving the launch plumbing
end-to-end without TPU hardware.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --steps 3
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_ALIASES, get_config, get_smoke_config, shape_by_name
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.plans import Plan, get_plan
from repro.launch.steps import build_train_step
from repro.train.optimizer import init_opt_state, AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--local", action="store_true", default=True,
                    help="reduced config on the local mesh (CPU bring-up)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    if args.local:
        cfg = get_smoke_config(args.arch).replace(
            param_dtype=jnp.float32, compute_dtype=jnp.float32)
        mesh = make_local_mesh()
        plan = Plan(strategy="dp", fsdp=False, seq_parallel=False,
                    remat=False)
        shape = shape_by_name(args.shape).__class__(
            "local", args.seq, args.batch, "train")
        multi_pod = False
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh()
        akey = ARCH_ALIASES.get(args.arch, args.arch).replace(
            "-", "_").replace(".", "_")
        plan = get_plan(akey, args.shape)
        shape = shape_by_name(args.shape)
        multi_pod = False

    built = build_train_step(cfg, shape, plan, mesh, multi_pod)
    step = jax.jit(built.fn, in_shardings=built.in_shardings,
                   out_shardings=built.out_shardings,
                   donate_argnums=built.donate_argnums)
    key = jax.random.PRNGKey(0)
    params = built.model.init_params(key)
    opt = init_opt_state(params, AdamWConfig(state_dtype=plan.opt_dtype))
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        "advantages": jax.random.normal(key, (B, S)),
        "old_logprobs": -2.0 * jnp.ones((B, S)),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (B, cfg.num_stub_positions, cfg.d_model), cfg.compute_dtype)
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros(
            (B, cfg.num_stub_positions, cfg.d_model), cfg.compute_dtype)
    for i in range(args.steps):
        t0 = time.monotonic()
        params, opt, metrics = step(params, opt, batch)
        loss = float(metrics["loss"])
        print(f"step {i}: loss={loss:.4f} "
              f"grad_norm={float(metrics['grad_norm']):.3f} "
              f"({time.monotonic()-t0:.2f}s)")
        assert np.isfinite(loss)
    print("OK")


if __name__ == "__main__":
    main()
