"""Loop-aware cost analysis over compiled (post-SPMD, per-device) HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
under-counts every scanned layer stack by its depth (a 96-layer model shows
1/96th of its FLOPs).  This analyzer parses the HLO module, multiplies each
while body by its ``known_trip_count`` backend config, and reports:

  * flops            — dots (2*M*N*K incl. batch) + elementwise + reduces
  * bytes            — HBM-traffic model: operands+outputs of every
                       top-level instruction (fusion internals are free)
  * collective_bytes — per collective kind, output-shape bytes x trips

All numbers are per device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
               "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
               "s4": 1, "u4": 1}

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "negate", "abs", "sign", "floor", "ceil", "round",
    "compare", "select", "and", "or", "not", "xor", "clamp", "convert",
    "cosine", "sine", "logistic", "atan2", "remainder", "cbrt", "erf",
}
FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
        "bitcast-convert", "after-all", "opt-barrier", "partition-id",
        "replica-id", "iota", "reshape", "broadcast", "transpose", "copy",
        "slice", "dynamic-slice", "dynamic-update-slice", "concatenate",
        "pad", "reverse", "gather", "scatter", "reduce", "rng-bit-generator",
        "custom-call", "infeed", "outfeed", "while", "conditional", "call",
        "fusion", "dot", "convolution", "cholesky", "triangular-solve",
        "sort", "map", "reduce-window", "select-and-scatter", "domain"}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "all-gather-start", "all-reduce-start",
               "collective-permute-start")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?)\s([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(r"(?:calls|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def _split_args(rest: str) -> List[str]:
    """Call-argument strings of an instruction line, up to the closing
    paren.  Depth-aware over (), [] and {} — shape strings like
    ``f32[128,128]{1,0}`` carry commas that must not split."""
    args: List[str] = []
    depth = 0
    buf = ""
    for ch in rest:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if ch == ")" and depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            args.append(buf)
            buf = ""
        else:
            buf += ch
    if buf:
        args.append(buf)
    return args


def _operand_name(tok: str) -> str:
    """Operand name from an argument token; newer HLO emitters print
    ``%name``, older ones ``f32[4,4]{1,0} %name``."""
    words = tok.split()
    return words[-1].lstrip("%") if words else ""


def _shape_elems_bytes(type_str: str) -> Tuple[float, float]:
    """Total (elements, bytes) of a possibly-tuple type string."""
    elems = 0.0
    byts = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1.0
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * DTYPE_BYTES[dt]
    return elems, byts


class Instr:
    __slots__ = ("name", "type_str", "opcode", "rest", "out_elems",
                 "out_bytes")

    def __init__(self, name, type_str, opcode, rest):
        self.name = name
        self.type_str = type_str.strip()
        self.opcode = opcode
        self.rest = rest
        self.out_elems, self.out_bytes = _shape_elems_bytes(self.type_str)


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_module(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)
        if cur is None:
            m = _COMP_RE.match(line)
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip().startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    return comps


def _dot_flops(instr: Instr, symtab: Dict[str, Instr]) -> float:
    out_elems = instr.out_elems
    # K: product of lhs contracting dim sizes
    args = _split_args(instr.rest)
    lhs = symtab.get(_operand_name(args[0])) if args else None
    m = _CONTRACT_RE.search(instr.rest)
    if lhs is None or m is None:
        return 2.0 * out_elems
    sm = _SHAPE_RE.search(lhs.type_str)
    if sm is None:
        return 2.0 * out_elems
    dims = [int(d) for d in sm.group(2).split(",") if d]
    k = 1.0
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(dims):
            k *= dims[idx]
    return 2.0 * out_elems * k


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.text = text
        self._memo: Dict[str, Dict[str, float]] = {}
        # map while-instruction -> trip count (by body computation name)
        self.trips: Dict[str, int] = {}
        for line in text.splitlines():
            if " while(" in line:
                tm = _TRIP_RE.search(line)
                bm = re.search(r"body=%?([\w.\-]+)", line)
                if bm:
                    self.trips[bm.group(1)] = (int(tm.group(1)) if tm else 1)

    def _entry_name(self) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", self.text, re.M)
        if m:
            return m.group(1)
        return next(iter(self.comps))

    def comp_cost(self, name: str, top: bool = True) -> Dict[str, float]:
        key = (name, top)
        if key in self._memo:
            return self._memo[key]
        total = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
        coll: Dict[str, float] = {}
        self._memo[key] = total           # break recursion cycles
        symtab = {i.name: i for i in self.comps.get(name, [])}
        for instr in self.comps.get(name, []):
            op = instr.opcode
            if op == "while":
                bm = _CALLS_RE.search(instr.rest)
                cm = _COND_RE.search(instr.rest)
                if bm:
                    body = bm.group(1)
                    trips = self.trips.get(body, 1)
                    sub = self.comp_cost(body, top=top)
                    for k in total:
                        total[k] += sub[k] * trips
                    for k, v in sub.get("_coll", {}).items():
                        coll[k] = coll.get(k, 0.0) + v * trips
                if cm:
                    sub = self.comp_cost(cm.group(1), top=False)
                    total["flops"] += sub["flops"]
                continue
            if op in ("fusion", "call", "map"):
                bm = _CALLS_RE.search(instr.rest)
                called = bm.group(1) if bm else None
                out_bytes = instr.out_bytes
                if called:
                    sub = self.comp_cost(called, top=False)
                    total["flops"] += sub["flops"]
                    # in-place fusions (root = dynamic-update-slice on a
                    # donated buffer) write the update region, not the
                    # whole output buffer
                    body = self.comps.get(called, [])
                    if body and body[-1].opcode in ("dynamic-update-slice",
                                                    "scatter"):
                        ops_ = self._operands(body[-1],
                                              {i.name: i for i in body})
                        upd = sum(o.out_bytes for o in ops_[1:])
                        out_bytes = min(out_bytes, 2 * upd)
                    # fusion internal traffic is free; count boundary bytes
                if top:
                    total["bytes"] += out_bytes + \
                        self._fusion_operand_bytes(instr, symtab, called)
                continue
            if op == "conditional":
                branches = re.findall(r"(?:true_computation|false_computation|"
                                      r"branch_computations=\{)%?([\w.\-]+)",
                                      instr.rest)
                for b in branches[:1]:
                    sub = self.comp_cost(b, top=top)
                    for k in total:
                        total[k] += sub[k]
                continue
            if op == "dot" or op == "convolution":
                total["flops"] += _dot_flops(instr, symtab)
                if top:
                    total["bytes"] += instr.out_bytes + self._operand_bytes(
                        instr, symtab)
                continue
            if any(op.startswith(c) for c in COLLECTIVES):
                total["collective_bytes"] += instr.out_bytes
                coll[op.replace("-start", "")] = coll.get(
                    op.replace("-start", ""), 0.0) + instr.out_bytes
                if top:
                    total["bytes"] += instr.out_bytes + self._operand_bytes(
                        instr, symtab)
                continue
            if op in ELEMENTWISE:
                total["flops"] += instr.out_elems
            elif op.startswith("reduce"):
                total["flops"] += self._operand_elems(instr, symtab)
            if top and op not in ("parameter", "constant",
                                  "get-tuple-element", "tuple", "bitcast",
                                  "after-all", "opt-barrier"):
                if op in ("dynamic-update-slice", "scatter"):
                    # in-place region update: traffic = the update (read)
                    # plus the written region, NOT the whole buffer
                    ops_ = self._operands(instr, symtab)
                    upd = sum(o.out_bytes for o in ops_[1:])
                    total["bytes"] += 2.0 * upd
                elif op in ("dynamic-slice", "slice", "gather"):
                    total["bytes"] += 2.0 * instr.out_bytes
                else:
                    total["bytes"] += instr.out_bytes + self._operand_bytes(
                        instr, symtab)
        total["_coll"] = coll
        self._memo[key] = total
        return total

    def _operands(self, instr: Instr, symtab) -> List[Instr]:
        out = []
        for tok in _split_args(instr.rest):
            nm = _operand_name(tok)
            if nm in symtab:
                out.append(symtab[nm])
        return out

    def _operand_bytes(self, instr: Instr, symtab) -> float:
        return sum(o.out_bytes for o in self._operands(instr, symtab))

    def _fusion_operand_bytes(self, instr: Instr, symtab,
                              called: Optional[str]) -> float:
        """Operand HBM bytes for a fusion: an operand that the fused
        computation only touches via dynamic-slice contributes the SLICE
        bytes, not the whole array (scan bodies slice one layer out of the
        stacked weights — counting the full stack 13x over is wrong)."""
        operands = self._operands(instr, symtab)
        if called is None or called not in self.comps:
            return sum(o.out_bytes for o in operands)
        body = self.comps[called]
        params = {}
        for bi in body:
            if bi.opcode == "parameter":
                m = re.match(r"\s*(\d+)", bi.rest)
                if m:
                    params[int(m.group(1))] = bi.name
        total = 0.0
        for i, o in enumerate(operands):
            pname = params.get(i)
            if pname is None:
                total += o.out_bytes
                continue
            users = [bi for bi in body
                     if re.search(r"%?" + re.escape(pname) + r"\b", bi.rest)]
            if users and all(u.opcode in ("dynamic-slice", "slice", "gather")
                             for u in users):
                total += sum(u.out_bytes for u in users)
            elif users and all(u.opcode in ("dynamic-update-slice", "scatter")
                               for u in users):
                # whole-buffer passthrough with an in-place region write
                upd = 0.0
                for u in users:
                    uops = self._operands(u, {i.name: i for i in body})
                    upd += sum(x.out_bytes for x in uops[1:])
                total += 2.0 * upd
            else:
                total += o.out_bytes
        return total

    def _operand_elems(self, instr: Instr, symtab) -> float:
        return sum(o.out_elems for o in self._operands(instr, symtab))

    def entry_cost(self) -> Dict[str, float]:
        cost = dict(self.comp_cost(self._entry_name(), top=True))
        coll = cost.pop("_coll", {})
        cost["collectives"] = coll
        return cost


def analyse_hlo(text: str) -> Dict[str, float]:
    return HloCost(text).entry_cost()
