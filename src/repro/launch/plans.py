"""Per-(architecture x shape) execution plans: sharding strategy, remat,
microbatching, optimizer-state dtype, decode-cache layout.

Strategies
----------
* ``dp``   — pure data parallel: batch over (data, model); params replicated.
  For the small archs (<1B) where tensor parallelism only adds latency.
* ``tp``   — Megatron tensor parallel over `model` (+ sequence-parallel
  residual stream) with FSDP parameter/optimizer sharding over `data`.
* decode cache: ``kvheads`` shards the KV-head axis over `model`;
  ``seqshard`` shards the cache sequence axis (distributed flash-decode —
  required when kv_heads %% model != 0 and for the 500k context).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class Plan:
    strategy: str = "tp"            # dp | tp
    fsdp: bool = True               # shard params/opt over data (tp only)
    seq_parallel: bool = True       # residual stream seq over model (tp only)
    remat: bool = True
    microbatches: int = 1
    opt_dtype: Any = jnp.float32
    decode_cache: str = "kvheads"   # kvheads | seqshard
    # long_500k only: shard cache seq over both axes
    cache_seq_axes: Tuple[str, ...] = ("model",)
    # decode-only: replicate activations over `data` and contract the
    # data-sharded weight dims locally (2D tensor-parallel serving) instead
    # of FSDP-gathering weights every step.  See EXPERIMENTS.md §Perf.
    decode_2d: bool = False


def _dense_plan(big: bool = False, micro: int = 1) -> Plan:
    return Plan(strategy="tp", fsdp=True, seq_parallel=True, remat=True,
                microbatches=micro,
                opt_dtype=jnp.bfloat16 if big else jnp.float32)


PLANS: Dict[Tuple[str, str], Plan] = {}


def _set(arch: str, shape: str, plan: Plan) -> None:
    PLANS[(arch, shape)] = plan


# -- small archs: pure DP ----------------------------------------------------
for _a in ("xlstm_125m", "whisper_small", "qwen3_0_6b"):
    _set(_a, "train_4k", Plan(strategy="dp", fsdp=False, seq_parallel=False,
                              remat=True, microbatches=1))
    _set(_a, "prefill_32k", Plan(strategy="dp", fsdp=False,
                                 seq_parallel=False, remat=False))
    _set(_a, "decode_32k", Plan(strategy="dp", fsdp=False,
                                seq_parallel=False, remat=False,
                                decode_cache="seqshard"))
    _set(_a, "long_500k", Plan(strategy="dp", fsdp=False, seq_parallel=False,
                               remat=False, decode_cache="seqshard",
                               cache_seq_axes=("data", "model")))

# -- medium TP archs ---------------------------------------------------------
for _a in ("gemma2_2b", "zamba2_1_2b", "granite_moe_3b_a800m",
           "phi_3_vision_4_2b"):
    _set(_a, "train_4k", _dense_plan(micro=4))
    _set(_a, "prefill_32k", _dense_plan())
_set("gemma2_2b", "decode_32k", Plan(decode_cache="seqshard", remat=False))
_set("gemma2_2b", "long_500k", Plan(decode_cache="seqshard", remat=False,
                                    cache_seq_axes=("data", "model")))
_set("zamba2_1_2b", "decode_32k", Plan(decode_cache="kvheads", remat=False))
_set("zamba2_1_2b", "long_500k", Plan(decode_cache="seqshard", remat=False,
                                      cache_seq_axes=("data",)))
_set("granite_moe_3b_a800m", "decode_32k", Plan(decode_cache="seqshard",
                                                remat=False))
_set("phi_3_vision_4_2b", "decode_32k", Plan(decode_cache="kvheads",
                                             remat=False))

# -- big archs: TP + FSDP + SP + remat + microbatches + bf16 opt -------------
_set("qwen1_5_110b", "train_4k", _dense_plan(big=True, micro=4))
_set("qwen1_5_110b", "prefill_32k", _dense_plan(big=True))
_set("qwen1_5_110b", "decode_32k", Plan(decode_cache="seqshard", remat=False,
                                        opt_dtype=jnp.bfloat16,
                                        decode_2d=True))
_set("nemotron_4_340b", "train_4k", _dense_plan(big=True, micro=8))
_set("nemotron_4_340b", "prefill_32k", _dense_plan(big=True))
_set("nemotron_4_340b", "decode_32k", Plan(decode_cache="seqshard",
                                           remat=False,
                                           opt_dtype=jnp.bfloat16,
                                           decode_2d=True))
_set("qwen3_moe_235b_a22b", "train_4k", _dense_plan(big=True, micro=4))
_set("qwen3_moe_235b_a22b", "prefill_32k", _dense_plan(big=True))
_set("qwen3_moe_235b_a22b", "decode_32k", Plan(decode_cache="seqshard",
                                               remat=False,
                                               opt_dtype=jnp.bfloat16,
                                               decode_2d=True))
# whisper decode runs (enc-dec); handled by the small-arch loop above.

# HC1 (EXPERIMENTS §Perf): qwen3-0.6b prefill at batch 32 leaves the model
# axis idle under dp (16x redundant compute) -> tensor parallel.
_set("qwen3_0_6b", "prefill_32k", _dense_plan())

# Pairs intentionally absent (long_500k on pure full-attention archs) are
# documented skips — see DESIGN.md "Shape-matrix skips".
SKIPS: Dict[Tuple[str, str], str] = {
    ("qwen3_moe_235b_a22b", "long_500k"): "full attention, no windowed variant",
    ("qwen3_0_6b", "long_500k"): "full attention, no windowed variant",
    ("nemotron_4_340b", "long_500k"): "full attention, no windowed variant",
    ("qwen1_5_110b", "long_500k"): "full attention, no windowed variant",
    ("granite_moe_3b_a800m", "long_500k"): "full attention, no windowed variant",
    ("phi_3_vision_4_2b", "long_500k"): "full attention, no windowed variant",
    ("whisper_small", "long_500k"): "decoder max position 1.5k; 500k decode meaningless",
    ("qwen3_0_6b", "decode_32k"): None,   # placeholder removed below
}
del SKIPS[("qwen3_0_6b", "decode_32k")]
_set("qwen3_0_6b", "decode_32k", Plan(strategy="dp", fsdp=False,
                                      seq_parallel=False, remat=False,
                                      decode_cache="seqshard"))


# skips take precedence; drop any overlapping plan entries
for _k in SKIPS:
    PLANS.pop(_k, None)


def get_plan(arch: str, shape: str) -> Optional[Plan]:
    if (arch, shape) in SKIPS:
        return None
    return PLANS[(arch, shape)]


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs by pytree path
# ---------------------------------------------------------------------------

# number of leading layer-stack dims per top-level param group
def _n_lead(top: str, cfg: ModelConfig) -> int:
    from repro.models.transformer import pattern_len
    if top == "layers":
        return pattern_len(cfg)
    if top == "mamba_main":
        return 2
    if top in ("mamba_tail", "enc_layers", "dec_layers", "mlstm", "slstm"):
        return 1
    return 0


def _core_spec(path: str, shape: Tuple[int, ...], plan: Plan,
               cfg: ModelConfig) -> Tuple:
    """PartitionSpec entries for the non-stacked dims of one leaf."""
    fs = "data" if (plan.fsdp and plan.strategy == "tp") else None
    M = "model" if plan.strategy == "tp" else None
    leaf = path.split("/")[-1]
    group = path.split("/")[0]

    if group == "embed":
        if cfg.tie_embeddings:
            return (M, None)           # vocab over model (used as lm head)
        return (None, M)               # d over model: cheap input gather
    if group == "lm_head":
        return (fs, M)                 # vocab over model
    if group == "pos_embed":
        return (None, None)
    if leaf in ("wq", "wk", "wv"):
        return (fs, M, None)
    if leaf == "wo":
        return (M, None, fs)
    if leaf in ("bq", "bk", "bv"):
        return (M, None)
    if leaf in ("q_norm", "k_norm"):
        return (None,)
    if leaf in ("w_in", "w_gate", "w_out") and len(shape) == 3:   # MoE expert
        return (M, fs, None) if leaf != "w_out" else (M, None, fs)
    if leaf in ("w_in", "w_gate"):
        return (fs, M)
    if leaf == "w_out":
        return (M, fs)
    if leaf == "router":
        return (None, None)
    # mamba2
    if leaf in ("in_z", "in_x", "in_dt"):
        return (fs, M)
    if leaf == "in_bc":
        return (fs, None)
    if leaf == "conv_x_w":
        return (None, M)
    if leaf == "conv_x_b":
        return (M,)
    if leaf in ("conv_bc_w", "conv_bc_b"):
        return (None,) * len(shape)
    if leaf in ("A_log", "dt_bias", "D"):
        return (M,)
    if leaf == "gate_norm":
        return (M,)
    if leaf == "out_proj":
        return (M, fs)
    # xlstm / norms / everything else: replicated
    return (None,) * len(shape)


def param_specs(params_shape, cfg: ModelConfig, plan: Plan):
    """ShapeDtypeStruct pytree -> PartitionSpec pytree (same structure)."""
    import jax

    def spec_for(path_tuple, leaf):
        parts = []
        for p in path_tuple:
            key = getattr(p, "key", None)
            parts.append(str(key) if key is not None
                         else str(getattr(p, "idx", p)))
        path = "/".join(parts)
        top = parts[0]
        n_lead = _n_lead(top, cfg)
        core = _core_spec("/".join([top, parts[-1]]), leaf.shape[n_lead:],
                          plan, cfg)
        full = (None,) * n_lead + tuple(core)
        assert len(full) == len(leaf.shape), (path, full, leaf.shape)
        # drop axes that don't divide evenly -> replicate that dim
        fixed = []
        for dim, ax in zip(leaf.shape, full):
            if ax is None:
                fixed.append(None)
                continue
            size = {"data": 16, "model": 16}.get(ax, 1)
            fixed.append(ax if dim % size == 0 else None)
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


# ---------------------------------------------------------------------------
# Activation logical-axis rules per plan
# ---------------------------------------------------------------------------

def activation_rules(plan: Plan, multi_pod: bool, kind: str) -> Dict[str, Any]:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    if plan.decode_2d and kind == "decode" and plan.strategy == "tp":
        # 2D TP decode: activations replicated over data; the d-sharded
        # weight dims contract locally with small activation psums instead
        # of full weight gathers per step.
        return {
            "batch": ("pod",) if multi_pod else None,
            "seq": None, "seq_attn": None, "seq_out": None,
            "embed": "data",
            "heads": "model", "kv_heads": "model", "head_dim": None,
            "ffn": "model", "vocab": "model", "experts": "model",
            "ssm_heads": "model", "ssm_state": None,
            "fsdp": "data" if plan.fsdp else None,
            "cache_seq": None,
        }
    if plan.strategy == "dp":
        batch = batch_axes + ("model",)
        rules = {k: None for k in
                 ("seq", "seq_attn", "seq_out", "embed", "heads", "kv_heads",
                  "head_dim", "ffn", "vocab", "experts", "ssm_heads",
                  "ssm_state", "cache_seq")}
        rules["batch"] = batch
        rules["fsdp"] = None
        return rules
    rules = {
        "batch": batch_axes,
        "seq": "model" if (plan.seq_parallel and kind == "train") else None,
        "seq_attn": None,
        "seq_out": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ffn": "model",
        "vocab": "model",
        "experts": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "fsdp": "data" if plan.fsdp else None,
        "cache_seq": None,
    }
    return rules


# ---------------------------------------------------------------------------
# Cache PartitionSpecs (decode shapes)
# ---------------------------------------------------------------------------

# cache leaf layouts: name -> (batch_axis_index, seq_axis_index or None,
#                              kvhead_axis_index or None)
CACHE_LAYOUT = {
    "k": (1, 2, 3), "v": (1, 2, 3),
    "k_local": (1, 2, 3), "v_local": (1, 2, 3),
    "k_global": (1, 2, 3), "v_global": (1, 2, 3),
    "k_x": (1, 2, 3), "v_x": (1, 2, 3),
    "attn_k": (1, 2, 3), "attn_v": (1, 2, 3),
    "ssm_main": (2, None, None), "conv_x_main": (2, None, None),
    "conv_bc_main": (2, None, None),
    "ssm_tail": (1, None, None), "conv_x_tail": (1, None, None),
    "conv_bc_tail": (1, None, None),
    "mlstm_C": (1, None, None), "mlstm_n": (1, None, None),
    "mlstm_conv": (1, None, None),
    "slstm_c": (1, None, None), "slstm_n": (1, None, None),
    "slstm_h": (1, None, None), "slstm_m": (1, None, None),
}


def cache_specs_for(cache_shape, cfg: ModelConfig, plan: Plan,
                    batch: int, multi_pod: bool):
    """Cache ShapeDtypeStruct pytree -> PartitionSpec pytree."""
    import jax
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    n_model = 16

    def spec_for(path_tuple, leaf):
        name = str(getattr(path_tuple[-1], "key", path_tuple[-1]))
        b_ax, s_ax, kh_ax = CACHE_LAYOUT[name]
        spec = [None] * len(leaf.shape)
        # batch
        if leaf.shape[b_ax] % (16 * (2 if multi_pod else 1)) == 0:
            spec[b_ax] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        elif multi_pod and leaf.shape[b_ax] % 2 == 0 and plan.decode_cache == "seqshard":
            spec[b_ax] = "pod"
        elif leaf.shape[b_ax] % 16 == 0:
            spec[b_ax] = "data"
        if s_ax is not None:
            if plan.decode_cache == "seqshard":
                axes = plan.cache_seq_axes
                # avoid double-use of an axis already used for batch
                used = spec[b_ax]
                used = (used if isinstance(used, tuple)
                        else (used,) if used else ())
                axes = tuple(a for a in axes if a not in used)
                if multi_pod and "pod" not in used and "pod" not in axes \
                        and spec[b_ax] is None:
                    axes = ("pod",) + axes
                size = 1
                for a in axes:
                    size *= {"pod": 2, "data": 16, "model": 16}[a]
                if axes and leaf.shape[s_ax] % size == 0:
                    spec[s_ax] = axes if len(axes) > 1 else axes[0]
            elif kh_ax is not None and plan.decode_cache == "kvheads" \
                    and leaf.shape[kh_ax] % n_model == 0:
                spec[kh_ax] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)
