"""Production mesh builders (TPU v5e pods).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state — dryrun.py must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax

# jax >= 0.5 exposes jax.sharding.AxisType and make_mesh(axis_types=...);
# older releases have neither (every axis is implicitly Auto).
try:
    from jax.sharding import AxisType
except ImportError:          # pragma: no cover - depends on jax version
    AxisType = None


def make_compat_mesh(shape, axes):
    """jax.make_mesh with Auto axis types across jax versions."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return make_compat_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) — roofline denominators
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
