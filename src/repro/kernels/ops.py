"""Jitted public wrappers around the Pallas kernels.

``use_pallas(True)`` switches the model layers onto the kernels (TPU); the
default pure-jnp path is used on CPU and as the oracle.  ``interpret``
defaults to True because this container is CPU-only — on real TPU set
``REPRO_PALLAS_INTERPRET=0``.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.ragged_decode_attention import (
    fused_sample as _fused_sample)
from repro.kernels.ragged_decode_attention import (
    paged_decode_attention as _paged)
from repro.kernels.ragged_decode_attention import (
    ragged_decode_attention as _ragged)

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("block_k", "softcap"))
def ragged_decode_attention(q, k_cache, v_cache, kv_len, block_k: int = 128,
                            softcap: float = 0.0):
    return _ragged(q, k_cache, v_cache, kv_len, block_k=block_k,
                   softcap=softcap, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("softcap",))
def paged_decode_attention(q, k_pages, v_pages, block_tables, kv_len,
                           softcap: float = 0.0):
    return _paged(q, k_pages, v_pages, block_tables, kv_len,
                  softcap=softcap, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("softcap",))
def paged_decode_attention_int8(q, k_pages, v_pages, k_scales, v_scales,
                                block_tables, kv_len, softcap: float = 0.0):
    """Paged decode over int8-quantized pages with per-page f32 scales."""
    return _paged(q, k_pages, v_pages, block_tables, kv_len,
                  softcap=softcap, k_scales=k_scales, v_scales=v_scales,
                  interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("top_k", "block_v", "softcap"))
def fused_sample(x, w, top_k: int = 1, block_v: int = 128,
                 softcap: float = 0.0):
    """Fused LM-head matmul + top-k + logsumexp (no (B, V) round-trip)."""
    return _fused_sample(x, w, top_k=top_k, block_v=block_v,
                         softcap=softcap, interpret=INTERPRET)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "window",
                                    "softcap"))
def flash_attention(q, k, v, seg_ids=None, block_q: int = 128,
                    block_k: int = 128, window: int = 0,
                    softcap: float = 0.0):
    return _flash(q, k, v, seg_ids=seg_ids, block_q=block_q,
                  block_k=block_k, window=window, softcap=softcap,
                  interpret=INTERPRET)
