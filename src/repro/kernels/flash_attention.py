"""Causal GQA flash-attention Pallas TPU kernel (prefill path).

Partial-mode resume re-prefills scavenged prefixes (paper §3.2), so prefill
throughput is on the rollout critical path alongside decode.  Blockwise
online softmax with causal *and* sliding-window block skipping: a kv block
is visited only when it intersects [q_start - window, q_end] — local
(gemma2) layers touch O(S * W) instead of O(S^2) work.

Tiling: grid (B, H, S//block_q, S//block_k); (block_q, D) query tile and
(block_k, D) kv tiles in VMEM; f32 scratch accumulators.  GQA maps query
head h to kv head h // (H // Kh) in the BlockSpec index map.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, *rest, block_q: int, block_k: int,
            window: int, softcap: float, scale: float, seg: bool):
    if seg:
        sq_ref, sk_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        sq_ref = sk_ref = None
        o_ref, m_ref, l_ref, acc_ref = rest
    qblk = pl.program_id(2)
    kblk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kblk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qblk * block_q
    k_start = kblk * block_k
    # causal skip: kv block entirely after the q block
    visible = k_start <= q_start + block_q - 1
    if window > 0:
        # window skip: kv block entirely before the window of every q row
        visible = jnp.logical_and(
            visible, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(visible)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[...].astype(jnp.float32)                  # (bk, D)
        v = v_ref[...].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = qpos >= kpos
        if window > 0:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        if seg:
            # packed prefill: no attention across segment boundaries
            mask = jnp.logical_and(
                mask, sq_ref[...][:, None] == sk_ref[...][None, :])
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                                 # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kblk == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    seg_ids: jnp.ndarray = None,
                    block_q: int = 128, block_k: int = 128,
                    window: int = 0, softcap: float = 0.0,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, S, H, D); k/v: (B, S, Kh, D) -> (B, S, H, D).  Causal.

    ``seg_ids``: optional (B, S) int32 segment ids for packed ragged
    prefill — several prompts concatenated per row attend only within
    their own segment (pad positions carry -1; their output rows are
    garbage and must be discarded by the caller).  The seg tile rides in
    as two extra VMEM operands (a block_q view for queries, a block_k
    view for keys of the same array)."""
    B, S, H, D = q.shape
    Kh = k.shape[2]
    G = H // Kh
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    grid = (B, H, S // block_q, S // block_k)
    kernel = functools.partial(_kernel, block_q=block_q, block_k=block_k,
                               window=window, softcap=softcap,
                               scale=1.0 / math.sqrt(D),
                               seg=seg_ids is not None)
    in_specs = [
        pl.BlockSpec((None, block_q, None, D),
                     lambda b, h, qb, kb: (b, qb, h, 0)),
        pl.BlockSpec((None, block_k, None, D),
                     lambda b, h, qb, kb: (b, kb, h // G, 0)),
        pl.BlockSpec((None, block_k, None, D),
                     lambda b, h, qb, kb: (b, kb, h // G, 0)),
    ]
    inputs = [q, k, v]
    if seg_ids is not None:
        assert seg_ids.shape == (B, S), (seg_ids.shape, (B, S))
        in_specs.append(pl.BlockSpec((None, block_q),
                                     lambda b, h, qb, kb: (b, qb)))
        in_specs.append(pl.BlockSpec((None, block_k),
                                     lambda b, h, qb, kb: (b, kb)))
        inputs.extend([seg_ids.astype(jnp.int32),
                       seg_ids.astype(jnp.int32)])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, block_q, None, D),
                               lambda b, h, qb, kb: (b, qb, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
        name="flash_attention",
    )(*inputs)
