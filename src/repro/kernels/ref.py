"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import layers as L


def ragged_decode_attention_ref(q, k_cache, v_cache, kv_len,
                                softcap: float = 0.0) -> jnp.ndarray:
    """(B, H, D) x (B, S, Kh, D) x (B,) -> (B, H, D)."""
    return L.decode_attention(q, k_cache, v_cache, kv_len, softcap=softcap)


def gather_pages(pages: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """Materialise a dense per-slot cache view from a paged pool.

    pages: (N, page, Kh, D); block_tables: (B, nb) -> (B, nb*page, Kh, D).
    This is the kernel-free path the engine uses on CPU: the paged Pallas
    kernel reads the same pages block-by-block instead of gathering.
    """
    B, nb = block_tables.shape
    g = jnp.take(pages, block_tables.reshape(-1), axis=0)
    return g.reshape(B, nb * pages.shape[1], *pages.shape[2:])


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, kv_len,
                               softcap: float = 0.0) -> jnp.ndarray:
    """(B, H, D) x (N, page, Kh, D) x (B, nb) x (B,) -> (B, H, D)."""
    return L.decode_attention(q, gather_pages(k_pages, block_tables),
                              gather_pages(v_pages, block_tables),
                              kv_len, softcap=softcap)


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0,
                        softcap: float = 0.0) -> jnp.ndarray:
    """(B, S, H, D) GQA causal attention oracle."""
    return L.full_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap)
