"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

# Documented numeric tolerance of int8 KV pages: max-abs-error of the
# paged decode attention OUTPUT (and hence, through the LM head, of the
# decode logits up to the head's Lipschitz constant) versus the fp path
# on the same KV, for per-page symmetric scale quantization
# (scale = amax / 127).  Asserted by the kernel oracle tests
# (tests/test_kernels.py) and documented in README §Kernel & memory
# roofline.
KV_INT8_DECODE_ATOL = 0.05


def ragged_decode_attention_ref(q, k_cache, v_cache, kv_len,
                                softcap: float = 0.0) -> jnp.ndarray:
    """(B, H, D) x (B, S, Kh, D) x (B,) -> (B, H, D)."""
    return L.decode_attention(q, k_cache, v_cache, kv_len, softcap=softcap)


def gather_pages(pages: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """Materialise a dense per-slot cache view from a paged pool.

    pages: (N, page, Kh, D); block_tables: (B, nb) -> (B, nb*page, Kh, D).
    This is the kernel-free path the engine uses on CPU: the paged Pallas
    kernel reads the same pages block-by-block instead of gathering.
    """
    B, nb = block_tables.shape
    g = jnp.take(pages, block_tables.reshape(-1), axis=0)
    return g.reshape(B, nb * pages.shape[1], *pages.shape[2:])


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, kv_len,
                               softcap: float = 0.0) -> jnp.ndarray:
    """(B, H, D) x (N, page, Kh, D) x (B, nb) x (B,) -> (B, H, D)."""
    return L.decode_attention(q, gather_pages(k_pages, block_tables),
                              gather_pages(v_pages, block_tables),
                              kv_len, softcap=softcap)


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, seg_ids=None) -> jnp.ndarray:
    """(B, S, H, D) GQA causal attention oracle (packed via seg_ids)."""
    return L.full_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, seg_q=seg_ids, seg_k=seg_ids)


def quantize_pages_ref(pages: jnp.ndarray):
    """Per-page symmetric int8 quantization: (N, page, Kh, D) fp ->
    (int8 pages, (N,) f32 scales) with scale = amax / 127 (1e-8 floor, so
    all-zero pages round-trip exactly)."""
    amax = jnp.max(jnp.abs(pages.astype(jnp.float32)), axis=(1, 2, 3))
    scales = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(pages.astype(jnp.float32)
                           / scales[:, None, None, None]), -127, 127)
    return q.astype(jnp.int8), scales


def dequantize_pages_ref(pages: jnp.ndarray, scales: jnp.ndarray
                         ) -> jnp.ndarray:
    """(N, page, Kh, D) int8 x (N,) f32 -> f32 pages."""
    return pages.astype(jnp.float32) * scales[:, None, None, None]


def paged_decode_attention_int8_ref(q, k_pages, v_pages, k_scales, v_scales,
                                    block_tables, kv_len,
                                    softcap: float = 0.0) -> jnp.ndarray:
    """int8-page oracle: dequantize the pools, then the fp paged ref."""
    return paged_decode_attention_ref(
        q, dequantize_pages_ref(k_pages, k_scales),
        dequantize_pages_ref(v_pages, v_scales), block_tables, kv_len,
        softcap=softcap)


def fused_sample_ref(x, w, top_k: int = 1, softcap: float = 0.0):
    """Two-pass oracle for the fused sampling kernel: materialise the
    full (B, V) logits, then top-k + logsumexp."""
    logits = jnp.einsum("bd,dv->bv", x, w).astype(jnp.float32)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    vals, idx = jax.lax.top_k(logits, top_k)
    lse = jax.nn.logsumexp(logits, axis=-1, keepdims=True)
    return vals, idx.astype(jnp.int32), lse
