"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import layers as L


def ragged_decode_attention_ref(q, k_cache, v_cache, kv_len,
                                softcap: float = 0.0) -> jnp.ndarray:
    """(B, H, D) x (B, S, Kh, D) x (B,) -> (B, H, D)."""
    return L.decode_attention(q, k_cache, v_cache, kv_len, softcap=softcap)


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0,
                        softcap: float = 0.0) -> jnp.ndarray:
    """(B, S, H, D) GQA causal attention oracle."""
    return L.full_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap)
