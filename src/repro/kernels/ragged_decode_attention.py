"""Ragged GQA decode-attention Pallas TPU kernel — the rollout hotolayer.

One new token per slot attends over a per-slot-length KV cache.  This is
the kernel the paper's scheduling feeds: length-sorted batches mean
neighbouring slots share similar ``kv_len``, so the kv-block skip pattern
(``@pl.when`` on block start < kv_len) is uniform across the grid and the
engine streams only live cache — the TPU-native payoff of SortedRL's
sorting (see DESIGN.md §3).

Tiling: grid (B, S // block_k); each program holds the full (H, D) query
tile in VMEM plus one (block_k, Kh, D) cache tile; flash-decode online
softmax accumulates in VMEM scratch across the sequential k dimension.
MXU alignment: block_k multiples of 128; D is the lane dimension.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, block_k: int, softcap: float):
    """Refs: kv_len (1,) i32 | q (H, D) | k/v (block_k, Kh, D) |
    o (H, D) | scratch m/l (H, 1) f32, acc (H, D) f32."""
    kblk = pl.program_id(1)
    nk = pl.num_programs(1)
    kv_len = kv_len_ref[0]

    @pl.when(kblk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kstart = kblk * block_k

    @pl.when(kstart < kv_len)           # ragged block skip
    def _compute():
        q = q_ref[...].astype(jnp.float32)            # (H, D)
        k = k_ref[...].astype(jnp.float32)            # (bk, Kh, D)
        v = v_ref[...].astype(jnp.float32)
        H, D = q.shape
        bk, Kh, _ = k.shape
        G = H // Kh
        qg = q.reshape(Kh, G, D) / math.sqrt(D)
        s = jnp.einsum("hgd,khd->hgk", qg, k,
                       preferred_element_type=jnp.float32)   # (Kh, G, bk)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        pos = kstart + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bk), 2)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...].reshape(Kh, G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(pos < kv_len, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                      # (Kh, G, 1)
        l_new = l_ref[...].reshape(Kh, G, 1) * alpha + jnp.sum(
            p, axis=-1, keepdims=True)
        pv = jnp.einsum("hgk,khd->hgd", p, v,
                        preferred_element_type=jnp.float32)
        acc_ref[...] = (acc_ref[...].reshape(Kh, G, D) * alpha
                        + pv).reshape(H, D)
        m_ref[...] = m_new.reshape(H, 1)
        l_ref[...] = l_new.reshape(H, 1)

    @pl.when(kblk == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


def ragged_decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                            v_cache: jnp.ndarray, kv_len: jnp.ndarray,
                            *, block_k: int = 128, softcap: float = 0.0,
                            interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, D); k/v_cache: (B, S, Kh, D); kv_len: (B,) -> (B, H, D).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on TPU pass interpret=False for the compiled kernel.
    """
    B, H, D = q.shape
    S, Kh = k_cache.shape[1], k_cache.shape[2]
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k
    grid = (B, nk)
    kernel = functools.partial(_kernel, block_k=block_k, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, kb: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, H, D), lambda b, kb: (b, 0, 0)),
            pl.BlockSpec((None, block_k, Kh, D), lambda b, kb: (b, kb, 0, 0)),
            pl.BlockSpec((None, block_k, Kh, D), lambda b, kb: (b, kb, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, H, D), lambda b, kb: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
        interpret=interpret,
        name="ragged_decode_attention",
    )(kv_len, q, k_cache, v_cache)
