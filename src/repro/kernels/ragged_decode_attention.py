"""Ragged GQA decode-attention Pallas TPU kernels — the rollout hot layer.

One new token per slot attends over a per-slot-length KV cache.  This is
the kernel the paper's scheduling feeds: length-sorted batches mean
neighbouring slots share similar ``kv_len``, so the kv-block skip pattern
(``@pl.when`` on block start < kv_len) is uniform across the grid and the
engine streams only live cache — the TPU-native payoff of SortedRL's
sorting (see DESIGN.md §3).

Two variants share one kernel body:

* ``ragged_decode_attention`` — dense ``(B, S, Kh, D)`` cache, kv blocks
  addressed contiguously (grid position == block index);
* ``paged_decode_attention`` — the cache is a pool of fixed-size pages
  ``(N, page, Kh, D)`` and each slot owns a *block table* mapping logical
  kv blocks to physical pages (``repro.core.kv_cache``).  The table is a
  scalar-prefetch operand, so the BlockSpec index_map dereferences it to
  DMA exactly the pages a slot maps — shared GRPO prefix pages stream
  once per slot without ever materialising a dense per-slot cache.

Tiling: grid (B, S // block_k); each program holds the full (H, D) query
tile in VMEM plus one (block_k, Kh, D) cache tile; flash-decode online
softmax accumulates in VMEM scratch across the sequential k dimension.
MXU alignment: block_k multiples of 128; D is the lane dimension.  For
the paged variant block_k == page size; production pools use 128-row
pages (multiple-of-128 constraint), tests exercise smaller interpreted
shapes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_decode_block(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                        *, kblk, nk, kstart, kv_len, softcap: float,
                        k_scale=None, v_scale=None):
    """Shared flash-decode body: one (block_k, Kh, D) kv tile starting at
    logical position `kstart`, online-softmax accumulated in VMEM scratch.
    Refs: q (H, D) | k/v (block_k, Kh, D) | o (H, D) |
    scratch m/l (H, 1) f32, acc (H, D) f32.
    ``k_scale``/``v_scale``: per-page dequant scalars (int8 KV pages)."""

    @pl.when(kblk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(kstart < kv_len)           # ragged block skip
    def _compute():
        q = q_ref[...].astype(jnp.float32)            # (H, D)
        k = k_ref[...].astype(jnp.float32)            # (bk, Kh, D)
        v = v_ref[...].astype(jnp.float32)
        if k_scale is not None:
            k = k * k_scale
        if v_scale is not None:
            v = v * v_scale
        H, D = q.shape
        bk, Kh, _ = k.shape
        G = H // Kh
        qg = q.reshape(Kh, G, D) / math.sqrt(D)
        s = jnp.einsum("hgd,khd->hgk", qg, k,
                       preferred_element_type=jnp.float32)   # (Kh, G, bk)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        pos = kstart + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bk), 2)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...].reshape(Kh, G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(pos < kv_len, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                      # (Kh, G, 1)
        l_new = l_ref[...].reshape(Kh, G, 1) * alpha + jnp.sum(
            p, axis=-1, keepdims=True)
        pv = jnp.einsum("hgk,khd->hgd", p, v,
                        preferred_element_type=jnp.float32)
        acc_ref[...] = (acc_ref[...].reshape(Kh, G, D) * alpha
                        + pv).reshape(H, D)
        m_ref[...] = m_new.reshape(H, 1)
        l_ref[...] = l_new.reshape(H, 1)

    @pl.when(kblk == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


def _kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, block_k: int, softcap: float):
    """Dense variant: kv block `kb` sits at cache rows [kb*block_k, ...)."""
    kblk = pl.program_id(1)
    _flash_decode_block(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                        kblk=kblk, nk=pl.num_programs(1),
                        kstart=kblk * block_k, kv_len=kv_len_ref[0],
                        softcap=softcap)


def _paged_kernel(bt_ref, kv_len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page_size: int, softcap: float):
    """Paged variant: the BlockSpec index_map already dereferenced the
    block table (scalar prefetch), so k/v refs hold the physical page for
    logical block `kb`; only the position base differs from dense."""
    del bt_ref   # consumed by the index_map
    b = pl.program_id(0)
    kblk = pl.program_id(1)
    _flash_decode_block(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                        kblk=kblk, nk=pl.num_programs(1),
                        kstart=kblk * page_size, kv_len=kv_len_ref[b],
                        softcap=softcap)


def _paged_kernel_int8(bt_ref, kv_len_ref, ks_ref, vs_ref, q_ref, k_ref,
                       v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                       page_size: int, softcap: float):
    """int8-page variant: k/v pages are stored quantized with one f32
    scale per physical page; the scales ride in as scalar-prefetch
    operands and are dereferenced through the same block table as the
    page itself, so dequant happens in-register after the page DMA."""
    b = pl.program_id(0)
    kblk = pl.program_id(1)
    page = bt_ref[b, kblk]
    _flash_decode_block(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                        kblk=kblk, nk=pl.num_programs(1),
                        kstart=kblk * page_size, kv_len=kv_len_ref[b],
                        softcap=softcap,
                        k_scale=ks_ref[page], v_scale=vs_ref[page])


def ragged_decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                            v_cache: jnp.ndarray, kv_len: jnp.ndarray,
                            *, block_k: int = 128, softcap: float = 0.0,
                            interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, D); k/v_cache: (B, S, Kh, D); kv_len: (B,) -> (B, H, D).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on TPU pass interpret=False for the compiled kernel.
    """
    B, H, D = q.shape
    S, Kh = k_cache.shape[1], k_cache.shape[2]
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k
    grid = (B, nk)
    kernel = functools.partial(_kernel, block_k=block_k, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, kb: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, H, D), lambda b, kb: (b, 0, 0)),
            pl.BlockSpec((None, block_k, Kh, D), lambda b, kb: (b, kb, 0, 0)),
            pl.BlockSpec((None, block_k, Kh, D), lambda b, kb: (b, kb, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, H, D), lambda b, kb: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
        interpret=interpret,
        name="ragged_decode_attention",
    )(kv_len, q, k_cache, v_cache)


def paged_decode_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                           kv_len: jnp.ndarray, *, softcap: float = 0.0,
                           k_scales: jnp.ndarray = None,
                           v_scales: jnp.ndarray = None,
                           interpret: bool = True) -> jnp.ndarray:
    """Decode attention over a paged KV pool.

    q: (B, H, D); k/v_pages: (N, page, Kh, D) physical page pool;
    block_tables: (B, nb) i32 — logical kv block j of slot b lives in
    physical page ``block_tables[b, j]`` (pad unused entries with any
    valid page id; rows past ``kv_len`` are masked); kv_len: (B,) valid
    lengths.  Returns (B, H, D).

    The block table and kv_len ride in as scalar-prefetch operands so the
    k/v index_maps can dereference the table — each grid step DMAs one
    physical page, which is how a GRPO group's shared prefix pages are
    read by every member without a dense per-slot copy.

    ``k_scales``/``v_scales``: (N,) f32 per-page dequant scales for int8
    page pools (``kv_quant="int8"`` engines).  They join the scalar
    prefetch so the kernel dequantises each page in-register right after
    its DMA — the pool stays int8 in HBM, halving (vs bf16; quartering vs
    f32) the decode's memory traffic and doubling effective capacity.
    """
    B, H, D = q.shape
    page, Kh = k_pages.shape[1], k_pages.shape[2]
    nb = block_tables.shape[1]
    assert block_tables.shape[0] == B and kv_len.shape == (B,)
    quant = k_scales is not None
    assert quant == (v_scales is not None)
    if quant:
        kernel = functools.partial(_paged_kernel_int8, page_size=page,
                                   softcap=softcap)
        nsp = 4                      # block_tables, kv_len, k/v_scales
        scalar_ops = (block_tables.astype(jnp.int32),
                      kv_len.astype(jnp.int32),
                      k_scales.astype(jnp.float32),
                      v_scales.astype(jnp.float32))

        def q_map(b, kb, bt, kl, ks, vs):
            return (b, 0, 0)

        def kv_map(b, kb, bt, kl, ks, vs):
            return (bt[b, kb], 0, 0, 0)
    else:
        kernel = functools.partial(_paged_kernel, page_size=page,
                                   softcap=softcap)
        nsp = 2                      # block_tables, kv_len
        scalar_ops = (block_tables.astype(jnp.int32),
                      kv_len.astype(jnp.int32))

        def q_map(b, kb, bt, kl):
            return (b, 0, 0)

        def kv_map(b, kb, bt, kl):
            return (bt[b, kb], 0, 0, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=nsp,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((None, H, D), q_map),
            pl.BlockSpec((None, page, Kh, D), kv_map),
            pl.BlockSpec((None, page, Kh, D), kv_map),
        ],
        out_specs=pl.BlockSpec((None, H, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
        name="paged_decode_attention",
    )(*scalar_ops, q, k_pages, v_pages)


# ---------------------------------------------------------------------------
# Fused sampling: LM head matmul + greedy/top-k + logsumexp in one pass
# ---------------------------------------------------------------------------

def _fused_sample_kernel(x_ref, w_ref, vals_ref, idx_ref, lse_ref,
                         m_ref, l_ref, tv_ref, ti_ref, *,
                         block_v: int, top_k: int, vocab: int,
                         softcap: float):
    """One (1, Dm) hidden row x one (Dm, block_v) head slice per program.
    Running logsumexp (m/l scratch) and running top-k (tv/ti scratch)
    accumulate across the sequential vocab grid axis; the merge keeps the
    running entries FIRST in the concat so ``lax.top_k``'s stable
    tie-break (lowest index wins) reproduces ``argmax``'s
    first-occurrence rule for the greedy token."""
    vblk = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vblk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        tv_ref[...] = jnp.full_like(tv_ref, NEG_INF)
        ti_ref[...] = jnp.zeros_like(ti_ref)

    x = x_ref[...].astype(jnp.float32)                    # (1, Dm)
    w = w_ref[...].astype(jnp.float32)                    # (Dm, bv)
    s = jnp.dot(x, w, preferred_element_type=jnp.float32)  # (1, bv)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    col = vblk * block_v + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_v), 1)
    s = jnp.where(col < vocab, s, NEG_INF)                # head padding
    m_prev = m_ref[...]                                   # (1, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(col < vocab, p, 0.0)
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + jnp.sum(
        p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    cat_v = jnp.concatenate([tv_ref[...], s], axis=1)     # (1, K + bv)
    cat_i = jnp.concatenate([ti_ref[...], col], axis=1)
    top_v, sel = jax.lax.top_k(cat_v, top_k)
    tv_ref[...] = top_v
    ti_ref[...] = jnp.take_along_axis(cat_i, sel, axis=1)

    @pl.when(vblk == nv - 1)
    def _finalize():
        vals_ref[...] = tv_ref[0]
        idx_ref[...] = ti_ref[0]
        lse_ref[...] = (m_ref[...] + jnp.log(
            jnp.maximum(l_ref[...], 1e-30)))[0]


def fused_sample(x: jnp.ndarray, w: jnp.ndarray, *, top_k: int = 1,
                 block_v: int = 128, softcap: float = 0.0,
                 interpret: bool = True):
    """Fused LM-head + sampling epilogue for the paged decode step.

    x: (B, Dm) final-normed hidden states; w: (Dm, V) head weights.
    Returns (vals (B, top_k) f32, idx (B, top_k) i32, lse (B, 1) f32):
    the top-k logits (softcapped), their vocab indices, and the
    logsumexp over the full vocab — everything greedy/top-k sampling
    needs (greedy token = idx[:, 0], its logprob = vals[:, 0] - lse[:, 0])
    without ever materialising the (B, V) logits round-trip.
    """
    B, Dm = x.shape
    V = w.shape[1]
    assert w.shape[0] == Dm, (x.shape, w.shape)
    nv = -(-V // block_v)
    if V % block_v:
        w = jnp.pad(w, ((0, 0), (0, nv * block_v - V)))
    kernel = functools.partial(_fused_sample_kernel, block_v=block_v,
                               top_k=top_k, vocab=V, softcap=softcap)
    vals, idx, lse = pl.pallas_call(
        kernel,
        grid=(B, nv),
        in_specs=[
            pl.BlockSpec((None, Dm), lambda b, vb: (b, 0)),
            pl.BlockSpec((Dm, block_v), lambda b, vb: (0, vb)),
        ],
        out_specs=[
            pl.BlockSpec((None, top_k), lambda b, vb: (b, 0)),
            pl.BlockSpec((None, top_k), lambda b, vb: (b, 0)),
            pl.BlockSpec((None, 1), lambda b, vb: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, top_k), jnp.float32),
            jax.ShapeDtypeStruct((B, top_k), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, top_k), jnp.float32),
            pltpu.VMEM((1, top_k), jnp.int32),
        ],
        interpret=interpret,
        name="fused_sample",
    )(x, w)
    return vals, idx, lse
