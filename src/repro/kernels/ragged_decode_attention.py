"""Ragged GQA decode-attention Pallas TPU kernels — the rollout hot layer.

One new token per slot attends over a per-slot-length KV cache.  This is
the kernel the paper's scheduling feeds: length-sorted batches mean
neighbouring slots share similar ``kv_len``, so the kv-block skip pattern
(``@pl.when`` on block start < kv_len) is uniform across the grid and the
engine streams only live cache — the TPU-native payoff of SortedRL's
sorting (see DESIGN.md §3).

Two variants share one kernel body:

* ``ragged_decode_attention`` — dense ``(B, S, Kh, D)`` cache, kv blocks
  addressed contiguously (grid position == block index);
* ``paged_decode_attention`` — the cache is a pool of fixed-size pages
  ``(N, page, Kh, D)`` and each slot owns a *block table* mapping logical
  kv blocks to physical pages (``repro.core.kv_cache``).  The table is a
  scalar-prefetch operand, so the BlockSpec index_map dereferences it to
  DMA exactly the pages a slot maps — shared GRPO prefix pages stream
  once per slot without ever materialising a dense per-slot cache.

Tiling: grid (B, S // block_k); each program holds the full (H, D) query
tile in VMEM plus one (block_k, Kh, D) cache tile; flash-decode online
softmax accumulates in VMEM scratch across the sequential k dimension.
MXU alignment: block_k multiples of 128; D is the lane dimension.  For
the paged variant block_k == page size; production pools use 128-row
pages (multiple-of-128 constraint), tests exercise smaller interpreted
shapes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_decode_block(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                        *, kblk, nk, kstart, kv_len, softcap: float):
    """Shared flash-decode body: one (block_k, Kh, D) kv tile starting at
    logical position `kstart`, online-softmax accumulated in VMEM scratch.
    Refs: q (H, D) | k/v (block_k, Kh, D) | o (H, D) |
    scratch m/l (H, 1) f32, acc (H, D) f32."""

    @pl.when(kblk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(kstart < kv_len)           # ragged block skip
    def _compute():
        q = q_ref[...].astype(jnp.float32)            # (H, D)
        k = k_ref[...].astype(jnp.float32)            # (bk, Kh, D)
        v = v_ref[...].astype(jnp.float32)
        H, D = q.shape
        bk, Kh, _ = k.shape
        G = H // Kh
        qg = q.reshape(Kh, G, D) / math.sqrt(D)
        s = jnp.einsum("hgd,khd->hgk", qg, k,
                       preferred_element_type=jnp.float32)   # (Kh, G, bk)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        pos = kstart + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bk), 2)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...].reshape(Kh, G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(pos < kv_len, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                      # (Kh, G, 1)
        l_new = l_ref[...].reshape(Kh, G, 1) * alpha + jnp.sum(
            p, axis=-1, keepdims=True)
        pv = jnp.einsum("hgk,khd->hgd", p, v,
                        preferred_element_type=jnp.float32)
        acc_ref[...] = (acc_ref[...].reshape(Kh, G, D) * alpha
                        + pv).reshape(H, D)
        m_ref[...] = m_new.reshape(H, 1)
        l_ref[...] = l_new.reshape(H, 1)

    @pl.when(kblk == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


def _kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, block_k: int, softcap: float):
    """Dense variant: kv block `kb` sits at cache rows [kb*block_k, ...)."""
    kblk = pl.program_id(1)
    _flash_decode_block(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                        kblk=kblk, nk=pl.num_programs(1),
                        kstart=kblk * block_k, kv_len=kv_len_ref[0],
                        softcap=softcap)


def _paged_kernel(bt_ref, kv_len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page_size: int, softcap: float):
    """Paged variant: the BlockSpec index_map already dereferenced the
    block table (scalar prefetch), so k/v refs hold the physical page for
    logical block `kb`; only the position base differs from dense."""
    del bt_ref   # consumed by the index_map
    b = pl.program_id(0)
    kblk = pl.program_id(1)
    _flash_decode_block(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                        kblk=kblk, nk=pl.num_programs(1),
                        kstart=kblk * page_size, kv_len=kv_len_ref[b],
                        softcap=softcap)


def ragged_decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                            v_cache: jnp.ndarray, kv_len: jnp.ndarray,
                            *, block_k: int = 128, softcap: float = 0.0,
                            interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, D); k/v_cache: (B, S, Kh, D); kv_len: (B,) -> (B, H, D).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on TPU pass interpret=False for the compiled kernel.
    """
    B, H, D = q.shape
    S, Kh = k_cache.shape[1], k_cache.shape[2]
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k
    grid = (B, nk)
    kernel = functools.partial(_kernel, block_k=block_k, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, kb: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, H, D), lambda b, kb: (b, 0, 0)),
            pl.BlockSpec((None, block_k, Kh, D), lambda b, kb: (b, kb, 0, 0)),
            pl.BlockSpec((None, block_k, Kh, D), lambda b, kb: (b, kb, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, H, D), lambda b, kb: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
        interpret=interpret,
        name="ragged_decode_attention",
    )(kv_len, q, k_cache, v_cache)


def paged_decode_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                           kv_len: jnp.ndarray, *, softcap: float = 0.0,
                           interpret: bool = True) -> jnp.ndarray:
    """Decode attention over a paged KV pool.

    q: (B, H, D); k/v_pages: (N, page, Kh, D) physical page pool;
    block_tables: (B, nb) i32 — logical kv block j of slot b lives in
    physical page ``block_tables[b, j]`` (pad unused entries with any
    valid page id; rows past ``kv_len`` are masked); kv_len: (B,) valid
    lengths.  Returns (B, H, D).

    The block table and kv_len ride in as scalar-prefetch operands so the
    k/v index_maps can dereference the table — each grid step DMAs one
    physical page, which is how a GRPO group's shared prefix pages are
    read by every member without a dense per-slot copy.
    """
    B, H, D = q.shape
    page, Kh = k_pages.shape[1], k_pages.shape[2]
    nb = block_tables.shape[1]
    assert block_tables.shape[0] == B and kv_len.shape == (B,)
    kernel = functools.partial(_paged_kernel, page_size=page, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,       # block_tables, kv_len
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((None, H, D), lambda b, kb, bt, kl: (b, 0, 0)),
            pl.BlockSpec((None, page, Kh, D),
                         lambda b, kb, bt, kl: (bt[b, kb], 0, 0, 0)),
            pl.BlockSpec((None, page, Kh, D),
                         lambda b, kb, bt, kl: (bt[b, kb], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, H, D), lambda b, kb, bt, kl: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
        name="paged_decode_attention",
    )(block_tables.astype(jnp.int32), kv_len.astype(jnp.int32),
      q, k_pages, v_pages)
