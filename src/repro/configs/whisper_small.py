"""Whisper-small: enc-dec, 12+12L d_model=768 12H (MHA) d_ff=3072
vocab=51865; conv/mel frontend STUB (precomputed frame embeddings,
1500 encoder positions); learned decoder positions, LayerNorm, GELU.
[arXiv:2212.04356]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    attn=AttnConfig(),
    mlp_act="gelu", gated_mlp=False, norm_type="layernorm",
    pos_embedding="learned", max_position=33_024,
    encoder_layers=12, encoder_positions=1500,
    num_stub_positions=1500, stub_kind="audio_frames",
    source="arXiv:2212.04356",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, encoder_layers=2, d_model=128,
                          num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256,
                          vocab_size=503, max_position=256,
                          encoder_positions=32, num_stub_positions=32)
