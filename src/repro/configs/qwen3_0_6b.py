"""Qwen3-0.6B: 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936,
qk-norm, tied embeddings.  [hf:Qwen/Qwen3-8B family, 0.6B spec]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=3072, vocab_size=151936, head_dim=128,
    attn=AttnConfig(qk_norm=True, rope_theta=1_000_000.0),
    mlp_act="silu", gated_mlp=True, tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B (family card; 0.6B spec per assignment)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=2, head_dim=32, d_ff=256,
                          vocab_size=503)
