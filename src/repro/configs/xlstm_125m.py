"""xLSTM-125M: 12 blocks (alternating mLSTM / sLSTM) d_model=768 4H,
vocab=50304, no positional embedding (recurrence encodes order).
[arXiv:2405.04517]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=192,
    attn=AttnConfig(),
    norm_type="layernorm", pos_embedding="none",
    supports_long_decode=True,
    source="arXiv:2405.04517",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=128, num_heads=4,
                          head_dim=32, vocab_size=503)
