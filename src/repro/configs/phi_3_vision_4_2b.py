"""Phi-3-Vision-4.2B: phi3-mini backbone 32L d_model=3072 32H (MHA kv=32)
d_ff=8192 vocab=32064 + CLIP vision frontend (STUB per the carve-out:
``input_specs`` feeds 576 precomputed patch embeddings).
[hf:microsoft/Phi-3-vision-128k-instruct]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    attn=AttnConfig(rope_theta=10_000.0),
    mlp_act="silu", gated_mlp=True,
    num_stub_positions=576, stub_kind="vision_patches",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=4, head_dim=32, d_ff=256,
                          vocab_size=503, num_stub_positions=16)
