"""Qwen1.5-110B: 80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064,
QKV bias.  [hf:Qwen/Qwen1.5-0.5B family card; 110B spec per assignment]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=49152, vocab_size=152064, head_dim=128,
    attn=AttnConfig(qkv_bias=True, rope_theta=1_000_000.0),
    mlp_act="silu", gated_mlp=True,
    source="hf:Qwen/Qwen1.5-0.5B (family card)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=2, head_dim=32, d_ff=256,
                          vocab_size=503)
