"""Gemma2-2B: 26L d_model=2304 8H (GQA kv=4) head_dim=256 d_ff=9216
vocab=256000; alternating local(4096)/global attention, attn+final logit
soft-capping, tied + scaled embeddings.  [arXiv:2408.00118]

Runs long_500k: local layers use a true 4096-wide ring cache; global
layers use a context-parallel sharded cache (distributed flash-decode)."""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
    d_ff=9216, vocab_size=256000, head_dim=256,
    attn=AttnConfig(attn_softcap=50.0, sliding_window=4096,
                    layer_pattern="local_global", rope_theta=10_000.0),
    mlp_act="gelu", gated_mlp=True, tie_embeddings=True,
    scale_embeddings=True, logit_softcap=30.0,
    supports_long_decode=True,
    source="arXiv:2408.00118",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=503,
        attn=AttnConfig(attn_softcap=50.0, sliding_window=16,
                        layer_pattern="local_global"))
