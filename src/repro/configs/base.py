"""Configuration system: model configs, input-shape configs, registry.

Every assigned architecture gets a ``repro/configs/<id>.py`` that builds a
:class:`ModelConfig` with the exact public-literature spec (cited in the
module docstring).  ``registry()`` maps arch-id -> config; the launcher and
tests select via ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2
    router_z_weight: float = 1e-3
    # Shared (dense) expert path, used by some MoE families; 0 disables.
    d_ff_shared: int = 0


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64          # N: SSM state size per head
    head_dim: int = 64           # P: channels per SSM head
    num_heads: int = 0           # derived from d_inner / head_dim if 0
    expand: int = 2              # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256        # SSD chunk length (TPU-friendly)
    ngroups: int = 1             # B/C groups (like GQA for SSM)


@dataclass(frozen=True)
class AttnConfig:
    qk_norm: bool = False        # qwen3: RMSNorm on per-head q/k
    qkv_bias: bool = False       # qwen1.5
    attn_softcap: float = 0.0    # gemma2 attention-logit soft capping
    sliding_window: int = 0      # window for "local" layers (gemma2)
    # layer pattern: 'global' | 'local_global' (alternating, local first)
    layer_pattern: str = "global"
    rope_theta: float = 10_000.0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    attn: AttnConfig = field(default_factory=AttnConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mlp_act: str = "silu"        # silu (gated) | relu2 (squared relu) | gelu
    gated_mlp: bool = True
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    norm_eps: float = 1e-6
    pos_embedding: str = "rope"  # rope | learned | sinusoidal | none
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma2: embeds *= sqrt(d_model)
    logit_softcap: float = 0.0   # gemma2 final-logit soft capping
    max_position: int = 1 << 20
    # hybrid (zamba2): apply a shared attention block every k SSM layers
    attn_every: int = 0
    # vlm / audio frontends are stubs: the model consumes precomputed
    # embeddings of this many positions (0 = no frontend)
    num_stub_positions: int = 0
    stub_kind: str = "none"      # none | vision_patches | audio_frames
    # enc-dec (whisper): encoder layer count (decoder uses num_layers)
    encoder_layers: int = 0
    encoder_positions: int = 0
    # activation checkpointing: recompute layer internals in backward
    remat: bool = False
    # numerics
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    # sub-quadratic decode support (drives long_500k applicability)
    supports_long_decode: bool = False
    source: str = ""             # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic total parameter count (for 6ND roofline terms)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        return _param_count(self, active_only=True)


def _dense_block_params(cfg: ModelConfig, d_ff: int) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    attn = d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads)  # qkv
    attn += cfg.num_heads * hd * d                          # out proj
    if cfg.attn.qkv_bias:
        attn += hd * (cfg.num_heads + 2 * cfg.num_kv_heads)
    mlp = d * d_ff * (3 if cfg.gated_mlp else 2)
    norms = 2 * d
    return attn + mlp + norms


def _ssm_block_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    nheads = s.num_heads or d_inner // s.head_dim
    in_proj = d * (2 * d_inner + 2 * s.ngroups * s.state_dim + nheads)
    conv = (d_inner + 2 * s.ngroups * s.state_dim) * s.conv_width
    out = d_inner * d
    extras = 2 * nheads + d_inner + d  # A_log, dt_bias, norm, layer norm
    return in_proj + conv + out + extras


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    n = cfg.vocab_size * cfg.d_model  # embedding
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * cfg.d_model
    n += cfg.d_model  # final norm
    if cfg.family in ("dense", "vlm"):
        n += cfg.num_layers * _dense_block_params(cfg, cfg.d_ff)
    elif cfg.family == "moe":
        m = cfg.moe
        per = _dense_block_params(cfg, 0)  # attn + norms only
        router = cfg.d_model * m.num_experts
        e = m.experts_per_token if active_only else m.num_experts
        expert = e * cfg.d_model * m.d_ff_expert * 3
        shared = cfg.d_model * m.d_ff_shared * 3 if m.d_ff_shared else 0
        n += cfg.num_layers * (per + router + expert + shared)
    elif cfg.family == "hybrid":
        n += cfg.num_layers * _ssm_block_params(cfg)
        n_attn = max(1, cfg.num_layers // max(cfg.attn_every, 1))
        n += n_attn and _dense_block_params(cfg, cfg.d_ff)  # shared block
    elif cfg.family == "ssm":
        # xlstm: alternating sLSTM / mLSTM; rough analytic count
        d = cfg.d_model
        n += cfg.num_layers * (8 * d * d)
    elif cfg.family == "audio":
        n += cfg.num_layers * (_dense_block_params(cfg, cfg.d_ff)
                               + cfg.d_model * cfg.resolved_head_dim
                               * (cfg.num_heads + 2 * cfg.num_kv_heads)
                               + cfg.num_heads * cfg.resolved_head_dim * cfg.d_model
                               + cfg.d_model)  # + cross-attn
        n += cfg.encoder_layers * _dense_block_params(cfg, cfg.d_ff)
    return int(n)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


ARCH_IDS: Tuple[str, ...] = (
    "qwen3_moe_235b_a22b",
    "qwen3_0_6b",
    "nemotron_4_340b",
    "qwen1_5_110b",
    "zamba2_1_2b",
    "xlstm_125m",
    "gemma2_2b",
    "granite_moe_3b_a800m",
    "phi_3_vision_4_2b",
    "whisper_small",
)

# public --arch ids (dashes/dots) -> module names
ARCH_ALIASES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen3-0.6b": "qwen3_0_6b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen1.5-110b": "qwen1_5_110b",
    "zamba2-1.2b": "zamba2_1_2b",
    "xlstm-125m": "xlstm_125m",
    "gemma2-2b": "gemma2_2b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "whisper-small": "whisper_small",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ARCH_ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced variant of the same family: <=2 layers, d_model<=512, <=4 experts."""
    mod_name = ARCH_ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
