"""Nemotron-4-340B: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU (non-gated) MLP.  [arXiv:2402.16819]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
    d_ff=73728, vocab_size=256000, head_dim=192,
    attn=AttnConfig(rope_theta=10_000.0),
    mlp_act="relu2", gated_mlp=False,
    source="arXiv:2402.16819",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=192, num_heads=6,
                          num_kv_heads=2, head_dim=32, d_ff=512,
                          vocab_size=503)
