"""Qwen3-MoE-235B-A22B: 94L d_model=4096 64H (GQA kv=4) d_ff_expert=1536,
vocab=151936, MoE 128 experts top-8, qk-norm.  [hf:Qwen/Qwen3-30B-A3B
scaled per assignment spec]"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=0, vocab_size=151936, head_dim=128,
    attn=AttnConfig(qk_norm=True, rope_theta=1_000_000.0),
    moe=MoEConfig(num_experts=128, experts_per_token=8, d_ff_expert=1536),
    mlp_act="silu", gated_mlp=True,
    source="hf:Qwen/Qwen3-30B-A3B (assignment spec)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
        vocab_size=503,
        moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=64,
                      capacity_factor=2.0))
