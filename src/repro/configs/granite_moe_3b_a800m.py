"""Granite-MoE-3B-A800M: 32L d_model=1536 24H (GQA kv=8) d_ff_expert=512
vocab=49155, MoE 40 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]

NOTE: the assignment's structured spec field says "MoE 40e top-8" while its
free text says "32 experts top-8"; we follow the structured field (40)."""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=0, vocab_size=49155, head_dim=64,
    attn=AttnConfig(rope_theta=10_000.0),
    moe=MoEConfig(num_experts=40, experts_per_token=8, d_ff_expert=512),
    mlp_act="silu", gated_mlp=True, tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
        vocab_size=503,
        moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=64,
                      capacity_factor=2.0))
