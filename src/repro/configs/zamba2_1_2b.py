"""Zamba2-1.2B: 38 Mamba2 layers d_model=2048, shared attention block
(32H MHA, d_ff=8192) applied every 6 SSM layers, vocab=32000,
ssm_state=64.  [arXiv:2411.15242]"""
from repro.configs.base import AttnConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    attn=AttnConfig(rope_theta=10_000.0),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, ngroups=1),
    attn_every=6, mlp_act="silu", gated_mlp=True,
    supports_long_decode=True,
    source="arXiv:2411.15242",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=503, attn_every=2,
        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, conv_width=4,
                      chunk_size=16, ngroups=1))
