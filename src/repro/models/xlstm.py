"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise-parallel
like linear attention with exponential gating) and sLSTM (scalar memory,
true recurrence with state mixing, lax.scan over time).

mLSTM state: (C (B,H,Dk,Dv), n (B,H,Dk)); sLSTM state: (c, n, h, m) each
(B, H, Dh).  Decode is O(1) per token for both — xLSTM archs therefore
support the 500k long-context decode shape natively.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, jnp.ndarray]

GATE_CLIP = 8.0   # clip pre-activation of exp input gate for f32 stability


# ---------------------------------------------------------------------------
# mLSTM core
# ---------------------------------------------------------------------------

def mlstm_chunked(q, k, v, i_pre, f_pre, chunk: int,
                  init_state: Optional[Tuple] = None):
    """q,k,v: (B,T,H,D); i_pre,f_pre: (B,T,H) gate pre-activations.
    Returns (h (B,T,H,D), (C, n) final state)."""
    B, T, H, D = q.shape
    f32 = jnp.float32
    assert T % chunk == 0
    nc = T // chunk
    qf = q.astype(f32) / math.sqrt(D)
    kf, vf = k.astype(f32), v.astype(f32)
    log_f = jax.nn.log_sigmoid(f_pre.astype(f32))            # <= 0
    log_i = jnp.clip(i_pre.astype(f32), -GATE_CLIP, GATE_CLIP)

    qc = qf.reshape(B, nc, chunk, H, D)
    kc = kf.reshape(B, nc, chunk, H, D)
    vc = vf.reshape(B, nc, chunk, H, D)
    lfc = log_f.reshape(B, nc, chunk, H)
    lic = log_i.reshape(B, nc, chunk, H)

    if init_state is None:
        C0 = jnp.zeros((B, H, D, D), f32)
        n0 = jnp.zeros((B, H, D), f32)
    else:
        C0, n0 = (s.astype(f32) for s in init_state)

    def step(carry, inp):
        C, n = carry
        qk_, kk_, vk_, lf, li = inp                  # (B, chunk, ...)
        cs = jnp.cumsum(lf, axis=1)                  # (B, c, H)
        total = cs[:, -1]                            # (B, H)
        # intra-chunk: w[t,s] = exp(cs_t - cs_s + li_s), s <= t
        wlog = (cs[:, :, None] - cs[:, None, :]
                + li[:, None, :])                    # (B, t, s, H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(mask[None, :, :, None], jnp.exp(wlog), 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qk_, kk_) * w
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, vk_)
        den_intra = jnp.sum(scores, axis=2)          # (B, c, H)
        # inter-chunk
        dec = jnp.exp(cs)                            # (B, c, H)
        y_off = jnp.einsum("bthd,bhde->bthe", qk_, C) * dec[..., None]
        den_off = jnp.einsum("bthd,bhd->bth", qk_, n) * dec
        den = jnp.maximum(jnp.abs(den_intra + den_off), 1.0)
        h = (y_intra + y_off) / den[..., None]
        # state update
        din = jnp.exp(total[:, None] + li - cs)      # (B, c, H)
        C = C * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bshd,bsh,bshe->bhde", kk_, din, vk_)
        n = n * jnp.exp(total)[..., None] + jnp.einsum(
            "bshd,bsh->bhd", kk_, din)
        return (C, n), h

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qc, kc, vc, lfc, lic))
    (C, n), hs = jax.lax.scan(step, (C0, n0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, H, D)
    return h.astype(q.dtype), (C, n)


def mlstm_ref(q, k, v, i_pre, f_pre, init_state=None):
    """Sequential oracle."""
    B, T, H, D = q.shape
    f32 = jnp.float32
    qf = q.astype(f32) / math.sqrt(D)
    log_f = jax.nn.log_sigmoid(f_pre.astype(f32))
    log_i = jnp.clip(i_pre.astype(f32), -GATE_CLIP, GATE_CLIP)
    if init_state is None:
        C = jnp.zeros((B, H, D, D), f32)
        n = jnp.zeros((B, H, D), f32)
    else:
        C, n = (s.astype(f32) for s in init_state)
    hs = []
    for t in range(T):
        f = jnp.exp(log_f[:, t])[..., None]
        i = jnp.exp(log_i[:, t])[..., None]
        C = C * f[..., None] + i[..., None] * jnp.einsum(
            "bhd,bhe->bhde", k[:, t].astype(f32), v[:, t].astype(f32))
        n = n * f + i * k[:, t].astype(f32)
        num = jnp.einsum("bhd,bhde->bhe", qf[:, t], C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf[:, t], n)), 1.0)
        hs.append(num / den[..., None])
    return jnp.stack(hs, 1).astype(q.dtype), (C, n)


def mlstm_decode(q1, k1, v1, i1, f1, state):
    """One token: q1..v1 (B,H,D); i1,f1 (B,H)."""
    C, n = state
    f32 = jnp.float32
    D = q1.shape[-1]
    f = jnp.exp(jax.nn.log_sigmoid(f1.astype(f32)))[..., None]
    i = jnp.exp(jnp.clip(i1.astype(f32), -GATE_CLIP, GATE_CLIP))[..., None]
    C = C * f[..., None] + i[..., None] * jnp.einsum(
        "bhd,bhe->bhde", k1.astype(f32), v1.astype(f32))
    n = n * f + i * k1.astype(f32)
    qf = q1.astype(f32) / math.sqrt(D)
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), 1.0)
    return (num / den[..., None]).astype(q1.dtype), (C, n)


# ---------------------------------------------------------------------------
# sLSTM core (inherently sequential)
# ---------------------------------------------------------------------------

def slstm_scan(x_gates, R, state):
    """x_gates: (B, T, 4, H, Dh) input contributions for (i, f, z, o);
    R: (4, H, Dh, Dh) recurrent mixing; state: (c, n, h, m) each (B,H,Dh).
    Returns (h_seq (B,T,H,Dh), new state)."""
    f32 = jnp.float32

    def step(carry, xg):
        c, n, h, m = carry
        rec = jnp.einsum("bhd,ghde->bghe", h, R.astype(f32))  # (B,4,H,Dh)
        g = xg.astype(f32) + rec
        it, ft, zt, ot = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(jnp.minimum(it - m_new, 0.0))
        f_p = jnp.exp(jnp.minimum(ft + m - m_new, 0.0))
        c = f_p * c + i_p * jnp.tanh(zt)
        n = f_p * n + i_p
        h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    (c, n, h, m), hs = jax.lax.scan(step, state,
                                    jnp.moveaxis(x_gates, 1, 0))
    return jnp.moveaxis(hs, 0, 1), (c, n, h, m)


def slstm_init_state(B, H, Dh):
    z = jnp.zeros((B, H, Dh), jnp.float32)
    return (z, z, z, jnp.full((B, H, Dh), -1e9, jnp.float32))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def init_mlstm_block(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    H = cfg.num_heads
    d_inner = 2 * d
    Dh = d_inner // H
    ks = jax.random.split(key, 8)
    sd = 1.0 / math.sqrt(d)
    sdi = 1.0 / math.sqrt(d_inner)
    return {
        "ln": L.init_norm(ks[0], d, "layernorm", dtype),
        "up": (jax.random.normal(ks[1], (d, 2 * d_inner)) * sd).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (4, d_inner)) * 0.5).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "wq": (jax.random.normal(ks[3], (d_inner, H, Dh)) * sdi).astype(dtype),
        "wk": (jax.random.normal(ks[4], (d_inner, H, Dh)) * sdi).astype(dtype),
        "wv": (jax.random.normal(ks[5], (d_inner, H, Dh)) * sdi).astype(dtype),
        "w_if": (jax.random.normal(ks[6], (d_inner, 2, H)) * sdi).astype(dtype),
        "b_if": jnp.concatenate([jnp.zeros((1, H)),
                                 jnp.ones((1, H)) * 3.0]).astype(jnp.float32),
        "out_norm": jnp.ones((H, Dh), dtype),
        "down": (jax.random.normal(ks[7], (d_inner, d))
                 * (1.0 / math.sqrt(d_inner * 2 * cfg.num_layers))).astype(dtype),
    }


def mlstm_block(p: Params, cfg: ModelConfig, x, state=None, conv_state=None,
                return_state: bool = False):
    """x: (B, T, d).  state: (C, n); conv_state: (B, 3, d_inner)."""
    B, T, d = x.shape
    H = cfg.num_heads
    d_inner = 2 * d
    Dh = d_inner // H
    h = L.layernorm(x, p["ln"]["scale"], p["ln"]["bias"])
    up = jnp.einsum("btd,de->bte", h, p["up"])
    xi, z = jnp.split(up, 2, axis=-1)
    from repro.models.ssm import _causal_conv
    xc = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    q = jnp.einsum("bte,ehd->bthd", xc, p["wq"])
    k = jnp.einsum("bte,ehd->bthd", xc, p["wk"])
    v = jnp.einsum("bte,ehd->bthd", xi, p["wv"])
    gif = jnp.einsum("bte,egh->btgh", xc, p["w_if"]).astype(jnp.float32) \
        + p["b_if"]
    i_pre, f_pre = gif[:, :, 0], gif[:, :, 1]
    chunk = min(128, T)
    if T % chunk:
        chunk = T
    hseq, new_state = mlstm_chunked(q, k, v, i_pre, f_pre, chunk, state)
    hseq = L.rmsnorm(hseq, p["out_norm"])           # per-head norm
    hflat = hseq.reshape(B, T, d_inner)
    out = jnp.einsum("bte,ed->btd", hflat * jax.nn.silu(z), p["down"])
    if return_state:
        K = p["conv_w"].shape[0]
        if T >= K - 1:
            cs = xi[:, T - (K - 1):]
        else:
            prev = conv_state if conv_state is not None else jnp.zeros(
                (B, K - 1, d_inner), xi.dtype)
            cs = jnp.concatenate([prev, xi], axis=1)[:, -(K - 1):]
        return x + out, (new_state, cs)
    return x + out


def mlstm_block_decode(p: Params, cfg: ModelConfig, x1, state, conv_state):
    """x1: (B, d)."""
    B, d = x1.shape
    H = cfg.num_heads
    d_inner = 2 * d
    h = L.layernorm(x1, p["ln"]["scale"], p["ln"]["bias"])
    up = jnp.einsum("bd,de->be", h, p["up"])
    xi, z = jnp.split(up, 2, axis=-1)
    win = jnp.concatenate([conv_state, xi[:, None]], axis=1)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", win.astype(jnp.float32),
                                p["conv_w"].astype(jnp.float32))
                     + p["conv_b"].astype(jnp.float32)).astype(x1.dtype)
    q = jnp.einsum("be,ehd->bhd", xc, p["wq"])
    k = jnp.einsum("be,ehd->bhd", xc, p["wk"])
    v = jnp.einsum("be,ehd->bhd", xi, p["wv"])
    gif = jnp.einsum("be,egh->bgh", xc, p["w_if"]).astype(jnp.float32) \
        + p["b_if"]
    h1, new_state = mlstm_decode(q, k, v, gif[:, 0], gif[:, 1], state)
    h1 = L.rmsnorm(h1, p["out_norm"])
    out = jnp.einsum("be,ed->bd", h1.reshape(B, d_inner)
                     * jax.nn.silu(z), p["down"])
    return x1 + out, new_state, win[:, 1:]


def init_slstm_block(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    H = cfg.num_heads
    Dh = d // H
    ks = jax.random.split(key, 6)
    sd = 1.0 / math.sqrt(d)
    d_ff = int(math.ceil(4 / 3 * d))
    return {
        "ln": L.init_norm(ks[0], d, "layernorm", dtype),
        "w_gates": (jax.random.normal(ks[1], (d, 4, H, Dh)) * sd).astype(dtype),
        "b_gates": jnp.zeros((4, H, Dh), jnp.float32)
        .at[1].set(3.0),  # forget-gate bias init
        "R": (jax.random.normal(ks[2], (4, H, Dh, Dh))
              * (1.0 / math.sqrt(Dh))).astype(jnp.float32),
        "out_norm": jnp.ones((H, Dh), dtype),
        "proj": (jax.random.normal(ks[3], (d, d)) * sd).astype(dtype),
        "ffn": L.init_mlp(ks[4], d, d_ff, True, cfg.num_layers, dtype),
        "ln2": L.init_norm(ks[5], d, "layernorm", dtype),
    }


def slstm_block(p: Params, cfg: ModelConfig, x, state=None,
                return_state: bool = False, valid=None):
    B, T, d = x.shape
    H = cfg.num_heads
    Dh = d // H
    h = L.layernorm(x, p["ln"]["scale"], p["ln"]["bias"])
    xg = jnp.einsum("btd,dghe->btghe", h, p["w_gates"]).astype(jnp.float32) \
        + p["b_gates"]
    if valid is not None:
        # mask the input gate at padded positions so n doesn't accumulate
        xg = xg.at[:, :, 0].add(
            jnp.where(valid[:, :, None, None], 0.0, -1e9))
    if state is None:
        state = slstm_init_state(B, H, Dh)
    hseq, new_state = slstm_scan(xg, p["R"], state)
    hseq = L.rmsnorm(hseq.astype(x.dtype), p["out_norm"])
    out = jnp.einsum("btd,de->bte", hseq.reshape(B, T, d), p["proj"])
    x = x + out
    h2 = L.layernorm(x, p["ln2"]["scale"], p["ln2"]["bias"])
    x = x + L.mlp(p["ffn"], h2, "gelu", True)
    if return_state:
        return x, new_state
    return x


def slstm_block_decode(p: Params, cfg: ModelConfig, x1, state):
    x, new_state = slstm_block(p, cfg, x1[:, None], state, return_state=True)
    return x[:, 0], new_state


# ---------------------------------------------------------------------------
# Full xLSTM model: scan over (mLSTM, sLSTM) pairs
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    from repro.models import transformer as TF
    dtype = cfg.param_dtype
    n_pairs = cfg.num_layers // 2
    keys = jax.random.split(key, cfg.num_layers + 3)
    m_blocks = [init_mlstm_block(keys[2 * i], cfg, dtype)
                for i in range(n_pairs)]
    s_blocks = [init_slstm_block(keys[2 * i + 1], cfg, dtype)
                for i in range(n_pairs)]
    return {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model))
                  * (1.0 / math.sqrt(cfg.d_model))).astype(dtype),
        "mlstm": TF._stack(m_blocks),
        "slstm": TF._stack(s_blocks),
        "final_norm": L.init_norm(keys[-2], cfg.d_model, "layernorm", dtype),
        "lm_head": (jax.random.normal(keys[-3], (cfg.d_model, cfg.vocab_size))
                    * (1.0 / math.sqrt(cfg.d_model))).astype(dtype),
    }


def _lm_logits(params, cfg, x):
    from repro.models import transformer as TF
    return TF.lm_logits(params, cfg, x)


def forward(params: Params, cfg: ModelConfig, tokens, valid=None):
    from repro.models import transformer as TF
    x = TF.embed_tokens(params, cfg, tokens)
    if valid is not None:
        x = jnp.where(valid[..., None], x, 0)

    def body(h, bps):
        mp, sp = bps
        h = mlstm_block(mp, cfg, h)
        h = slstm_block(sp, cfg, h, valid=valid)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["mlstm"], params["slstm"]))
    return _lm_logits(params, cfg, x)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Recurrent state only — O(1) in sequence length (long_500k native)."""
    dtype = dtype or cfg.compute_dtype
    d = cfg.d_model
    H = cfg.num_heads
    n_pairs = cfg.num_layers // 2
    d_inner = 2 * d
    Dm = d_inner // H
    Ds = d // H
    f32 = jnp.float32
    return {
        "mlstm_C": jnp.zeros((n_pairs, batch, H, Dm, Dm), f32),
        "mlstm_n": jnp.zeros((n_pairs, batch, H, Dm), f32),
        "mlstm_conv": jnp.zeros((n_pairs, batch, 3, d_inner), dtype),
        "slstm_c": jnp.zeros((n_pairs, batch, H, Ds), f32),
        "slstm_n": jnp.zeros((n_pairs, batch, H, Ds), f32),
        "slstm_h": jnp.zeros((n_pairs, batch, H, Ds), f32),
        "slstm_m": jnp.full((n_pairs, batch, H, Ds), -1e9, f32),
    }


def prefill(params: Params, cfg: ModelConfig, tokens, cache, prompt_lens):
    """Left-padded prompts (see hybrid.prefill note)."""
    from repro.models import transformer as TF
    B, T = tokens.shape
    valid = (jnp.arange(T)[None] - (T - prompt_lens)[:, None]) >= 0
    x = TF.embed_tokens(params, cfg, tokens)
    x = jnp.where(valid[..., None], x, 0)

    def body(h, xs):
        mp, sp, mC, mn, mcv, sc, sn, sh, sm = xs
        h, ((C, n), conv) = mlstm_block(mp, cfg, h, return_state=True)
        h, (c2, n2, h2, m2) = slstm_block(sp, cfg, h, (sc, sn, sh, sm),
                                          return_state=True, valid=valid)
        return h, (C, n, conv, c2, n2, h2, m2)

    xs = (params["mlstm"], params["slstm"], cache["mlstm_C"],
          cache["mlstm_n"], cache["mlstm_conv"], cache["slstm_c"],
          cache["slstm_n"], cache["slstm_h"], cache["slstm_m"])
    x, (C, n, conv, c2, n2, h2, m2) = jax.lax.scan(body, x, xs)
    cache = {"mlstm_C": C, "mlstm_n": n,
             "mlstm_conv": conv.astype(cache["mlstm_conv"].dtype),
             "slstm_c": c2, "slstm_n": n2, "slstm_h": h2, "slstm_m": m2}
    return _lm_logits(params, cfg, x), cache


def decode_step(params: Params, cfg: ModelConfig, token, cache, kv_len=None):
    from repro.models import transformer as TF
    x = TF.embed_tokens(params, cfg, token[:, None])[:, 0]

    def body(h, xs):
        mp, sp, mC, mn, mcv, sc, sn, sh, sm = xs
        h, (C, n), conv = mlstm_block_decode(mp, cfg, h, (mC, mn), mcv)
        h, (c2, n2, h2, m2) = slstm_block_decode(sp, cfg, h,
                                                 (sc, sn, sh, sm))
        return h, (C, n, conv, c2, n2, h2, m2)

    xs = (params["mlstm"], params["slstm"], cache["mlstm_C"],
          cache["mlstm_n"], cache["mlstm_conv"], cache["slstm_c"],
          cache["slstm_n"], cache["slstm_h"], cache["slstm_m"])
    x, (C, n, conv, c2, n2, h2, m2) = jax.lax.scan(body, x, xs)
    cache = {"mlstm_C": C, "mlstm_n": n,
             "mlstm_conv": conv.astype(cache["mlstm_conv"].dtype),
             "slstm_c": c2, "slstm_n": n2, "slstm_h": h2, "slstm_m": m2}
    return _lm_logits(params, cfg, x), cache
