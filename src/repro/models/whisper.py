"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

Per the assignment carve-out, the mel-spectrogram + conv feature frontend is
a STUB: ``input_specs`` feeds precomputed frame embeddings (B, T_enc, d)
directly into the encoder.  The transformer itself (bidirectional encoder,
causal decoder with cross-attention, learned decoder positions, LayerNorm,
GELU MLPs) is implemented fully.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as TF

Params = Dict[str, Any]


def _init_xattn(key, cfg, dtype):
    return L.init_attention(key, cfg, dtype)


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = cfg.param_dtype
    n_enc, n_dec = cfg.encoder_layers, cfg.num_layers
    keys = jax.random.split(key, n_enc + n_dec + 6)
    enc_blocks = [TF.init_block(keys[i], cfg, dtype) for i in range(n_enc)]
    dec_blocks = []
    for i in range(n_dec):
        k1, k2, k3 = jax.random.split(keys[n_enc + i], 3)
        b = TF.init_block(k1, cfg, dtype)
        b["xattn"] = _init_xattn(k2, cfg, dtype)
        b["ln_x"] = L.init_norm(k3, cfg.d_model, cfg.norm_type, dtype)
        dec_blocks.append(b)
    return {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model))
                  * (1.0 / math.sqrt(cfg.d_model))).astype(dtype),
        "pos_embed": (jax.random.normal(keys[-2], (cfg.max_position,
                                                   cfg.d_model)) * 0.02
                      ).astype(dtype),
        "enc_layers": TF._stack(enc_blocks),
        "dec_layers": TF._stack(dec_blocks),
        "enc_norm": L.init_norm(keys[-3], cfg.d_model, cfg.norm_type, dtype),
        "final_norm": L.init_norm(keys[-4], cfg.d_model, cfg.norm_type, dtype),
        "lm_head": (jax.random.normal(keys[-5], (cfg.d_model, cfg.vocab_size))
                    * (1.0 / math.sqrt(cfg.d_model))).astype(dtype),
    }


def encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, T_enc, d) stub frame embeddings -> encoder states."""
    B, T, _ = frames.shape
    x = frames.astype(cfg.compute_dtype)
    x = x + L.sinusoidal_embedding(T, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def body(h, bp):
        hn = L.norm(h, bp["ln1"], cfg.norm_type, cfg.norm_eps)
        q, k, v = L.qkv_project(bp["attn"], cfg, hn, positions)
        o = L.full_attention(q, k, v, causal=False) if T <= TF.FULL_ATTN_MAX_SEQ \
            else L.blockwise_attention(q, k, v, causal=False)
        h = h + L.attn_output(bp["attn"], o)
        hn = L.norm(h, bp["ln2"], cfg.norm_type, cfg.norm_eps)
        h = h + L.mlp(bp["mlp"], hn, cfg.mlp_act, cfg.gated_mlp)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.norm(x, params["enc_norm"], cfg.norm_type, cfg.norm_eps)


def _dec_block(bp, cfg, h, positions, enc_kv, causal_full: bool):
    """enc_kv: (k_enc, v_enc) precomputed (B, T_enc, Kh, D)."""
    hn = L.norm(h, bp["ln1"], cfg.norm_type, cfg.norm_eps)
    q, k, v = L.qkv_project(bp["attn"], cfg, hn, positions)
    S = q.shape[1]
    o = L.full_attention(q, k, v, causal=True) if S <= TF.FULL_ATTN_MAX_SEQ \
        else L.blockwise_attention(q, k, v, causal=True)
    h = h + L.attn_output(bp["attn"], o)
    # cross attention
    hn = L.norm(h, bp["ln_x"], cfg.norm_type, cfg.norm_eps)
    qx = jnp.einsum("bsd,dhk->bshk", hn, bp["xattn"]["wq"])
    k_enc, v_enc = enc_kv
    ox = L.full_attention(qx, k_enc, v_enc, causal=False)
    h = h + L.attn_output(bp["xattn"], ox)
    hn = L.norm(h, bp["ln2"], cfg.norm_type, cfg.norm_eps)
    h = h + L.mlp(bp["mlp"], hn, cfg.mlp_act, cfg.gated_mlp)
    return h


def cross_kv(params: Params, cfg: ModelConfig, enc_states: jnp.ndarray):
    """Precompute per-decoder-layer cross-attention K/V from encoder states.
    Returns (k_x, v_x): (n_dec, B, T_enc, Kh, D)."""
    def body(_, bp):
        k = jnp.einsum("bsd,dhk->bshk", enc_states, bp["xattn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_states, bp["xattn"]["wv"])
        return None, (k, v)
    _, (k_x, v_x) = jax.lax.scan(body, None, params["dec_layers"])
    return k_x, v_x


def decoder_forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                    enc_states: jnp.ndarray) -> jnp.ndarray:
    """Teacher-forced decoder pass (training): tokens (B, S)."""
    B, S = tokens.shape
    x = TF.embed_tokens(params, cfg, tokens)
    x = x + params["pos_embed"][:S][None].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    k_x, v_x = cross_kv(params, cfg, enc_states)

    def body(h, xs):
        bp, kx, vx = xs
        return _dec_block(bp, cfg, h, positions, (kx, vx), True), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["dec_layers"], k_x, v_x))
    return TF.lm_logits(params, cfg, x)


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            frames: jnp.ndarray) -> jnp.ndarray:
    return decoder_forward(params, cfg, tokens, encode(params, cfg, frames))


# ---------------------------------------------------------------------------
# Decode: self-attn cache + precomputed cross K/V
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None
               ) -> Dict[str, jnp.ndarray]:
    dtype = dtype or cfg.compute_dtype
    Kh, D = cfg.num_kv_heads, cfg.resolved_head_dim
    n_dec, T_enc = cfg.num_layers, cfg.encoder_positions
    return {
        "k": jnp.zeros((n_dec, batch, max_len, Kh, D), dtype),
        "v": jnp.zeros((n_dec, batch, max_len, Kh, D), dtype),
        "k_x": jnp.zeros((n_dec, batch, T_enc, Kh, D), dtype),
        "v_x": jnp.zeros((n_dec, batch, T_enc, Kh, D), dtype),
    }


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            cache: Dict[str, jnp.ndarray], prompt_lens: jnp.ndarray,
            frames: Optional[jnp.ndarray] = None,
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Encodes frames (filling cross K/V) and prefises decoder prompts."""
    if frames is not None:
        enc_states = encode(params, cfg, frames)
        k_x, v_x = cross_kv(params, cfg, enc_states)
        cache = dict(cache, k_x=k_x.astype(cache["k_x"].dtype),
                     v_x=v_x.astype(cache["v_x"].dtype))
    B, S = tokens.shape
    x = TF.embed_tokens(params, cfg, tokens)
    x = x + params["pos_embed"][:S][None].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(h, xs):
        bp, kx, vx, kc, vc = xs
        hn = L.norm(h, bp["ln1"], cfg.norm_type, cfg.norm_eps)
        q, k, v = L.qkv_project(bp["attn"], cfg, hn, positions)
        o = L.full_attention(q, k, v, causal=True) if S <= TF.FULL_ATTN_MAX_SEQ \
            else L.blockwise_attention(q, k, v, causal=True)
        h = h + L.attn_output(bp["attn"], o)
        hn = L.norm(h, bp["ln_x"], cfg.norm_type, cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", hn, bp["xattn"]["wq"])
        ox = L.full_attention(qx, kx, vx, causal=False)
        h = h + L.attn_output(bp["xattn"], ox)
        hn = L.norm(h, bp["ln2"], cfg.norm_type, cfg.norm_eps)
        h = h + L.mlp(bp["mlp"], hn, cfg.mlp_act, cfg.gated_mlp)
        kc = kc.at[:, :S].set(k.astype(kc.dtype))
        vc = vc.at[:, :S].set(v.astype(vc.dtype))
        return h, (kc, vc)

    x, (kc, vc) = jax.lax.scan(body, x, (params["dec_layers"], cache["k_x"],
                                         cache["v_x"], cache["k"],
                                         cache["v"]))
    cache = dict(cache, k=kc, v=vc)
    return TF.lm_logits(params, cfg, x), cache


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                cache: Dict[str, jnp.ndarray], kv_len: jnp.ndarray,
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    x = TF.embed_tokens(params, cfg, token[:, None])
    x = x + params["pos_embed"][kv_len][:, None].astype(x.dtype)

    def body(h, xs):
        bp, kx, vx, kc, vc = xs
        hn = L.norm(h, bp["ln1"], cfg.norm_type, cfg.norm_eps)
        q, k, v = L.qkv_project(bp["attn"], cfg, hn, kv_len[:, None])
        kc = TF._write_token(kc[None], k[None, :, 0], kv_len)[0]
        vc = TF._write_token(vc[None], v[None, :, 0], kv_len)[0]
        o = L.decode_attention(q[:, 0], kc, vc, kv_len + 1)
        h = h + L.attn_output(bp["attn"], o[:, None])
        hn = L.norm(h, bp["ln_x"], cfg.norm_type, cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", hn, bp["xattn"]["wq"])
        T_enc = kx.shape[1]
        ox = L.decode_attention(qx[:, 0], kx, vx,
                                jnp.full_like(kv_len, T_enc))
        h = h + L.attn_output(bp["xattn"], ox[:, None])
        hn = L.norm(h, bp["ln2"], cfg.norm_type, cfg.norm_eps)
        h = h + L.mlp(bp["mlp"], hn, cfg.mlp_act, cfg.gated_mlp)
        return h, (k[:, 0], v[:, 0])

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k_x"], cache["v_x"],
                  cache["k"], cache["v"]))
    cache = dict(cache,
                 k=TF._write_token(cache["k"], k_new, kv_len),
                 v=TF._write_token(cache["v"], v_new, kv_len))
    return TF.lm_logits(params, cfg, x[:, 0]), cache
