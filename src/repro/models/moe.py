"""Mixture-of-Experts FFN (top-k routing, capacity-based token drop).

Two execution paths, both validated against a loop-over-experts oracle:

* ``moe_mlp_dense`` — GShard-style one-hot dispatch einsum.  Used for small
  token counts (decode steps, CPU smoke tests).  Memory O(T * E * C).
* ``moe_mlp_ep``   — expert-parallel path for training/prefill at scale:
  a ``shard_map`` region where tokens are split over (data, model), each
  device builds fixed-capacity per-expert buffers, and ``all_to_all`` over
  the ``model`` axis moves token buffers to the devices owning the experts
  (classic DeepSpeed-MoE/EP layout, TPU-native: the all-to-all is exactly
  the collective the roofline must see).

Router aux losses (load-balance + z-loss) are accumulated into a host of
side outputs threaded through as an explicit return.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]


def init_moe_mlp(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.num_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    sd_in = 1.0 / math.sqrt(d)
    sd_out = 1.0 / math.sqrt(f * 2 * cfg.num_layers)
    p = {
        "router": (jax.random.normal(k1, (d, E)) * sd_in).astype(jnp.float32),
        "w_in": (jax.random.normal(k2, (E, d, f)) * sd_in).astype(dtype),
        "w_gate": (jax.random.normal(k3, (E, d, f)) * sd_in).astype(dtype),
        "w_out": (jax.random.normal(k4, (E, f, d)) * sd_out).astype(dtype),
    }
    if m.d_ff_shared:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(k5, d, m.d_ff_shared, True, cfg.num_layers, dtype)
    return p


def _route(p: Params, cfg: ModelConfig, x2d: jnp.ndarray):
    """x2d: (T, d) -> (gates (T,k) f32, idx (T,k) int32, aux (dict))."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.experts_per_token)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # aux losses (Switch-style load balance + z loss)
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], m.num_experts), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    aux = {
        "load_balance": m.num_experts * jnp.sum(density * mean_probs),
        "router_z": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
    }
    return gates, idx, aux


def _capacity(cfg: ModelConfig, T: int) -> int:
    m = cfg.moe
    c = int(math.ceil(m.capacity_factor * m.experts_per_token * T
                      / m.num_experts))
    return max(4, c)


def _dispatch_indices(idx: jnp.ndarray, E: int, C: int):
    """idx: (T, k) expert ids.  Returns (pos (T,k) slot-in-expert,
    keep (T,k) bool) computed in routing order with capacity C."""
    T, k = idx.shape
    flat = idx.reshape(-1)                                   # (T*k,)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)        # (T*k, E)
    pos_flat = jnp.cumsum(onehot, axis=0) - onehot           # slot before me
    pos = jnp.take_along_axis(pos_flat, flat[:, None], axis=1)[:, 0]
    keep = pos < C
    return pos.reshape(T, k), keep.reshape(T, k)


def _expert_ffn(p: Params, xe: jnp.ndarray, act: str) -> jnp.ndarray:
    """xe: (E, C, d) -> (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    if act == "silu":
        a = jax.nn.silu(h)
    elif act == "relu2":
        a = jnp.square(jax.nn.relu(h))
    else:
        a = jax.nn.gelu(h)
    a = a * jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    return jnp.einsum("ecf,efd->ecd", a, p["w_out"])


def moe_mlp_dense(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Capacity-based scatter/gather MoE on whatever tokens are local.

    x: (B, S, d) -> (B, S, d).  Suitable for small T (decode / smoke).
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)
    gates, idx, aux = _route(p, cfg, x2d)
    C = _capacity(cfg, T)
    pos, keep = _dispatch_indices(idx, m.num_experts, C)
    buf = jnp.zeros((m.num_experts, C, d), x.dtype)
    for j in range(m.experts_per_token):           # k small & static
        contrib = jnp.where(keep[:, j, None], x2d, 0).astype(x.dtype)
        buf = buf.at[idx[:, j], jnp.where(keep[:, j], pos[:, j], C - 1)].add(
            jnp.where(keep[:, j, None], contrib, 0))
    out_e = _expert_ffn(p, buf, cfg.mlp_act)       # (E, C, d)
    y2d = jnp.zeros((T, d), jnp.float32)
    for j in range(m.experts_per_token):
        gathered = out_e[idx[:, j], jnp.minimum(pos[:, j], C - 1)]
        y2d = y2d + jnp.where(keep[:, j, None],
                              gathered.astype(jnp.float32)
                              * gates[:, j, None], 0.0)
    y = y2d.reshape(B, S, d).astype(x.dtype)
    if "shared" in p:
        from repro.models.layers import mlp as dense_mlp
        y = y + dense_mlp(p["shared"], x, "silu", True)
    return y, aux


def moe_mlp_ep(p: Params, cfg: ModelConfig, x: jnp.ndarray, mesh,
               data_axes=("data",), model_axis: str = "model",
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Expert-parallel MoE via shard_map + all_to_all over `model`.

    x: (B, S, d) with batch sharded over ``data_axes``.  Inside the region
    the sequence is additionally split over ``model`` so each device routes
    its own token slice; per-expert capacity buffers are exchanged with
    all_to_all so the device owning expert e computes all its tokens.
    """
    try:                       # jax >= 0.6: top-level export, check_vma kwarg
        from jax import shard_map
        check_kw = {"check_vma": False}
    except ImportError:        # older jax: experimental home, check_rep kwarg
        from jax.experimental.shard_map import shard_map
        check_kw = {"check_rep": False}
    m = cfg.moe
    E = m.num_experts
    n_model = mesh.shape[model_axis]
    # pad the expert axis up to a multiple of the model axis (granite: 40
    # experts on 16-way EP -> 48 with 8 never-routed dummies)
    E_pad = -(-E // n_model) * n_model
    E_local = E_pad // n_model

    def local_fn(p_local, x_local):
        # x_local: (B_l, S_l, d); experts sharded: w_* (E_local, ...)
        B_l, S_l, d = x_local.shape
        T_l = B_l * S_l
        x2d = x_local.reshape(T_l, d)
        logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                            p_local["router"])   # router replicated
        if E_pad > E:
            logits = jnp.pad(logits, ((0, 0), (0, E_pad - E)),
                             constant_values=-1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, m.experts_per_token)
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
        density = jnp.mean(jax.nn.one_hot(idx[:, 0], E), axis=0)
        mean_probs = jnp.mean(probs[:, :E], axis=0)
        aux = {
            "load_balance": jax.lax.pmean(
                E * jnp.sum(density * mean_probs), model_axis),
            "router_z": jax.lax.pmean(
                jnp.mean(jnp.square(jax.nn.logsumexp(logits, -1))), model_axis),
        }
        C = _capacity(cfg, T_l)
        pos, keep = _dispatch_indices(idx, E_pad, C)
        buf = jnp.zeros((E_pad, C, d), x_local.dtype)
        for j in range(m.experts_per_token):
            safe_pos = jnp.where(keep[:, j], pos[:, j], C - 1)
            contrib = jnp.where(keep[:, j, None], x2d, 0).astype(x_local.dtype)
            buf = buf.at[idx[:, j], safe_pos].add(contrib)
        # (E, C, d) -> all_to_all: send expert-owner chunks, receive every
        # source's buffer for my local experts.
        buf = buf.reshape(n_model, E_local, C, d)
        recv = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv[s, e_l] = tokens from source s for my local expert e_l
        xe = jnp.swapaxes(recv, 0, 1).reshape(E_local, n_model * C, d)
        out_e = _expert_ffn(p_local, xe, cfg.mlp_act)     # (E_local, nC, d)
        out_e = jnp.swapaxes(out_e.reshape(E_local, n_model, C, d), 0, 1)
        back = jax.lax.all_to_all(out_e, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        back = back.reshape(E_pad, C, d)   # my tokens, expert-major
        y2d = jnp.zeros((T_l, d), jnp.float32)
        for j in range(m.experts_per_token):
            safe_pos = jnp.where(keep[:, j], pos[:, j], C - 1)
            gathered = back[idx[:, j], safe_pos]
            y2d = y2d + jnp.where(keep[:, j, None],
                                  gathered.astype(jnp.float32)
                                  * gates[:, j, None], 0.0)
        y = y2d.reshape(B_l, S_l, d).astype(x_local.dtype)
        return y, aux

    batch_spec = P(data_axes if len(data_axes) > 1 else data_axes[0],
                   model_axis, None)
    espec = P(model_axis, None, None)
    in_specs = (
        {"router": P(None, None), "w_in": espec, "w_gate": espec,
         "w_out": espec},
        batch_spec,
    )
    out_specs = (batch_spec, {"load_balance": P(), "router_z": P()})
    p_moe = {k: p[k] for k in ("router", "w_in", "w_gate", "w_out")}
    if E_pad > E:
        padw = lambda w: jnp.pad(w, ((0, E_pad - E),) + ((0, 0),) * (w.ndim - 1))
        p_moe = dict(p_moe, w_in=padw(p["w_in"]), w_gate=padw(p["w_gate"]),
                     w_out=padw(p["w_out"]))
    y, aux = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, **check_kw)(p_moe, x)
    if "shared" in p:
        from repro.models.layers import mlp as dense_mlp
        y = y + dense_mlp(p["shared"], x, "silu", True)
    return y, aux


def moe_mlp_ref(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Oracle: loop over experts, no capacity drop.  For tests only."""
    m = cfg.moe
    B, S, d = x.shape
    x2d = x.reshape(-1, d)
    gates, idx, _ = _route(p, cfg, x2d)
    y = jnp.zeros_like(x2d, dtype=jnp.float32)
    for e in range(m.num_experts):
        h = jnp.einsum("td,df->tf", x2d, p["w_in"][e])
        if cfg.mlp_act == "silu":
            a = jax.nn.silu(h)
        elif cfg.mlp_act == "relu2":
            a = jnp.square(jax.nn.relu(h))
        else:
            a = jax.nn.gelu(h)
        a = a * jnp.einsum("td,df->tf", x2d, p["w_gate"][e])
        oe = jnp.einsum("tf,fd->td", a, p["w_out"][e]).astype(jnp.float32)
        w = jnp.sum(jnp.where(idx == e, gates, 0.0), axis=1)
        y = y + oe * w[:, None]
    out = y.reshape(B, S, d).astype(x.dtype)
    if "shared" in p:
        from repro.models.layers import mlp as dense_mlp
        out = out + dense_mlp(p["shared"], x, "silu", True)
    return out
