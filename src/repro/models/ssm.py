"""Mamba2 (SSD) blocks: chunked state-space dual form for training/prefill
(lax.scan over chunks — O(chunk^2) intra-chunk compute, states materialised
only at chunk boundaries, TPU/VMEM-friendly) and O(1) recurrent decode.

Shapes follow the Mamba2 minimal formulation:
  x       : (B, T, H, P)    SSM-head inputs (P = head channels)
  dt      : (B, T, H)       discretisation step (softplus + bias)
  A       : (H,)            negative decay rate;  a_log = dt * A
  B_, C_  : (B, T, G, N)    input/output projections (G groups, GQA-style)
  state   : (B, H, N, P)
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_constraint
from repro.models import layers as L

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Core SSD scan
# ---------------------------------------------------------------------------

def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., T) -> (..., T, T) with out[t, s] = sum_{s < r <= t} a_r
    (lower-triangular cumulative segment sums; -inf above diagonal)."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]        # sum_{s<r<=t}
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, a_log: jnp.ndarray, B_: jnp.ndarray,
                C_: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,T,H,P), final_state (B,H,N,P)).

    Scans over T//chunk chunks; each chunk does the quadratic intra-chunk
    contribution plus the inter-chunk state recurrence.
    """
    Bsz, T, H, Pdim = x.shape
    G, N = B_.shape[2], B_.shape[3]
    T_orig = T
    if T % chunk:
        # pad the tail with x=0, a_log=0 (decay 1): state passes through
        pad = chunk - T % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        T = T + pad
    nc = T // chunk
    rep = H // G
    f32 = jnp.float32

    xc = x.reshape(Bsz, nc, chunk, H, Pdim).astype(f32)
    ac = a_log.reshape(Bsz, nc, chunk, H).astype(f32)
    Bc = B_.reshape(Bsz, nc, chunk, G, N).astype(f32)
    Cc = C_.reshape(Bsz, nc, chunk, G, N).astype(f32)

    s0 = (jnp.zeros((Bsz, H, N, Pdim), f32) if init_state is None
          else init_state.astype(f32))

    def chunk_step(state, inp):
        xk, ak, Bk, Ck = inp          # (B, chunk, ...)
        cs = jnp.cumsum(ak, axis=1)                       # (B, c, H)
        total = cs[:, -1]                                 # (B, H)
        # intra-chunk: Lmat[t,s] = exp(sum_{s<r<=t} a_r), causal
        Lmat = jnp.exp(_segsum(jnp.moveaxis(ak, 1, 2)))   # (B, H, c, c)
        CB = jnp.einsum("btgn,bsgn->bgts", Ck, Bk)        # (B, G, c, c)
        CB = jnp.repeat(CB, rep, axis=1)                  # (B, H, c, c)
        M = CB * Lmat
        y_diag = jnp.einsum("bhts,bshp->bthp", M, xk)
        # inter-chunk: contribution of incoming state
        decay_out = jnp.exp(cs)                           # (B, c, H)
        Ch = jnp.repeat(Ck, rep, axis=2)                  # (B, c, H, N)
        y_off = jnp.einsum("bthn,bhnp->bthp", Ch, state) * decay_out[..., None]
        # state update: S' = S * exp(total) + sum_s B_s x_s exp(total - cs_s)
        decay_in = jnp.exp(total[:, None] - cs)           # (B, c, H)
        Bh = jnp.repeat(Bk, rep, axis=2)                  # (B, c, H, N)
        s_add = jnp.einsum("bshn,bsh,bshp->bhnp", Bh, decay_in, xk)
        state = state * jnp.exp(total)[..., None, None] + s_add
        return state, y_diag + y_off

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(ac, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    final, ys = jax.lax.scan(chunk_step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, T, H, Pdim)[:, :T_orig]
    return y.astype(x.dtype), final


def ssd_ref(x, a_log, B_, C_, init_state=None):
    """Sequential oracle: plain recurrence h_t = exp(a_t) h_{t-1} + B_t x_t."""
    Bsz, T, H, Pdim = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    f32 = jnp.float32
    h = (jnp.zeros((Bsz, H, N, Pdim), f32) if init_state is None
         else init_state.astype(f32))
    ys = []
    for t in range(T):
        a = jnp.exp(a_log[:, t].astype(f32))                       # (B, H)
        Bt = jnp.repeat(B_[:, t].astype(f32), rep, axis=1)         # (B, H, N)
        Ct = jnp.repeat(C_[:, t].astype(f32), rep, axis=1)
        h = h * a[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", Bt, x[:, t].astype(f32))
        ys.append(jnp.einsum("bhn,bhnp->bhp", Ct, h))
    return jnp.stack(ys, axis=1).astype(x.dtype), h


def ssd_decode(x1, a_log1, B1, C1, state):
    """One-step recurrence.  x1: (B, H, P); a_log1: (B, H); B1/C1: (B, G, N);
    state: (B, H, N, P) -> (y (B, H, P), new state)."""
    H = x1.shape[1]
    G = B1.shape[1]
    rep = H // G
    f32 = jnp.float32
    a = jnp.exp(a_log1.astype(f32))
    Bh = jnp.repeat(B1.astype(f32), rep, axis=1)
    Ch = jnp.repeat(C1.astype(f32), rep, axis=1)
    state = state * a[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bh, x1.astype(f32))
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state)
    return y.astype(x1.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 block (in_proj -> conv -> SSD -> gate -> norm -> out_proj)
# ---------------------------------------------------------------------------

def mamba2_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = s.num_heads or d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.ngroups * s.state_dim
    return d_inner, nheads, conv_dim


def init_mamba2(key, cfg: ModelConfig, dtype) -> Params:
    """Projection weights are kept per-segment (z / x / BC / dt) rather
    than one concatenated in_proj so each can carry its own sharding:
    z, x, dt shard over `model` (heads/d_inner); BC is tiny and replicated."""
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, conv_dim = mamba2_dims(cfg)
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    gN = 2 * s.ngroups * s.state_dim
    sd = 1.0 / math.sqrt(d)
    return {
        "in_z": (jax.random.normal(k1, (d, d_inner)) * sd).astype(dtype),
        "in_x": (jax.random.normal(k2, (d, d_inner)) * sd).astype(dtype),
        "in_bc": (jax.random.normal(k4, (d, gN)) * sd).astype(dtype),
        "in_dt": (jax.random.normal(k5, (d, nheads)) * sd).astype(dtype),
        "conv_x_w": (jax.random.normal(k6, (s.conv_width, d_inner))
                     * (1.0 / math.sqrt(s.conv_width))).astype(dtype),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_bc_w": (jax.random.normal(k6, (s.conv_width, gN))
                      * (1.0 / math.sqrt(s.conv_width))).astype(dtype),
        "conv_bc_b": jnp.zeros((gN,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "gate_norm": jnp.ones((d_inner,), dtype),
        "out_proj": (jax.random.normal(k3, (d_inner, d))
                     * (1.0 / math.sqrt(d_inner * 2 * cfg.num_layers))
                     ).astype(dtype),
    }


def _causal_conv(xconv: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv.  xconv: (B, T, Cd); w: (K, Cd)."""
    K = w.shape[0]
    if init is None:
        pad = jnp.zeros((xconv.shape[0], K - 1, xconv.shape[2]), xconv.dtype)
    else:
        pad = init.astype(xconv.dtype)
    xp = jnp.concatenate([pad, xconv], axis=1)
    out = sum(xp[:, i:i + xconv.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def mamba2_forward(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                   init_state=None, conv_init=None,
                   return_state: bool = False):
    """x: (B, T, d) -> (B, T, d) [, (ssm_state, conv_state)]."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = mamba2_dims(cfg)
    gN = s.ngroups * s.state_dim
    Bsz, T, _ = x.shape
    z = jnp.einsum("btd,de->bte", x, p["in_z"])
    xi = jnp.einsum("btd,de->bte", x, p["in_x"])
    bc = jnp.einsum("btd,de->bte", x, p["in_bc"])
    dt = jnp.einsum("btd,de->bte", x, p["in_dt"])
    ci_x = conv_init[0] if conv_init is not None else None
    ci_bc = conv_init[1] if conv_init is not None else None
    xs = _causal_conv(xi, p["conv_x_w"], p["conv_x_b"], ci_x)
    bc_out = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"], ci_bc)
    B_, C_ = jnp.split(bc_out, 2, axis=-1)
    xs = xs.reshape(Bsz, T, nheads, s.head_dim)
    xs = logical_constraint(xs, ("batch", "seq_attn", "ssm_heads", None))
    B_ = B_.reshape(Bsz, T, s.ngroups, s.state_dim)
    C_ = C_.reshape(Bsz, T, s.ngroups, s.state_dim)
    dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    A = -jnp.exp(p["A_log"])                                       # (H,)
    a_log = dt_s * A
    x_in = xs.astype(jnp.float32) * dt_s[..., None]
    chunk = min(s.chunk_size, T)
    y, final = ssd_chunked(x_in.astype(x.dtype), a_log, B_, C_, chunk,
                           init_state)
    y = y.astype(jnp.float32) + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, T, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rmsnorm(y.astype(x.dtype), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    out = logical_constraint(out, ("batch", "seq", "embed"))
    if return_state:
        # conv state: last K-1 pre-activation conv inputs per segment
        K = p["conv_x_w"].shape[0]

        def tail(seq, prev, dim):
            if T >= K - 1:
                return seq[:, T - (K - 1):]
            pad = jnp.zeros((Bsz, K - 1 - T, dim), seq.dtype)
            prev = prev if prev is not None else pad
            return jnp.concatenate([prev, seq], axis=1)[:, -(K - 1):]

        conv_state = (tail(xi, ci_x, d_inner), tail(bc, ci_bc, gN))
        return out, (final, conv_state)
    return out


def mamba2_decode(p: Params, cfg: ModelConfig, x1: jnp.ndarray,
                  ssm_state: jnp.ndarray, conv_state: jnp.ndarray):
    """x1: (B, d) one token.  conv_state: (B, K-1, conv_dim)."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = mamba2_dims(cfg)
    Bsz = x1.shape[0]
    z = jnp.einsum("bd,de->be", x1, p["in_z"])
    xi = jnp.einsum("bd,de->be", x1, p["in_x"])
    bc = jnp.einsum("bd,de->be", x1, p["in_bc"])
    dt = jnp.einsum("bd,de->be", x1, p["in_dt"])
    conv_x_state, conv_bc_state = conv_state

    def conv1(win_prev, new, w, b):
        win = jnp.concatenate([win_prev, new[:, None]], axis=1)
        out = jax.nn.silu(jnp.einsum("bkc,kc->bc", win.astype(jnp.float32),
                                     w.astype(jnp.float32))
                          + b.astype(jnp.float32)).astype(x1.dtype)
        return out, win[:, 1:]

    xs, conv_x_state = conv1(conv_x_state, xi, p["conv_x_w"], p["conv_x_b"])
    bc_out, conv_bc_state = conv1(conv_bc_state, bc, p["conv_bc_w"],
                                  p["conv_bc_b"])
    B_, C_ = jnp.split(bc_out, 2, axis=-1)
    xs = xs.reshape(Bsz, nheads, s.head_dim)
    B_ = B_.reshape(Bsz, s.ngroups, s.state_dim)
    C_ = C_.reshape(Bsz, s.ngroups, s.state_dim)
    dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a_log1 = dt_s * A
    x_in = xs.astype(jnp.float32) * dt_s[..., None]
    y, ssm_state = ssd_decode(x_in.astype(x1.dtype), a_log1, B_, C_, ssm_state)
    y = y.astype(jnp.float32) + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(Bsz, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rmsnorm(y.astype(x1.dtype), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])
    return out, ssm_state, (conv_x_state, conv_bc_state)
