"""Dense decoder-only transformer (also the backbone for MoE / VLM archs).

Layer stacks are ``lax.scan`` over stacked parameters so HLO size (and AOT
compile time) is independent of depth.  Alternating layer patterns
(gemma2 local/global) stack as (L/pl, pl, ...) and unroll the inner ``pl``
sub-layers statically inside the scan body.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_constraint
from repro.models import layers as L

Params = Dict[str, Any]

FULL_ATTN_MAX_SEQ = 2048   # above this, use blockwise (flash-style) attention


def pattern_len(cfg: ModelConfig) -> int:
    return 2 if cfg.attn.layer_pattern == "local_global" else 1


def _sub_window(cfg: ModelConfig, j: int) -> int:
    """Sliding window for sub-layer j of a pattern group (0 = full attn)."""
    if cfg.attn.layer_pattern == "local_global":
        return cfg.attn.sliding_window if j == 0 else 0
    return cfg.attn.sliding_window


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype,
               mlp_init: Optional[Callable] = None) -> Params:
    ka, km, kn1, kn2 = jax.random.split(key, 4)
    mlp_init = mlp_init or (lambda k: L.init_mlp(
        k, cfg.d_model, cfg.d_ff, cfg.gated_mlp, cfg.num_layers, dtype))
    return {
        "attn": L.init_attention(ka, cfg, dtype),
        "mlp": mlp_init(km),
        "ln1": L.init_norm(kn1, cfg.d_model, cfg.norm_type, dtype),
        "ln2": L.init_norm(kn2, cfg.d_model, cfg.norm_type, dtype),
    }


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key,
                mlp_init: Optional[Callable] = None) -> Params:
    pl = pattern_len(cfg)
    n_groups = cfg.num_layers // pl
    keys = jax.random.split(key, cfg.num_layers + 3)
    dtype = cfg.param_dtype
    blocks = [init_block(keys[i], cfg, dtype, mlp_init)
              for i in range(cfg.num_layers)]
    if pl == 2:
        groups = [_stack([blocks[2 * i], blocks[2 * i + 1]])
                  for i in range(n_groups)]
    else:
        groups = blocks
    params: Params = {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model))
                  * (1.0 / math.sqrt(cfg.d_model))).astype(dtype),
        "layers": _stack(groups),
        "final_norm": L.init_norm(keys[-2], cfg.d_model, cfg.norm_type, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[-3], (cfg.d_model, cfg.vocab_size))
            * (1.0 / math.sqrt(cfg.d_model))).astype(dtype)
    if cfg.pos_embedding == "learned":
        params["pos_embed"] = (jax.random.normal(
            keys[-3], (cfg.max_position, cfg.d_model)) * 0.02).astype(dtype)
    return params


# ---------------------------------------------------------------------------
# Shared block application
# ---------------------------------------------------------------------------

ZERO_AUX = {"load_balance": jnp.float32(0.0), "router_z": jnp.float32(0.0)}


def _apply_mlp(bp, cfg, h, mlp_fn):
    """Returns (y, aux).  ``mlp_fn(params, x) -> (y, aux)`` (MoE) or dense."""
    if mlp_fn is not None:
        out = mlp_fn(bp["mlp"], h)
        if isinstance(out, tuple):
            return out
        return out, dict(ZERO_AUX)
    return L.mlp(bp["mlp"], h, cfg.mlp_act, cfg.gated_mlp), dict(ZERO_AUX)


def block_forward(bp: Params, cfg: ModelConfig, x: jnp.ndarray,
                  positions: jnp.ndarray, window: int,
                  mlp_fn: Optional[Callable] = None):
    """Full-sequence (training / prefill) block.  Returns (x, aux)."""
    h = L.norm(x, bp["ln1"], cfg.norm_type, cfg.norm_eps)
    q, k, v = L.qkv_project(bp["attn"], cfg, h, positions)
    S = q.shape[1]
    if S <= FULL_ATTN_MAX_SEQ:
        o = L.full_attention(q, k, v, causal=True, window=window,
                             softcap=cfg.attn.attn_softcap)
    else:
        o = L.blockwise_attention(q, k, v, causal=True, window=window,
                                  softcap=cfg.attn.attn_softcap)
    x = x + L.attn_output(bp["attn"], o)
    h = L.norm(x, bp["ln2"], cfg.norm_type, cfg.norm_eps)
    y, aux = _apply_mlp(bp, cfg, h, mlp_fn)
    x = x + y
    return logical_constraint(x, ("batch", "seq", "embed")), aux


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params: Params, cfg: ModelConfig, tokens: jnp.ndarray
                 ) -> jnp.ndarray:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.scale_embeddings:
        x = x * math.sqrt(cfg.d_model)
    return logical_constraint(x, ("batch", "seq", "embed"))


def lm_logits(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = L.norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", x, w.astype(cfg.compute_dtype))
    if cfg.logit_softcap > 0:
        logits = L._softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    axes = (("batch", "seq_out", "vocab") if logits.ndim == 3
            else ("batch", "vocab"))
    return logical_constraint(logits, axes)


# ---------------------------------------------------------------------------
# Forward (training / scoring): full sequence -> logits
# ---------------------------------------------------------------------------

def forward(params: Params, cfg: ModelConfig, tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            mlp_fn: Optional[Callable] = None):
    """Returns (logits, aux) where aux holds summed router losses."""
    x = embed_tokens(params, cfg, tokens) if embeds is None else embeds
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.pos_embedding == "learned":
        x = x + params["pos_embed"][:S][None].astype(x.dtype)
    pl = pattern_len(cfg)

    def body(carry, group):
        h, aux_sum = carry
        for j in range(pl):
            bp = jax.tree.map(lambda a: a[j], group) if pl == 2 else group
            h, aux = block_forward(bp, cfg, h, positions, _sub_window(cfg, j),
                                   mlp_fn=mlp_fn)
            aux_sum = jax.tree.map(jnp.add, aux_sum, aux)
        return (h, aux_sum), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, dict(ZERO_AUX)), params["layers"])
    return lm_logits(params, cfg, x), aux


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Dict[str, jnp.ndarray]:
    dtype = dtype or cfg.compute_dtype
    Kh, D = cfg.num_kv_heads, cfg.resolved_head_dim
    pl = pattern_len(cfg)
    n_groups = cfg.num_layers // pl
    if pl == 2:
        W = min(cfg.attn.sliding_window, max_len)
        return {
            "k_local": jnp.zeros((n_groups, batch, W, Kh, D), dtype),
            "v_local": jnp.zeros((n_groups, batch, W, Kh, D), dtype),
            "k_global": jnp.zeros((n_groups, batch, max_len, Kh, D), dtype),
            "v_global": jnp.zeros((n_groups, batch, max_len, Kh, D), dtype),
        }
    return {
        "k": jnp.zeros((cfg.num_layers, batch, max_len, Kh, D), dtype),
        "v": jnp.zeros((cfg.num_layers, batch, max_len, Kh, D), dtype),
    }


def _write_token(cache_layer: jnp.ndarray, new: jnp.ndarray,
                 idx: jnp.ndarray) -> jnp.ndarray:
    """cache_layer: (Lg, B, S, Kh, D); new: (Lg, B, Kh, D); idx: (B,)."""
    b = jnp.arange(cache_layer.shape[1])
    return cache_layer.at[:, b, idx].set(new.astype(cache_layer.dtype))


# ---------------------------------------------------------------------------
# Decode step: one token, scan over layers
# ---------------------------------------------------------------------------

def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                cache: Dict[str, jnp.ndarray], kv_len: jnp.ndarray,
                mlp_fn: Optional[Callable] = None,
                embeds: Optional[jnp.ndarray] = None,
                return_hidden: bool = False,
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """token: (B,) int32; kv_len: (B,) current lengths (position of the new
    token).  Returns (logits (B, V), updated cache).

    ``return_hidden=True`` returns the final-normed hidden state (B, d)
    instead of logits — the fused-sampling decode path computes the LM
    head blockwise in the same pass as top-k/lse, so the full (B, V)
    logits round-trip never materialises (see rollout/engine.py)."""
    if embeds is None:
        x = embed_tokens(params, cfg, token[:, None])
    else:
        x = embeds[:, None] if embeds.ndim == 2 else embeds
    if cfg.pos_embedding == "learned":
        x = x + params["pos_embed"][kv_len][:, None].astype(x.dtype)
    if pattern_len(cfg) == 2:
        raise ValueError("use decode_step_pattern for local/global archs")
    # write the new token's k/v first, then attend over the cache.
    # Pass cache slices as scan xs; collect per-layer new kv as ys.
    if True:
        def body(h, xs):
            group, kc, vc = xs
            hn = L.norm(h, group["ln1"], cfg.norm_type, cfg.norm_eps)
            q, k, v = L.qkv_project(group["attn"], cfg, hn, kv_len[:, None])
            kc = _write_token(kc[None], k[None, :, 0], kv_len)[0]
            vc = _write_token(vc[None], v[None, :, 0], kv_len)[0]
            o = L.decode_attention(q[:, 0], kc, vc, kv_len + 1,
                                   softcap=cfg.attn.attn_softcap,
                                   window=cfg.attn.sliding_window)
            h = h + L.attn_output(group["attn"], o[:, None])
            hn = L.norm(h, group["ln2"], cfg.norm_type, cfg.norm_eps)
            y, _ = _apply_mlp(group, cfg, hn, mlp_fn)
            h = h + y
            return h, (k[:, 0], v[:, 0])

        x, (k_new, v_new) = jax.lax.scan(body, x, (params["layers"],
                                                   cache["k"], cache["v"]))
        cache = dict(cache)
        cache["k"] = _write_token(cache["k"], k_new, kv_len)
        cache["v"] = _write_token(cache["v"], v_new, kv_len)
        if return_hidden:
            hidden = L.norm(x[:, 0], params["final_norm"], cfg.norm_type,
                            cfg.norm_eps)
            return hidden, cache
        logits = lm_logits(params, cfg, x[:, 0])
        return logits, cache


def decode_step_pattern(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                        cache: Dict[str, jnp.ndarray], kv_len: jnp.ndarray,
                        mlp_fn: Optional[Callable] = None,
                        ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Decode for local/global alternating pattern (gemma2)."""
    x = embed_tokens(params, cfg, token[:, None])
    W = cache["k_local"].shape[2]
    ring_idx = kv_len % W

    def body(h, xs):
        group, kl, vl, kg, vg = xs
        # --- local sub-layer: ring cache of width W ---
        bp = jax.tree.map(lambda a: a[0], group)
        hn = L.norm(h, bp["ln1"], cfg.norm_type, cfg.norm_eps)
        q, k, v = L.qkv_project(bp["attn"], cfg, hn, kv_len[:, None])
        kl = _write_token(kl[None], k[None, :, 0], ring_idx)[0]
        vl = _write_token(vl[None], v[None, :, 0], ring_idx)[0]
        o = L.decode_attention(q[:, 0], kl, vl, jnp.minimum(kv_len + 1, W),
                               softcap=cfg.attn.attn_softcap)
        h = h + L.attn_output(bp["attn"], o[:, None])
        hn = L.norm(h, bp["ln2"], cfg.norm_type, cfg.norm_eps)
        h = h + L.mlp(bp["mlp"], hn, cfg.mlp_act, cfg.gated_mlp)
        # --- global sub-layer: linear cache ---
        bp = jax.tree.map(lambda a: a[1], group)
        hn = L.norm(h, bp["ln1"], cfg.norm_type, cfg.norm_eps)
        q2, k2, v2 = L.qkv_project(bp["attn"], cfg, hn, kv_len[:, None])
        kg = _write_token(kg[None], k2[None, :, 0], kv_len)[0]
        vg = _write_token(vg[None], v2[None, :, 0], kv_len)[0]
        o2 = L.decode_attention(q2[:, 0], kg, vg, kv_len + 1,
                                softcap=cfg.attn.attn_softcap)
        h = h + L.attn_output(bp["attn"], o2[:, None])
        hn = L.norm(h, bp["ln2"], cfg.norm_type, cfg.norm_eps)
        h = h + L.mlp(bp["mlp"], hn, cfg.mlp_act, cfg.gated_mlp)
        return h, (k[:, 0], v[:, 0], k2[:, 0], v2[:, 0])

    x, (kl_n, vl_n, kg_n, vg_n) = jax.lax.scan(
        body, x, (params["layers"], cache["k_local"], cache["v_local"],
                  cache["k_global"], cache["v_global"]))
    cache = {
        "k_local": _write_token(cache["k_local"], kl_n, ring_idx),
        "v_local": _write_token(cache["v_local"], vl_n, ring_idx),
        "k_global": _write_token(cache["k_global"], kg_n, kv_len),
        "v_global": _write_token(cache["v_global"], vg_n, kv_len),
    }
    logits = lm_logits(params, cfg, x[:, 0])
    return logits, cache


def decode(params, cfg, token, cache, kv_len, mlp_fn=None, embeds=None,
           return_hidden=False):
    if pattern_len(cfg) == 2:
        assert not return_hidden, "return_hidden: local/global not supported"
        return decode_step_pattern(params, cfg, token, cache, kv_len, mlp_fn)
    return decode_step(params, cfg, token, cache, kv_len, mlp_fn, embeds,
                       return_hidden=return_hidden)


# ---------------------------------------------------------------------------
# Prefill: run full (padded) prompts through, filling the cache
# ---------------------------------------------------------------------------

def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            cache: Dict[str, jnp.ndarray], prompt_lens: jnp.ndarray,
            mlp_fn: Optional[Callable] = None,
            embeds: Optional[jnp.ndarray] = None,
            seg_ids: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """tokens: (B, S) right-padded prompts.  Fills cache[:, :, :S]; returns
    (logits at each position (B, S, V), cache).  Padded positions are
    masked downstream via kv_len = prompt_lens.

    Packed mode (``seg_ids`` given): each row holds several prompts
    concatenated back to back; ``seg_ids`` (B, S) carries the row-local
    segment index (-1 for padding) and ``positions`` the within-segment
    position of every token (rope / learned pos-emb see per-prompt
    coordinates).  Attention masks across segment boundaries; the causal
    and sliding-window masks stay correct under the packed global arange
    because segments are contiguous, so global position deltas equal
    within-segment deltas."""
    x = embed_tokens(params, cfg, tokens) if embeds is None else embeds
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.pos_embedding == "learned":
        if seg_ids is None:
            x = x + params["pos_embed"][:S][None].astype(x.dtype)
        else:
            x = x + params["pos_embed"][positions].astype(x.dtype)
    pl = pattern_len(cfg)

    if pl == 2:
        assert seg_ids is None, "packed prefill: local/global not supported"
        W = cache["k_local"].shape[2]

        def body(h, xs):
            group, kl, vl, kg, vg = xs
            outs = []
            for j, (kc, vc) in enumerate(((kl, vl), (kg, vg))):
                bp = jax.tree.map(lambda a: a[j], group)
                hn = L.norm(h, bp["ln1"], cfg.norm_type, cfg.norm_eps)
                q, k, v = L.qkv_project(bp["attn"], cfg, hn, positions)
                window = _sub_window(cfg, j)
                if S <= FULL_ATTN_MAX_SEQ:
                    o = L.full_attention(q, k, v, causal=True, window=window,
                                         softcap=cfg.attn.attn_softcap)
                else:
                    o = L.blockwise_attention(q, k, v, causal=True,
                                              window=window,
                                              softcap=cfg.attn.attn_softcap)
                h = h + L.attn_output(bp["attn"], o)
                hn = L.norm(h, bp["ln2"], cfg.norm_type, cfg.norm_eps)
                h = h + L.mlp(bp["mlp"], hn, cfg.mlp_act, cfg.gated_mlp)
                if j == 0:
                    # ring cache: slot for position p is p % W; keep last W
                    if S <= W:
                        kc = kc.at[:, :S].set(k.astype(kc.dtype))
                        vc = vc.at[:, :S].set(v.astype(vc.dtype))
                    else:
                        idx = jnp.arange(S - W, S) % W
                        kc = kc.at[:, idx].set(k[:, S - W:].astype(kc.dtype))
                        vc = vc.at[:, idx].set(v[:, S - W:].astype(vc.dtype))
                else:
                    kc = kc.at[:, :S].set(k.astype(kc.dtype))
                    vc = vc.at[:, :S].set(v.astype(vc.dtype))
                outs.append((kc, vc))
            return h, (outs[0][0], outs[0][1], outs[1][0], outs[1][1])

        x, (kl, vl, kg, vg) = jax.lax.scan(
            body, x, (params["layers"], cache["k_local"], cache["v_local"],
                      cache["k_global"], cache["v_global"]))
        cache = {"k_local": kl, "v_local": vl, "k_global": kg, "v_global": vg}
    else:
        def body(h, xs):
            group, kc, vc = xs
            hn = L.norm(h, group["ln1"], cfg.norm_type, cfg.norm_eps)
            q, k, v = L.qkv_project(group["attn"], cfg, hn, positions)
            if S <= FULL_ATTN_MAX_SEQ:
                o = L.full_attention(q, k, v, causal=True,
                                     window=cfg.attn.sliding_window,
                                     softcap=cfg.attn.attn_softcap,
                                     seg_q=seg_ids, seg_k=seg_ids)
            else:
                o = L.blockwise_attention(q, k, v, causal=True,
                                          window=cfg.attn.sliding_window,
                                          softcap=cfg.attn.attn_softcap,
                                          seg_q=seg_ids, seg_k=seg_ids)
            h = h + L.attn_output(group["attn"], o)
            hn = L.norm(h, group["ln2"], cfg.norm_type, cfg.norm_eps)
            y, _ = _apply_mlp(group, cfg, hn, mlp_fn)
            h = h + y
            kc = kc.at[:, :S].set(k.astype(kc.dtype))
            vc = vc.at[:, :S].set(v.astype(vc.dtype))
            return h, (kc, vc)

        x, (kc, vc) = jax.lax.scan(body, x, (params["layers"],
                                             cache["k"], cache["v"]))
        cache = dict(cache, k=kc, v=vc)
    logits = lm_logits(params, cfg, x)
    return logits, cache
