"""Shared model building blocks: norms, rotary embeddings, attention
(GQA / qk-norm / bias / soft-cap / sliding-window / blockwise-causal),
MLP variants.  Pure functional: params are dicts of jnp arrays.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint

Params = Dict[str, jnp.ndarray]

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm(x, p: Params, kind: str, eps: float):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"], eps)
    return layernorm(x, p["scale"], p["bias"], eps)


def init_norm(key, d: int, kind: str, dtype) -> Params:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs   # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]               # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(length: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    emb = jnp.zeros((length, d), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(pos * div))
    emb = emb.at[:, 1::2].set(jnp.cos(pos * div))
    return emb


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def full_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   causal: bool = True, window: int = 0, softcap: float = 0.0,
                   q_offset: int = 0, seg_q=None, seg_k=None) -> jnp.ndarray:
    """Reference attention, materialises (B, H, Sq, Sk) scores in f32.

    q: (B, Sq, H, D); k/v: (B, Sk, Kh, D) with H = Kh * G (GQA).
    Used for short sequences and as the oracle for the Pallas kernels.
    """
    B, Sq, H, D = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qf = q.astype(jnp.float32).reshape(B, Sq, Kh, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) / math.sqrt(D)
    scores = _softcap(scores, softcap)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    mask_b = jnp.broadcast_to(mask, (B,) + mask.shape)
    if seg_q is not None:
        mask_b &= seg_q[:, :, None] == seg_k[:, None, :]
    scores = jnp.where(mask_b[:, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)   # fully-masked rows
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vf)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0,
                        q_block: int = 512, k_block: int = 1024,
                        seg_q=None, seg_k=None) -> jnp.ndarray:
    """Memory-bounded causal attention: lax.map over q blocks, lax.scan over
    kv blocks with online-softmax carry.  O(Sq/Bq * B*H*Bq*Bk) temp memory.

    This is the pure-JAX flash-attention used for long-sequence prefill on
    every backend; the Pallas kernel implements the same tiling for TPU.
    ``seg_q``/``seg_k``: (B, S) segment ids for packed prefill — tokens
    attend only within their segment (pad positions carry -1).
    """
    B, Sq, H, D = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    Sq_orig, Sk_orig = Sq, Sk
    if Sq % q_block:
        q = jnp.pad(q, ((0, 0), (0, q_block - Sq % q_block), (0, 0), (0, 0)))
        Sq = q.shape[1]
    if seg_q is not None and Sq != Sq_orig:
        seg_q = jnp.pad(seg_q, ((0, 0), (0, Sq - Sq_orig)),
                        constant_values=-1)
    if Sk % k_block:
        # padded keys are masked out via the kpos < Sk_orig check below
        k = jnp.pad(k, ((0, 0), (0, k_block - Sk % k_block), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_block - Sk % k_block), (0, 0), (0, 0)))
        Sk = k.shape[1]
    if seg_k is not None and Sk != Sk_orig:
        seg_k = jnp.pad(seg_k, ((0, 0), (0, Sk - Sk_orig)),
                        constant_values=-1)
    nq, nk = Sq // q_block, Sk // k_block
    scale = 1.0 / math.sqrt(D)

    # GQA via kv-head repetition, NOT head-dim folding: a (Kh, G) reshape
    # of the model-sharded H axis breaks GSPMD sharding when Kh < mesh
    # (score all-gathers); repeated kv stays local per head shard.
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    kb = k.reshape(B, nk, k_block, H, D)
    vb = v.reshape(B, nk, k_block, H, D)
    skb = (seg_k.reshape(B, nk, k_block) if seg_k is not None
           else jnp.zeros((B, nk, k_block), jnp.int32))

    def one_q_block(qi):
        qblk = jax.lax.dynamic_slice_in_dim(q, qi * q_block, q_block, axis=1)
        # contract in the input dtype with f32 accumulation (MXU-native);
        # f32 upcasts double HBM + collective traffic for no accuracy the
        # f32 softmax below doesn't already provide
        qblk = (qblk.astype(jnp.float32) * scale).astype(q.dtype)
        qpos = qi * q_block + jnp.arange(q_block)
        sq_blk = (jax.lax.dynamic_slice_in_dim(seg_q, qi * q_block, q_block,
                                               axis=1)
                  if seg_q is not None else None)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, vj, skj, kidx = inp
            kpos = kidx * k_block + jnp.arange(k_block)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kj,
                           preferred_element_type=jnp.float32)
            s = _softcap(s, softcap)
            mask = kpos[None, :] < Sk_orig
            mask = jnp.broadcast_to(mask, (q_block, k_block))
            if causal:
                mask = mask & (qpos[:, None] >= kpos[None, :])
            if window:
                mask = mask & (qpos[:, None] - kpos[None, :] < window)
            if sq_blk is not None:
                mask = mask[None] & (sq_blk[:, :, None] == skj[:, None, :])
            else:
                mask = mask[None]
            s = jnp.where(mask[:, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (m_new == -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        a0 = jnp.zeros((B, H, q_block, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
             jnp.moveaxis(skb, 1, 0), jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, H, q_block, D) -> (B, q_block, H, D)
        return jnp.moveaxis(out, 2, 1).astype(q.dtype)

    blocks = jax.lax.map(one_q_block, jnp.arange(nq))   # (nq, B, qb, H, D)
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, H, D)
    return out[:, :Sq_orig]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     kv_len: jnp.ndarray, *, softcap: float = 0.0,
                     window: int = 0, cache_offset: int = 0,
                     kv_start: Optional[jnp.ndarray] = None,
                     combine_axis: Optional[str] = None) -> jnp.ndarray:
    """Single-token ragged decode attention.

    q: (B, H, D); k/v_cache: (B, S, Kh, D); kv_len: (B,) valid lengths.
    ``kv_start``: (B,) first valid cache index (left-padded prefills).
    ``cache_offset``: global position of cache slot 0 (context-parallel
    shards pass their shard offset).  ``combine_axis``: mesh axis name for
    distributed flash-decode (partial max/sum combined via lax.p* ops —
    callers must be inside shard_map for that mode).
    """
    B, H, D = q.shape
    S, Kh = k_cache.shape[1], k_cache.shape[2]
    G = H // Kh
    # contract in the cache dtype with f32 accumulation (MXU-native
    # bf16 x bf16 -> f32); casting the cache to f32 would materialise a
    # full converted copy every step (2x the decode HBM traffic).
    qf = (q / math.sqrt(D)).astype(k_cache.dtype).reshape(B, Kh, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache,
                   preferred_element_type=jnp.float32)
    s = _softcap(s, softcap)
    pos = cache_offset + jnp.arange(S)
    valid = pos[None, :] < kv_len[:, None]
    if kv_start is not None:
        valid &= pos[None, :] >= kv_start[:, None]
    if window:
        valid &= pos[None, :] >= (kv_len[:, None] - window)
    s = jnp.where(valid[:, None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1)                          # (B, Kh, G)
    if combine_axis is not None:
        m = jax.lax.pmax(m, combine_axis)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    if combine_axis is not None:
        l = jax.lax.psum(l, combine_axis)
        acc = jax.lax.psum(acc, combine_axis)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention module (projections + variants)
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, Kh = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sd = 1.0 / math.sqrt(d)
    p: Params = {
        "wq": (jax.random.normal(k1, (d, H, hd)) * sd).astype(dtype),
        "wk": (jax.random.normal(k2, (d, Kh, hd)) * sd).astype(dtype),
        "wv": (jax.random.normal(k3, (d, Kh, hd)) * sd).astype(dtype),
        "wo": (jax.random.normal(k4, (H, hd, d)) * sd / math.sqrt(2 * cfg.num_layers)).astype(dtype),
    }
    if cfg.attn.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((Kh, hd), dtype)
        p["bv"] = jnp.zeros((Kh, hd), dtype)
    if cfg.attn.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def qkv_project(p: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> q (B,S,H,D), k/v (B,S,Kh,D); applies bias/qk-norm/rope."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.attn.rope_theta)
        k = apply_rope(k, positions, cfg.attn.rope_theta)
    # "seq_attn" (not "seq"): under sequence parallelism the residual
    # stream is seq-sharded but attention wants full sequences per head
    q = logical_constraint(q, ("batch", "seq_attn", "heads", "head_dim"))
    k = logical_constraint(k, ("batch", "seq_attn", "kv_heads", "head_dim"))
    v = logical_constraint(v, ("batch", "seq_attn", "kv_heads", "head_dim"))
    return q, k, v


def attn_output(p: Params, o: jnp.ndarray) -> jnp.ndarray:
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return logical_constraint(out, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, gated: bool, num_layers: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    sd_in, sd_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(d_ff * 2 * num_layers)
    p = {
        "w_in": (jax.random.normal(k1, (d, d_ff)) * sd_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d)) * sd_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(k3, (d, d_ff)) * sd_in).astype(dtype)
    return p


def mlp(p: Params, x: jnp.ndarray, act: str, gated: bool) -> jnp.ndarray:
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    h = logical_constraint(h, ("batch", "seq_attn", "ffn"))
    if act == "silu":
        a = jax.nn.silu(h)
    elif act == "relu2":
        a = jnp.square(jax.nn.relu(h))
    elif act == "gelu":
        a = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    if gated:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        a = a * g
    out = jnp.einsum("bsf,fd->bsd", a, p["w_out"])
    return logical_constraint(out, ("batch", "seq", "embed"))
