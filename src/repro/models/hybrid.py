"""Zamba2-style hybrid [arXiv:2411.15242]: a Mamba2 backbone with a single
*shared* attention+MLP block applied every ``attn_every`` SSM layers
(weights shared across applications; each application has its own KV cache).

Layout for L layers, k = attn_every:  g = L // k groups of (k mamba layers
+ shared attn block), then L - g*k tail mamba layers.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as TF

Params = Dict[str, Any]


def _layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    k = cfg.attn_every
    g = cfg.num_layers // k
    tail = cfg.num_layers - g * k
    return g, k, tail


def init_params(cfg: ModelConfig, key) -> Params:
    g, k, tail = _layout(cfg)
    dtype = cfg.param_dtype
    keys = jax.random.split(key, cfg.num_layers + 4)
    mamba = [S.init_mamba2(keys[i], cfg, dtype) for i in range(cfg.num_layers)]
    main = [TF._stack(mamba[gi * k:(gi + 1) * k]) for gi in range(g)]
    params: Params = {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model))
                  * (1.0 / math.sqrt(cfg.d_model))).astype(dtype),
        "mamba_main": TF._stack(main),                      # (g, k, ...)
        "shared_attn": TF.init_block(keys[-2], cfg, dtype),
        "final_norm": L.init_norm(keys[-3], cfg.d_model, cfg.norm_type, dtype),
        "lm_head": (jax.random.normal(keys[-4], (cfg.d_model, cfg.vocab_size))
                    * (1.0 / math.sqrt(cfg.d_model))).astype(dtype),
    }
    if tail:
        params["mamba_tail"] = TF._stack(mamba[g * k:])     # (tail, ...)
    return params


def _shared_attn_forward(p, cfg, x, positions):
    x, _ = TF.block_forward(p, cfg, x, positions, 0)
    return x


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = TF.embed_tokens(params, cfg, tokens)
    B, T = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    g, k, tail = _layout(cfg)

    def group_body(h, gparams):
        def mamba_body(hh, mp):
            return hh + S.mamba2_forward(mp, cfg, hh), None
        h, _ = jax.lax.scan(mamba_body, h, gparams)
        h = _shared_attn_forward(params["shared_attn"], cfg, h, positions)
        return h, None

    if cfg.remat:
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    x, _ = jax.lax.scan(group_body, x, params["mamba_main"])
    if tail:
        def mamba_body(hh, mp):
            return hh + S.mamba2_forward(mp, cfg, hh), None
        x, _ = jax.lax.scan(mamba_body, x, params["mamba_tail"])
    return TF.lm_logits(params, cfg, x)


# ---------------------------------------------------------------------------
# Cache: ssm+conv state per mamba layer, KV cache per shared-attn application
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Dict[str, jnp.ndarray]:
    dtype = dtype or cfg.compute_dtype
    g, k, tail = _layout(cfg)
    d_inner, nheads, conv_dim = S.mamba2_dims(cfg)
    s = cfg.ssm
    gN = 2 * s.ngroups * s.state_dim
    Kh, D = cfg.num_kv_heads, cfg.resolved_head_dim
    Kc = s.conv_width - 1
    cache = {
        "ssm_main": jnp.zeros((g, k, batch, nheads, s.state_dim, s.head_dim),
                              jnp.float32),
        "conv_x_main": jnp.zeros((g, k, batch, Kc, d_inner), dtype),
        "conv_bc_main": jnp.zeros((g, k, batch, Kc, gN), dtype),
        "attn_k": jnp.zeros((g, batch, max_len, Kh, D), dtype),
        "attn_v": jnp.zeros((g, batch, max_len, Kh, D), dtype),
    }
    if tail:
        cache["ssm_tail"] = jnp.zeros(
            (tail, batch, nheads, s.state_dim, s.head_dim), jnp.float32)
        cache["conv_x_tail"] = jnp.zeros((tail, batch, Kc, d_inner), dtype)
        cache["conv_bc_tail"] = jnp.zeros((tail, batch, Kc, gN), dtype)
    return cache


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            cache: Dict[str, jnp.ndarray], prompt_lens: jnp.ndarray,
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """NOTE: SSM state prefill with *ragged* prompt lengths would require
    per-slot state snapshots at prompt_lens; we require right-aligned
    (left-padded) prompts for hybrid/ssm archs instead — the engine pads
    left so every slot's last token sits at position S-1 and states are
    exact.  Padding tokens decay into the state with x=0 contributions via
    a mask."""
    x = TF.embed_tokens(params, cfg, tokens)
    B, T = x.shape[:2]
    # left-padded: valid tokens occupy [T - len, T)
    positions = (jnp.arange(T)[None] - (T - prompt_lens)[:, None])
    valid = positions >= 0
    x = jnp.where(valid[..., None], x, 0)
    positions = jnp.maximum(positions, 0)
    g, k, tail = _layout(cfg)

    def group_body(carry, xs):
        h = carry
        gparams, ssm_g, cvx_g, cvbc_g, kc, vc = xs

        def mamba_body(hh, ms):
            mp, st, cvx, cvbc = ms
            out, (st2, (cvx2, cvbc2)) = S.mamba2_forward(
                mp, cfg, jnp.where(valid[..., None], hh, 0),
                init_state=None, conv_init=None, return_state=True)
            return (hh + jnp.where(valid[..., None], out, 0),
                    (st2, cvx2, cvbc2))

        h, (ssm_new, cvx_new, cvbc_new) = jax.lax.scan(
            mamba_body, h, (gparams, ssm_g, cvx_g, cvbc_g))
        # shared attention with its own cache slot
        bp = params["shared_attn"]
        hn = L.norm(h, bp["ln1"], cfg.norm_type, cfg.norm_eps)
        q, kk, vv = L.qkv_project(bp["attn"], cfg, hn, positions)
        seg = valid.astype(jnp.int32)
        if T <= TF.FULL_ATTN_MAX_SEQ:
            o = L.full_attention(q, kk, vv, causal=True, seg_q=seg, seg_k=seg)
        else:
            o = L.blockwise_attention(q, kk, vv, causal=True)
        h = h + jnp.where(valid[..., None], L.attn_output(bp["attn"], o), 0)
        hn = L.norm(h, bp["ln2"], cfg.norm_type, cfg.norm_eps)
        h = h + jnp.where(valid[..., None],
                          L.mlp(bp["mlp"], hn, cfg.mlp_act, cfg.gated_mlp), 0)
        kc = kc.at[:, :T].set(kk.astype(kc.dtype))
        vc = vc.at[:, :T].set(vv.astype(vc.dtype))
        return h, (ssm_new, cvx_new, cvbc_new, kc, vc)

    x, (ssm_m, cvx_m, cvbc_m, kc, vc) = jax.lax.scan(
        group_body, x, (params["mamba_main"], cache["ssm_main"],
                        cache["conv_x_main"], cache["conv_bc_main"],
                        cache["attn_k"], cache["attn_v"]))
    cache = dict(cache, ssm_main=ssm_m, conv_x_main=cvx_m,
                 conv_bc_main=cvbc_m, attn_k=kc, attn_v=vc)
    if tail:
        def mamba_body(hh, ms):
            mp, st, cvx, cvbc = ms
            out, (st2, (cvx2, cvbc2)) = S.mamba2_forward(
                mp, cfg, jnp.where(valid[..., None], hh, 0),
                return_state=True)
            return (hh + jnp.where(valid[..., None], out, 0),
                    (st2, cvx2, cvbc2))
        x, (st_t, cvx_t, cvbc_t) = jax.lax.scan(
            mamba_body, x, (params["mamba_tail"], cache["ssm_tail"],
                            cache["conv_x_tail"], cache["conv_bc_tail"]))
        cache = dict(cache, ssm_tail=st_t, conv_x_tail=cvx_t,
                     conv_bc_tail=cvbc_t)
    logits = TF.lm_logits(params, cfg, x)
    return logits, cache


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                cache: Dict[str, jnp.ndarray], kv_len: jnp.ndarray,
                kv_start: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """token (B,), kv_len (B,): write index in the attention caches (the
    SSM state implicitly encodes the same history).  ``kv_start``: first
    valid cache row per slot (left-padded prefills)."""
    x = TF.embed_tokens(params, cfg, token[:, None])[:, 0]   # (B, d)
    g, k, tail = _layout(cfg)
    B = x.shape[0]
    if kv_start is None:
        kv_start = jnp.zeros_like(kv_len)
    positions = kv_len - kv_start

    def group_body(h, xs):
        gparams, ssm_g, cvx_g, cvbc_g, kc, vc = xs

        def mamba_body(hh, ms):
            mp, st, cvx, cvbc = ms
            out, st2, (cvx2, cvbc2) = S.mamba2_decode(mp, cfg, hh, st,
                                                      (cvx, cvbc))
            return hh + out, (st2, cvx2, cvbc2)

        h, (ssm_new, cvx_new, cvbc_new) = jax.lax.scan(
            mamba_body, h, (gparams, ssm_g, cvx_g, cvbc_g))
        bp = params["shared_attn"]
        hn = L.norm(h[:, None], bp["ln1"], cfg.norm_type, cfg.norm_eps)
        q, kk, vv = L.qkv_project(bp["attn"], cfg, hn, positions[:, None])
        kc = TF._write_token(kc[None], kk[None, :, 0], kv_len)[0]
        vc = TF._write_token(vc[None], vv[None, :, 0], kv_len)[0]
        o = L.decode_attention(q[:, 0], kc, vc, kv_len + 1,
                               kv_start=kv_start)
        h = h + L.attn_output(bp["attn"], o[:, None])[:, 0]
        hn = L.norm(h[:, None], bp["ln2"], cfg.norm_type, cfg.norm_eps)
        h = h + L.mlp(bp["mlp"], hn, cfg.mlp_act, cfg.gated_mlp)[:, 0]
        return h, (ssm_new, cvx_new, cvbc_new, kk[:, 0], vv[:, 0])

    x, (ssm_m, cvx_m, cvbc_m, k_new, v_new) = jax.lax.scan(
        group_body, x, (params["mamba_main"], cache["ssm_main"],
                        cache["conv_x_main"], cache["conv_bc_main"],
                        cache["attn_k"], cache["attn_v"]))
    cache = dict(cache,
                 ssm_main=ssm_m, conv_x_main=cvx_m, conv_bc_main=cvbc_m,
                 attn_k=TF._write_token(cache["attn_k"], k_new, kv_len),
                 attn_v=TF._write_token(cache["attn_v"], v_new, kv_len))
    if tail:
        def mamba_body(hh, ms):
            mp, st, cvx, cvbc = ms
            out, st2, (cvx2, cvbc2) = S.mamba2_decode(mp, cfg, hh, st,
                                                      (cvx, cvbc))
            return hh + out, (st2, cvx2, cvbc2)
        x, (st_t, cvx_t, cvbc_t) = jax.lax.scan(
            mamba_body, x, (params["mamba_tail"], cache["ssm_tail"],
                            cache["conv_x_tail"], cache["conv_bc_tail"]))
        cache = dict(cache, ssm_tail=st_t, conv_x_tail=cvx_t,
                     conv_bc_tail=cvbc_t)
    logits = TF.lm_logits(params, cfg, x)
    return logits, cache
