"""Unified model interface: ``build_model(cfg)`` returns a :class:`Model`
bundle of pure functions shared by the trainer, the rollout engine, and the
dry-run launcher.

Batch dict conventions
----------------------
* train / scoring : {"tokens": (B, S) i32, ...}  (+ "patch_embeds" for vlm,
  "frames" for audio — the stub frontends per the assignment carve-out)
* prefill         : {"tokens": (B, S) i32, "prompt_lens": (B,) i32, ...}
* decode          : token (B,) i32, cache pytree, kv_len (B,) i32
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import hybrid as HY
from repro.models import moe as MOE
from repro.models import transformer as TF
from repro.models import whisper as WH
from repro.models import xlstm as XL


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_params: Callable          # (key) -> params
    forward: Callable              # (params, batch) -> (logits, aux)
    init_cache: Callable           # (batch_size, max_len) -> cache
    prefill: Callable              # (params, batch, cache) -> (logits, cache)
    decode_step: Callable          # (params, token, cache, kv_len, **kw) -> (logits, cache)
    padding_side: str              # "right" (attention) | "left" (ssm/hybrid)
    prefill_extra: int = 0         # cache rows prepended by the stub frontend
    # packed ragged prefill: several prompts concatenated per row with
    # segment-offset tables (batch adds "seg_ids"/"positions").  None for
    # families without segment-masked attention support.
    prefill_packed: Any = None     # (params, batch, cache) -> (logits, cache)


def _moe_mlp_fn(cfg: ModelConfig, ep_mesh=None, data_axes=("data",)):
    if ep_mesh is not None:
        def fn(p, x):
            return MOE.moe_mlp_ep(p, cfg, x, ep_mesh, data_axes=data_axes)
    else:
        def fn(p, x):
            return MOE.moe_mlp_dense(p, cfg, x)
    return fn


def build_model(cfg: ModelConfig, ep_mesh=None, data_axes=("data",)) -> Model:
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        mlp_fn = _moe_mlp_fn(cfg, ep_mesh, data_axes) if fam == "moe" else None
        mlp_init = ((lambda k: MOE.init_moe_mlp(k, cfg, cfg.param_dtype))
                    if fam == "moe" else None)

        def init_params(key):
            return TF.init_params(cfg, key, mlp_init=mlp_init)

        def forward(params, batch):
            if fam == "vlm" and "patch_embeds" in batch:
                tok_e = TF.embed_tokens(params, cfg, batch["tokens"])
                pe = batch["patch_embeds"].astype(tok_e.dtype)
                x = jnp.concatenate([pe, tok_e], axis=1)
                return TF.forward(params, cfg, embeds=x, mlp_fn=mlp_fn)
            return TF.forward(params, cfg, batch["tokens"], mlp_fn=mlp_fn)

        def init_cache(batch_size, max_len):
            return TF.init_cache(cfg, batch_size, max_len)

        def prefill(params, batch, cache):
            embeds = None
            if fam == "vlm" and "patch_embeds" in batch:
                tok_e = TF.embed_tokens(params, cfg, batch["tokens"])
                pe = batch["patch_embeds"].astype(tok_e.dtype)
                embeds = jnp.concatenate([pe, tok_e], axis=1)
            return TF.prefill(params, cfg, batch["tokens"], cache,
                              batch["prompt_lens"], mlp_fn=mlp_fn,
                              embeds=embeds)

        def decode_step(params, token, cache, kv_len, **kw):
            return TF.decode(params, cfg, token, cache, kv_len, mlp_fn=mlp_fn,
                             return_hidden=kw.get("return_hidden", False))

        prefill_packed = None
        if fam != "vlm":
            # vlm prepends stub patch rows per prompt — incompatible with
            # the packed layout's contiguous-segment assumption
            def prefill_packed(params, batch, cache):
                return TF.prefill(params, cfg, batch["tokens"], cache,
                                  batch["prompt_lens"], mlp_fn=mlp_fn,
                                  seg_ids=batch["seg_ids"],
                                  positions=batch["positions"])

        return Model(cfg, init_params, forward, init_cache, prefill,
                     decode_step, padding_side="right",
                     prefill_extra=(cfg.num_stub_positions
                                    if fam == "vlm" else 0),
                     prefill_packed=prefill_packed)

    if fam == "hybrid":
        def forward(params, batch):
            return HY.forward(params, cfg, batch["tokens"]), dict(TF.ZERO_AUX)

        def prefill(params, batch, cache):
            return HY.prefill(params, cfg, batch["tokens"], cache,
                              batch["prompt_lens"])

        def decode_step(params, token, cache, kv_len, **kw):
            return HY.decode_step(params, cfg, token, cache, kv_len,
                                  kv_start=kw.get("kv_start"))

        return Model(cfg, lambda key: HY.init_params(cfg, key), forward,
                     lambda b, m: HY.init_cache(cfg, b, m), prefill,
                     decode_step, padding_side="left")

    if fam == "ssm":
        def forward(params, batch):
            return XL.forward(params, cfg, batch["tokens"]), dict(TF.ZERO_AUX)

        def prefill(params, batch, cache):
            return XL.prefill(params, cfg, batch["tokens"], cache,
                              batch["prompt_lens"])

        def decode_step(params, token, cache, kv_len, **kw):
            return XL.decode_step(params, cfg, token, cache, kv_len)

        return Model(cfg, lambda key: XL.init_params(cfg, key), forward,
                     lambda b, m: XL.init_cache(cfg, b, m), prefill,
                     decode_step, padding_side="left")

    if fam == "audio":
        def forward(params, batch):
            return (WH.forward(params, cfg, batch["tokens"], batch["frames"]),
                    dict(TF.ZERO_AUX))

        def prefill(params, batch, cache):
            return WH.prefill(params, cfg, batch["tokens"], cache,
                              batch["prompt_lens"],
                              frames=batch.get("frames"))

        def decode_step(params, token, cache, kv_len, **kw):
            return WH.decode_step(params, cfg, token, cache, kv_len)

        return Model(cfg, lambda key: WH.init_params(cfg, key), forward,
                     lambda b, m: WH.init_cache(cfg, b, m), prefill,
                     decode_step, padding_side="right")

    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for the dry-run (no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, seq_len: int, batch: int, kind: str
                ) -> Dict[str, Any]:
    """Returns the batch pytree as ShapeDtypeStructs for jit(...).lower().

    train  : RL update-step inputs (tokens, loss_mask, advantages, old_logprobs)
    prefill: prompt batch
    decode : one-token step inputs (token, kv_len) — the KV cache spec is
             built separately via ``cache_specs``.
    """
    sds = jax.ShapeDtypeStruct
    i32, f32 = jnp.int32, jnp.float32
    if kind == "train":
        batch_specs = {
            "tokens": sds((batch, seq_len), i32),
            "loss_mask": sds((batch, seq_len), f32),
            "advantages": sds((batch, seq_len), f32),
            "old_logprobs": sds((batch, seq_len), f32),
        }
    elif kind == "prefill":
        batch_specs = {
            "tokens": sds((batch, seq_len), i32),
            "prompt_lens": sds((batch,), i32),
        }
    elif kind == "decode":
        batch_specs = {
            "token": sds((batch,), i32),
            "kv_len": sds((batch,), i32),
        }
    else:
        raise ValueError(kind)
    if cfg.family == "vlm" and kind != "decode":
        batch_specs["patch_embeds"] = sds(
            (batch, cfg.num_stub_positions, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio" and kind != "decode":
        batch_specs["frames"] = sds(
            (batch, cfg.num_stub_positions, cfg.d_model), jnp.bfloat16)
    return batch_specs


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    """Cache pytree as ShapeDtypeStructs (eval_shape — no allocation)."""
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))
