"""End-to-end system tests: the full SortedRL pipeline (task generator ->
controller -> real JAX engine -> trainer) plus launch-layer structure
checks on the local mesh."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffer import Mode


def test_end_to_end_logic_rl_sorted():
    """SFT + two RL groups on K&K through the real engine.  Asserts: the
    pipeline runs, importance ratios are ~1 for on-policy data, rewards
    are in range, and rollout accounting is consistent."""
    from repro.train.loop import RLExperimentConfig, run_logic_rl
    cfg = RLExperimentConfig(strategy="sorted", mode=Mode.ON_POLICY,
                             rollout_batch=8, group_size=2, update_batch=8,
                             n_groups=1, sft_steps=30, d_model=64, layers=2,
                             eval_size=16, eval_every=100)
    out = run_logic_rl(cfg)
    assert out["rollout_metrics"]["updates"] >= 2
    for h in out["history"]:
        assert 0.0 <= h["reward_mean"] <= 2.0
        assert abs(h["ratio_mean"] - 1.0) < 0.05      # on-policy
        assert np.isfinite(h["total_loss"])
    assert 0.0 <= out["rollout_metrics"]["bubble_ratio"] <= 1.0


def test_end_to_end_partial_mode_ratios():
    """Partial mode: resumed trajectories carry stitched pi_old; ratios on
    stale tokens deviate from 1 after updates but stay finite."""
    from repro.train.loop import RLExperimentConfig, run_logic_rl
    cfg = RLExperimentConfig(strategy="sorted", mode=Mode.PARTIAL,
                             rollout_batch=8, group_size=2, update_batch=8,
                             n_groups=1, sft_steps=30, d_model=64, layers=2,
                             eval_size=16, eval_every=100)
    out = run_logic_rl(cfg)
    assert out["rollout_metrics"]["tokens_discarded"] == 0
    for h in out["history"]:
        assert np.isfinite(h["ratio_mean"])


def test_launch_steps_structure_local_mesh():
    """build_train_step / build_serve_step produce consistent spec trees
    and run on a 1x1 mesh with the smoke config."""
    from repro.configs.base import ShapeConfig, get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.launch.plans import Plan
    from repro.launch.steps import build_serve_step, build_train_step
    from repro.train.optimizer import AdamWConfig, init_opt_state

    cfg = get_smoke_config("qwen3_0_6b").replace(param_dtype=jnp.float32,
                                                 compute_dtype=jnp.float32)
    mesh = make_local_mesh()
    plan = Plan(strategy="dp", fsdp=False, seq_parallel=False, remat=False)
    shape = ShapeConfig("t", 32, 4, "train")
    built = build_train_step(cfg, shape, plan, mesh, False)
    assert jax.tree.structure(built.in_specs[0]) == jax.tree.structure(
        built.in_shardings[0])
    step = jax.jit(built.fn, in_shardings=built.in_shardings,
                   out_shardings=built.out_shardings,
                   donate_argnums=built.donate_argnums)
    key = jax.random.PRNGKey(0)
    params = built.model.init_params(key)
    opt = init_opt_state(params, AdamWConfig())
    batch = {
        "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((4, 32), jnp.float32),
        "advantages": jax.random.normal(key, (4, 32)),
        "old_logprobs": -2.0 * jnp.ones((4, 32)),
    }
    params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))

    dshape = ShapeConfig("d", 64, 4, "decode")
    built_d = build_serve_step(cfg, dshape, plan, mesh, False)
    sstep = jax.jit(built_d.fn, in_shardings=built_d.in_shardings,
                    out_shardings=built_d.out_shardings,
                    donate_argnums=built_d.donate_argnums)
    cache = built_d.model.init_cache(4, 64 + 8)
    tok = jnp.zeros((4,), jnp.int32)
    kv = jnp.full((4,), 3, jnp.int32)
    nxt, lp, cache = sstep(params, tok, cache, kv)
    assert nxt.shape == (4,) and np.all(np.isfinite(np.asarray(lp)))


def test_param_specs_cover_all_archs():
    """Every arch's parameter tree gets a valid PartitionSpec (structure
    match + rank match + mesh-axis divisibility already enforced)."""
    from repro.configs.base import ARCH_IDS, get_config
    from repro.launch.plans import Plan, param_specs
    from repro.models.model import build_model
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        ps = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
        specs = param_specs(ps, cfg, Plan())
        assert jax.tree.structure(ps, is_leaf=lambda x: hasattr(x, "shape")) \
            == jax.tree.structure(specs,
                                  is_leaf=lambda s: hasattr(s, "index"))


def test_sim_vs_real_engine_same_controller():
    """The controller drives the simulator and the real engine through the
    identical protocol: same number of trained trajectories."""
    from repro.core.buffer import StatefulRolloutBuffer
    from repro.core.controller import SortedRLConfig, SortedRLController
    from repro.data import logic
    from repro.models.model import build_model
    from repro.rollout.engine import SlotEngine
    from repro.rollout.sim import SimEngine
    from repro.train.loop import tiny_lm_config

    vocab = logic.VOCAB
    model = build_model(tiny_lm_config(len(vocab), 64, 2, 2))
    params = model.init_params(jax.random.PRNGKey(0))
    prompts = [[vocab.bos_id, 7 + i % 5] for i in range(8)]
    counts = {}
    for name, eng in (
            ("sim", SimEngine(capacity=4, max_gen_len=8)),
            ("real", SlotEngine(model, lambda: params, capacity=4,
                                max_total_len=64, max_gen_len=8,
                                eos_id=vocab.eos_id, pad_id=vocab.pad_id))):
        buf = StatefulRolloutBuffer(Mode.ON_POLICY)
        cfg = SortedRLConfig(rollout_batch=4, group_size=2, update_batch=4,
                             max_gen_len=8)
        trained = []
        ctl = SortedRLController(eng, buf, cfg,
                                 lambda e, v: trained.extend(e))
        ctl.run_group([list(p) for p in prompts])
        counts[name] = len(trained)
    assert counts["sim"] == counts["real"] == 8


def test_plan_matrix_covers_all_40_pairs():
    """Every (arch x shape) pair is either planned or a documented skip —
    exactly the assigned 10x4 matrix."""
    from repro.configs.base import ARCH_IDS, SHAPES
    from repro.launch.plans import PLANS, SKIPS
    covered = 0
    for a in ARCH_IDS:
        for s in SHAPES:
            key = (a, s.name)
            assert (key in PLANS) != (key in SKIPS), key
            covered += 1
    assert covered == 40


def test_multi_response_grpo_loop():
    """Paper's 8-responses-per-prompt setting (reduced to 2) with GRPO
    group normalisation runs end-to-end."""
    from repro.train.loop import RLExperimentConfig, run_logic_rl
    cfg = RLExperimentConfig(strategy="sorted", mode=Mode.ON_POLICY,
                             rollout_batch=8, group_size=1, update_batch=8,
                             n_groups=1, sft_steps=20, d_model=64, layers=2,
                             eval_size=8, eval_every=100,
                             responses_per_prompt=2, advantage_kind="grpo")
    out = run_logic_rl(cfg)
    assert out["rollout_metrics"]["updates"] >= 1
    for h in out["history"]:
        assert np.isfinite(h["total_loss"])


def test_hlo_cost_inplace_dus_accounting():
    """The HBM-traffic model charges dynamic-update-slice for the update
    region, not the whole (donated, aliased) buffer — the decode-cache
    write must not look like a full-cache rewrite."""
    from repro.launch.hlo_cost import analyse_hlo

    def write_one(cache, val, idx):
        return jax.lax.dynamic_update_slice(cache, val, (idx, jnp.int32(0)))

    cache = jax.ShapeDtypeStruct((4096, 1024), jnp.float32)
    val = jax.ShapeDtypeStruct((1, 1024), jnp.float32)
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    txt = jax.jit(write_one, donate_argnums=(0,)).lower(
        cache, val, idx).compile().as_text()
    c = analyse_hlo(txt)
    # whole-buffer accounting would be ~32 MiB; region accounting ~8 KiB
    assert c["bytes"] < 1e6, c["bytes"]
