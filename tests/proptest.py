"""Tiny in-repo property-test helper — the repo's replacement for the
`hypothesis` dependency (tests must collect and run on a clean
interpreter with no external test packages).

Strategies are seeded-random value generators; `@cases` runs the wrapped
test once per drawn example.  Deliberately shrink-free: a failing case is
reported with its index and drawn values, which is enough to reproduce it
(the draw for case i depends only on (test name, _seed, i)).

Usage mirrors the hypothesis surface we used:

    @cases(max_examples=50,
           n=integers(1, 30),
           mode=sampled_from([Mode.ON_POLICY, Mode.PARTIAL]),
           schedule=lists(tuples(integers(0, 4), booleans()),
                          min_size=1, max_size=40))
    def test_something(n, mode, schedule): ...
"""
from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable, Sequence


class Strategy:
    """Wraps a draw function (random.Random -> value)."""

    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)


def integers(lo: int, hi: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(lo, hi))


def floats(lo: float, hi: float) -> Strategy:
    return Strategy(lambda rng: rng.uniform(lo, hi))


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(seq: Sequence) -> Strategy:
    pool = list(seq)
    return Strategy(lambda rng: pool[rng.randrange(len(pool))])


def lists(elem: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    return Strategy(lambda rng: [elem.example(rng)
                                 for _ in range(rng.randint(min_size,
                                                            max_size))])


def tuples(*elems: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(e.example(rng) for e in elems))


def cases(max_examples: int = 30, _seed: int = 0, **strategies: Strategy):
    """Run the test once per example, kwargs drawn from `strategies`.

    `_seed` varies the whole example stream; each case is independently
    seeded so a failure report identifies the exact reproducing draw.
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            for i in range(max_examples):
                # string seeding is deterministic across processes
                rng = random.Random(f"{fn.__name__}:{_seed}:{i}")
                drawn = {name: s.example(rng)
                         for name, s in strategies.items()}
                try:
                    fn(*args, **kw, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} case {i}/{max_examples} failed "
                        f"with {drawn!r}") from e

        # hide drawn params from pytest's fixture resolution
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[p for name, p in sig.parameters.items()
                        if name not in strategies])
        del wrapper.__wrapped__
        return wrapper
    return deco
