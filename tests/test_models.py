"""Per-architecture smoke tests (reduced configs, one forward + one train
step) and the decode-vs-forward exactness check across all 10 assigned
architectures (ragged prompts, left/right padding, ring caches, SSM state,
VLM patch stub, whisper cross-attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models.model import build_model

pytestmark = pytest.mark.slow   # jit-heavy: compiles all 10 architectures

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, m, B, S, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.num_stub_positions, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.num_stub_positions, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    m = build_model(cfg)
    params = m.init_params(KEY)
    B, S = 2, 32
    logits, aux = m.forward(params, _batch_for(cfg, m, B, S, KEY))
    S_out = S + (cfg.num_stub_positions if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert np.isfinite(float(aux["load_balance"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One RL update step on the reduced config: loss finite, params move."""
    from repro.rl.losses import LossConfig
    from repro.rl.trainer import make_train_step
    from repro.train.optimizer import AdamWConfig, init_opt_state

    cfg = get_smoke_config(arch).replace(param_dtype=jnp.float32,
                                         compute_dtype=jnp.float32)
    m = build_model(cfg)
    params = m.init_params(KEY)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(m, LossConfig(), opt_cfg))
    B, S = 2, 16
    batch = _batch_for(cfg, m, B, S, KEY)
    batch.update({
        "loss_mask": jnp.ones((B, S), jnp.float32).at[:, :4].set(0.0),
        "advantages": jax.random.normal(KEY, (B, S)),
        "old_logprobs": -jnp.ones((B, S)) * 2.0,
    })
    p2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["total_loss"]))
    diff = sum(float(jnp.abs(a - b).sum())
               for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert diff > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Prefill + teacher-forced decode logits == full forward logits,
    with ragged prompt lengths."""
    cfg = get_smoke_config(arch).replace(param_dtype=jnp.float32,
                                         compute_dtype=jnp.float32)
    m = build_model(cfg)
    params = m.init_params(KEY)
    B, S, G = 2, 12, 3
    toks = np.asarray(jax.random.randint(KEY, (B, S + G), 0, cfg.vocab_size))
    plens = np.array([S, S - 3])
    pt = np.zeros((B, S), np.int32)
    for b in range(B):
        if m.padding_side == "right":
            pt[b, :plens[b]] = toks[b, :plens[b]]
        else:
            pt[b, S - plens[b]:] = toks[b, :plens[b]]
    batch = _batch_for(cfg, m, B, S, KEY)
    batch["tokens"] = jnp.asarray(pt)
    batch["prompt_lens"] = jnp.asarray(plens)
    maxlen = S + G + 2 + m.prefill_extra
    cache = m.init_cache(B, maxlen)
    _, cache = m.prefill(params, batch, cache)
    if m.padding_side == "left":
        kv_len = jnp.array([S, S])
        kv_start = jnp.asarray(S - plens)
    else:
        kv_len = jnp.asarray(plens) + m.prefill_extra
        kv_start = None
    dec = []
    for t in range(G):
        nxt = jnp.array([toks[b, plens[b] + t] for b in range(B)])
        lg, cache = m.decode_step(params, nxt, cache, kv_len,
                                  kv_start=kv_start)
        dec.append(np.asarray(lg))
        kv_len = kv_len + 1
    off = cfg.num_stub_positions if cfg.family == "vlm" else 0
    for b in range(B):
        fb = dict(batch)
        fb["tokens"] = jnp.asarray(toks[b:b + 1, :plens[b] + G])
        for k in ("patch_embeds", "frames"):
            if k in fb:
                fb[k] = fb[k][b:b + 1]
        ref, _ = m.forward(params, fb)
        ref = np.asarray(ref)
        for t in range(G):
            want = ref[0, off + plens[b] + t]
            got = dec[t][b]
            err = np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-9)
            assert err < 2e-3, (arch, b, t, err)


def test_gemma2_ring_cache_wraparound():
    """Local-layer ring cache with prompt longer than the window: decode
    after wrap still matches full forward (window masking exact)."""
    cfg = get_smoke_config("gemma2_2b").replace(param_dtype=jnp.float32,
                                                compute_dtype=jnp.float32)
    W = cfg.attn.sliding_window            # 16 in the smoke config
    m = build_model(cfg)
    params = m.init_params(KEY)
    B, S, G = 1, W + 8, 3                  # prompt exceeds the window
    toks = np.asarray(jax.random.randint(KEY, (B, S + G), 0,
                                         cfg.vocab_size))
    batch = {"tokens": jnp.asarray(toks[:, :S]),
             "prompt_lens": jnp.full((B,), S, jnp.int32)}
    cache = m.init_cache(B, S + G + 2)
    _, cache = m.prefill(params, batch, cache)
    kv_len = jnp.full((B,), S, jnp.int32)
    outs = []
    for t in range(G):
        lg, cache = m.decode_step(params, jnp.asarray(toks[:, S + t]),
                                  cache, kv_len)
        outs.append(np.asarray(lg))
        kv_len = kv_len + 1
    ref, _ = m.forward(params, {"tokens": jnp.asarray(toks)})
    ref = np.asarray(ref)
    for t in range(G):
        want = ref[0, S + t]
        err = np.max(np.abs(outs[t][0] - want)) / (np.max(np.abs(want)) + 1e-9)
        assert err < 2e-3, (t, err)
