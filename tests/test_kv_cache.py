"""Host-side paged KV-cache bookkeeping (repro.core.kv_cache): page pool
refcounting, prefix sharing, copy-on-write planning, residency/eviction.
Pure numpy — no jax, no engine."""
import pytest

from repro.core.kv_cache import (GARBAGE_PAGE, PagedKVCache, PagePool,
                                 PoolExhausted)


def make_kv(num_pages=9, page_size=4, extra_rows=0):
    return PagedKVCache(num_pages, page_size, extra_rows=extra_rows)


# -- PagePool -----------------------------------------------------------------

def test_pool_alloc_release_cycle():
    pool = PagePool(num_pages=4, page_size=8)
    assert pool.free_pages() == 3 and pool.pages_in_use == 0
    a, b = pool.alloc(), pool.alloc()
    assert a != b and GARBAGE_PAGE not in (a, b)
    assert pool.pages_in_use == 2
    pool.retain(a)
    assert not pool.release(a)          # refcount 2 -> 1: not freed
    assert pool.release(a)              # 1 -> 0: freed
    assert pool.release(b)
    assert pool.free_pages() == 3 and pool.occupancy() == 0.0


def test_pool_exhaustion_raises():
    pool = PagePool(num_pages=3, page_size=8)
    pool.alloc(), pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()


def test_garbage_page_never_allocated_or_released():
    pool = PagePool(num_pages=3, page_size=8)
    assert pool.alloc() != GARBAGE_PAGE
    assert pool.alloc() != GARBAGE_PAGE
    with pytest.raises(AssertionError):
        pool.release(GARBAGE_PAGE)


# -- prefill / sharing --------------------------------------------------------

def test_register_prefill_allocates_by_rows():
    kv = make_kv(page_size=4)
    table = kv.register_prefill(0, tuple(range(10)))    # 10 rows -> 3 pages
    assert len(table) == 3
    assert kv.stats.prefill_tokens_run == 10
    kv.check_invariants()


def test_extra_rows_count_toward_pages():
    kv = make_kv(page_size=4, extra_rows=3)             # vlm stub rows
    table = kv.register_prefill(0, (1, 2))              # 2+3 rows -> 2 pages
    assert len(table) == 2 and kv.rows(0) == 5


def test_share_maps_donor_pages_and_refcounts():
    kv = make_kv(page_size=4)
    key = (1, 2, 3, 4, 5)
    t0 = kv.register_prefill(0, key)
    assert kv.find_donor(key) == 0
    kv.share(1, 0, key)
    assert kv.tables[1] == t0
    assert all(kv.pool.refcount[p] == 2 for p in t0)
    assert kv.stats.prefill_tokens_saved == 5
    kv.release_seq(0)
    assert all(kv.pool.refcount[p] == 1 for p in t0)    # shared pages live on
    kv.release_seq(1)
    assert kv.pool.pages_in_use == 0
    kv.check_invariants()


def test_donor_invalidated_after_release():
    kv = make_kv()
    key = (7, 8, 9)
    kv.register_prefill(0, key)
    kv.release_seq(0)
    assert kv.find_donor(key) is None


def test_in_batch_then_cross_batch_donor_chain():
    kv = make_kv(num_pages=17)
    key = (1, 1, 1, 1)
    kv.register_prefill(0, key)
    kv.share(1, 0, key)
    kv.release_seq(0)                    # follower keeps the pages alive
    donor = kv.find_donor(key)
    assert donor == 1                    # follower is registered as donor too
    kv.share(2, donor, key)
    kv.release_many([1, 2])
    assert kv.pool.pages_in_use == 0


# -- copy-on-write ------------------------------------------------------------

def test_prepare_step_cow_on_shared_write_page():
    kv = make_kv(page_size=4)
    key = (1, 2, 3, 4, 5, 6)             # 6 rows: page 0 full, page 1 partial
    kv.register_prefill(0, key)
    kv.share(1, 0, key)
    kv.share(2, 0, key)
    # all three write position 6 -> logical block 1 (the shared partial page)
    copies = kv.prepare_step([0, 1, 2], [6, 6, 6])
    assert len(copies) == 2 and kv.stats.cow_copies == 2
    pages = [kv.tables[u][1] for u in (0, 1, 2)]
    assert len(set(pages)) == 3          # exclusively owned now
    assert all(kv.pool.refcount[p] == 1 for p in pages)
    # the full prefix page stays shared
    assert kv.pool.refcount[kv.tables[0][0]] == 3
    kv.check_invariants()


def test_prepare_step_appends_fresh_page_at_boundary():
    kv = make_kv(page_size=4)
    kv.register_prefill(0, (1, 2, 3, 4))        # exactly one page
    copies = kv.prepare_step([0], [4])          # next write: new block
    assert copies == [] and len(kv.tables[0]) == 2
    kv.check_invariants()


def test_append_tokens_extends_committed_prefix():
    kv = make_kv()
    kv.register_prefill(0, (1, 2))
    kv.append_tokens([0], [3])
    assert kv.tokens[0] == [1, 2, 3] and kv.rows(0) == 3


# -- residency / resume / eviction -------------------------------------------

def test_resume_exact_and_trimmed():
    kv = make_kv(page_size=4)
    kv.register_prefill(0, (1, 2, 3, 4, 5, 6, 7))       # 2 pages
    kv.deactivate(0)
    # partial-mode resume: exact committed prefix
    assert kv.try_resume(0, (1, 2, 3, 4, 5, 6, 7))
    kv.deactivate(0)
    # on-policy re-roll: prompt prefix of the resident sequence -> trim
    assert kv.try_resume(0, (1, 2, 3))
    assert len(kv.tables[0]) == 1 and kv.tokens[0] == [1, 2, 3]
    assert kv.stats.resumed_without_prefill == 2
    kv.check_invariants()


def test_resume_mismatch_drops_stale_pages():
    kv = make_kv()
    kv.register_prefill(0, (1, 2, 3))
    kv.deactivate(0)
    assert not kv.try_resume(0, (9, 9, 9))
    assert 0 not in kv.tables and kv.pool.pages_in_use == 0


def test_eviction_is_lru_and_spares_active():
    kv = make_kv(num_pages=4, page_size=4)               # 3 usable pages
    kv.register_prefill(0, (1, 1, 1))
    kv.register_prefill(1, (2, 2, 2))
    kv.deactivate(0)
    kv.deactivate(1)
    kv.register_prefill(2, (3, 3, 3))                    # pool full
    kv.register_prefill(3, (4, 4, 4))                    # evicts uid 0 (LRU)
    assert kv.stats.evictions == 1
    assert 0 not in kv.tables and 1 in kv.tables
    kv.register_prefill(4, (5, 5, 5))                    # evicts uid 1
    assert 1 not in kv.tables
    # only active sequences remain -> nothing evictable -> exhausted
    with pytest.raises(PoolExhausted):
        kv.register_prefill(5, (6, 6, 6))


def test_shared_pages_survive_donor_eviction():
    kv = make_kv(num_pages=3, page_size=4)               # 2 usable pages
    kv.register_prefill(0, (1, 2, 3, 4, 5, 6))           # 2 pages: A, B
    kv.share(1, 0, (1, 2, 3, 4))                         # prefix page A only
    kv.deactivate(0)
    # pool is full; a new prefill evicts resident 0: its unshared page B
    # is freed, the shared page A survives with uid 1's reference
    kv.register_prefill(2, (7, 7, 7))
    assert kv.stats.evictions == 1
    assert 0 not in kv.tables
    assert kv.tokens[1] == [1, 2, 3, 4]
    assert kv.pool.refcount[kv.tables[1][0]] == 1
    kv.check_invariants()


# -- cross-pool migration -----------------------------------------------------

def test_export_is_pure_and_import_moves_span():
    src, dst = make_kv(page_size=4), make_kv(page_size=4)
    key = (1, 2, 3, 4, 5, 6)
    t0 = src.register_prefill(0, key)
    src.deactivate(0)
    ex = src.export_pages(0)
    assert ex.pages == t0 and ex.tokens == list(key) and not ex.active
    # export did not mutate the donor
    assert src.tables[0] == t0 and 0 in src._resident
    src.check_invariants()
    t1 = dst.import_pages(ex)
    assert len(t1) == len(t0)
    assert dst.tokens[0] == list(key)
    assert 0 in dst._resident and 0 not in dst._active
    assert dst.stats.migrated_pages == len(t1)
    # donor releases only after the importer accepted
    src.release_seq(0)
    assert src.pool.pages_in_use == 0
    # the migrated span resumes with zero re-prefill on the destination
    assert dst.try_resume(0, key)
    assert dst.stats.prefill_tokens_run == 0
    assert dst.stats.resumed_without_prefill == 1
    dst.release_seq(0)
    assert dst.pool.pages_in_use == 0
    src.check_invariants(), dst.check_invariants()


def test_import_active_entry_stays_active():
    src, dst = make_kv(), make_kv()
    src.register_prefill(7, (1, 2, 3))           # active (never deactivated)
    ex = src.export_pages(7)
    assert ex.active
    dst.import_pages(ex)
    assert 7 in dst._active and 7 not in dst._resident
    src.release_seq(7), dst.release_seq(7)


def test_import_rolls_back_on_exhausted_pool():
    src = make_kv(page_size=2)
    dst = PagedKVCache(num_pages=2, page_size=2)  # 1 usable page
    src.register_prefill(0, (1, 2, 3, 4, 5))      # 3 pages — cannot fit
    ex = src.export_pages(0)
    with pytest.raises(PoolExhausted):
        dst.import_pages(ex)
    assert dst.pool.pages_in_use == 0, "failed import leaked pages"
    assert 0 not in dst.tables
    # donor copy untouched: the caller can fall back to re-prefill
    assert src.tables[0] and src.tokens[0] == [1, 2, 3, 4, 5]
    src.check_invariants(), dst.check_invariants()


def test_imported_span_serves_as_prefix_donor():
    """Migration must carry the donor keys, not re-key on the committed
    sequence: a GRPO member that decoded past its prefill prefix still
    attracts its siblings' PROMPT key on the destination pool."""
    src, dst = make_kv(), make_kv()
    key = (9, 8, 7)
    src.register_prefill(0, key)
    src.append_tokens([0], [5])          # decode past the prefill prefix
    src.append_tokens([0], [4])
    src.deactivate(0)
    assert src.find_donor(key) == 0
    dst.import_pages(src.export_pages(0))
    src.release_seq(0)
    assert dst.find_donor(key) == 0, \
        "migrated entry stopped serving its prefill prefix"
    dst.share(1, 0, key)
    dst.release_many([0, 1])
    assert dst.pool.pages_in_use == 0
    dst.check_invariants()


# -- block tables -------------------------------------------------------------

def test_block_table_pads_with_garbage():
    kv = make_kv(page_size=4)
    t0 = kv.register_prefill(0, (1, 2, 3, 4, 5))         # 2 pages
    bt = kv.block_table([0, -1], n_blocks=4)
    assert bt.shape == (2, 4)
    assert list(bt[0, :2]) == t0
    assert (bt[0, 2:] == GARBAGE_PAGE).all()
    assert (bt[1] == GARBAGE_PAGE).all()                 # inactive slot
    assert kv.max_blocks([0]) == 2


# -- property tests: random interleavings (tests/proptest.py) -----------------
#
# The example-based cases above pin known-good sequences; these drive the
# pool and the cache through randomized op interleavings and assert the
# structural invariants the engine relies on at every step:
#   * refcounts never go negative and always equal the table references
#   * after every owner frees, zero pages remain in use (no leaks)
#   * the donor index never dangles (every entry points at a live table,
#     inverse map consistent, find_donor only returns covering donors)

from proptest import booleans, cases, integers, lists, tuples  # noqa: E402


def _donor_index_consistent(kv: PagedKVCache) -> None:
    for key, holders in kv._donors.items():
        assert holders, f"empty donor set left behind for {key}"
        for uid in holders:
            assert uid in kv.tables, f"donor {uid} dangling for {key}"
            assert key in kv._donor_keys[uid]
    for uid, keys in kv._donor_keys.items():
        assert uid in kv.tables
        for key in keys:
            assert uid in kv._donors.get(key, set())
        donor = kv.find_donor(next(iter(keys)))
        if donor is not None:
            key = next(iter(keys))
            assert kv.tokens[donor][:len(key)] == list(key)


@cases(max_examples=40,
       num_pages=integers(2, 12),
       page_size=integers(1, 4),
       ops=lists(tuples(integers(0, 1), integers(0, 3)),
                 min_size=1, max_size=60))
def test_pool_random_alloc_retain_release(num_pages, page_size, ops):
    pool = PagePool(num_pages, page_size)
    held = []                      # one list entry per outstanding reference
    for opcode, arg in ops:
        if opcode == 0:
            try:
                held.append(pool.alloc())
            except PoolExhausted:
                assert pool.free_pages() == 0
        elif held:
            i = arg % len(held)
            if arg % 2 == 0:
                held.append(pool.retain(held[i]))
            else:
                pool.release(held.pop(i))
        assert (pool.refcount >= 0).all()
        assert pool.pages_in_use + pool.free_pages() == num_pages - 1
    for page in held:
        pool.release(page)
    assert pool.pages_in_use == 0 and (pool.refcount == 0).all()


@cases(max_examples=50,
       num_pages=integers(3, 16),
       page_size=integers(1, 4),
       retain=booleans(),
       ops=lists(tuples(integers(0, 6), integers(0, 5), integers(0, 9)),
                 min_size=1, max_size=70))
def test_cache_random_interleavings_hold_invariants(num_pages, page_size,
                                                    retain, ops):
    kv = PagedKVCache(num_pages, page_size, retain_across_sync=retain)
    for opcode, uid, arg in ops:
        if opcode == 0 and uid not in kv.tables:        # fresh prefill
            key = tuple(uid * 101 + j for j in range(1 + arg))
            try:
                kv.register_prefill(uid, key)
            except PoolExhausted:
                pass                                    # oversubscribed
        elif opcode == 1 and uid not in kv.tables:      # prefix share
            keys = sorted(kv._donors)
            if keys:
                key = keys[arg % len(keys)]
                donor = kv.find_donor(key)
                if donor is not None:
                    kv.share(uid, donor, key)
        elif opcode == 2:                               # decode step (COW)
            active = sorted(kv._active)
            if active:
                u = active[arg % len(active)]
                try:
                    kv.prepare_step([u], [len(kv.tokens[u])])
                except PoolExhausted:
                    continue
                kv.append_tokens([u], [arg])
        elif opcode == 3:                               # interrupt
            active = sorted(kv._active)
            if active:
                kv.deactivate(active[arg % len(active)])
        elif opcode == 4:                               # resume a prefix
            resident = sorted(kv._resident)
            if resident:
                u = resident[arg % len(resident)]
                toks = kv.tokens[u]
                n = 1 + arg % max(1, len(toks))
                kv.try_resume(u, tuple(toks[:n]))
        elif opcode == 5:                               # finish
            if uid in kv.tables:
                kv.release_seq(uid)
        elif opcode == 6:                               # weight sync
            kv.sync_version(kv.version + 1)
        kv.check_invariants()
        assert (kv.pool.refcount >= 0).all()
        _donor_index_consistent(kv)
    kv.release_many(list(kv.tables))
    assert kv.pool.pages_in_use == 0, "pages leaked after all frees"
    assert (kv.pool.refcount == 0).all()
    assert not kv._donors and not kv._donor_keys, "donor index leaked"


@cases(max_examples=50,
       pages_a=integers(4, 14),
       pages_b=integers(4, 14),
       page_size=integers(1, 4),
       ops=lists(tuples(integers(0, 6), integers(0, 5), integers(0, 9)),
                 min_size=1, max_size=70))
def test_migration_random_interleavings_hold_invariants(pages_a, pages_b,
                                                        page_size, ops):
    """Random interleavings of prefill/share/COW/interrupt/MIGRATE across
    TWO pools: refcounts match the tables on both sides at every step,
    a failed import never half-lands a span, and after all frees both
    pools are empty (zero leaks on donor AND destination)."""
    pools = [PagedKVCache(pages_a, page_size),
             PagedKVCache(pages_b, page_size)]

    def other(side):
        return pools[1 - side]

    for opcode, uid, arg in ops:
        side = arg % 2
        kv = pools[side]
        if opcode == 0 and all(uid not in p.tables for p in pools):
            key = tuple(uid * 101 + j for j in range(1 + arg))
            try:
                kv.register_prefill(uid, key)
            except PoolExhausted:
                pass
        elif opcode == 1 and all(uid not in p.tables for p in pools):
            keys = sorted(kv._donors)
            if keys:
                key = keys[arg % len(keys)]
                donor = kv.find_donor(key)
                if donor is not None:
                    kv.share(uid, donor, key)
        elif opcode == 2:                               # decode step (COW)
            active = sorted(kv._active)
            if active:
                u = active[arg % len(active)]
                try:
                    kv.prepare_step([u], [len(kv.tokens[u])])
                except PoolExhausted:
                    continue
                kv.append_tokens([u], [arg])
        elif opcode == 3:                               # interrupt
            active = sorted(kv._active)
            if active:
                kv.deactivate(active[arg % len(active)])
        elif opcode == 4:                               # resume
            resident = sorted(kv._resident)
            if resident:
                u = resident[arg % len(resident)]
                toks = kv.tokens[u]
                n = 1 + arg % max(1, len(toks))
                kv.try_resume(u, tuple(toks[:n]))
        elif opcode == 5:                               # migrate -> other
            movable = sorted(kv.tables)
            if movable:
                u = movable[arg % len(movable)]
                ex = kv.export_pages(u)
                try:
                    other(side).import_pages(ex)
                except PoolExhausted:
                    pass                # donor copy survives the failure
                else:
                    kv.release_seq(u)   # accepted: donor lets go
        elif opcode == 6:                               # finish
            if uid in kv.tables:
                kv.release_seq(uid)
        for p in pools:
            p.check_invariants()
            assert (p.pool.refcount >= 0).all()
    for p in pools:
        p.release_many(list(p.tables))
        assert p.pool.pages_in_use == 0, "pages leaked after all frees"
        assert (p.pool.refcount == 0).all()
        assert not p._donors and not p._donor_keys, "donor index leaked"


@cases(max_examples=40,
       pages_a=integers(4, 14),
       pages_b=integers(4, 14),
       page_size=integers(1, 4),
       ops=lists(tuples(integers(0, 5), integers(0, 5), integers(0, 9)),
                 min_size=1, max_size=60))
def test_quantized_scale_planes_follow_pages(pages_a, pages_b, page_size,
                                             ops):
    """The int8 engine keeps a per-(layer, page) scale plane next to the
    page pool and applies three rules: COW copies the scale row with the
    page, decode writeback restamps the written page's scale, and
    export->import migrates scale rows alongside the physical pages.
    This drives random share/COW/interrupt/migrate interleavings with a
    host model of that plane (one stamp per page) and asserts every
    sequence always reads the stamps its prefix was written with — a
    missed COW copy or a migration that dropped scales shows up as a
    stale stamp under some reader's table."""
    pools = [PagedKVCache(pages_a, page_size),
             PagedKVCache(pages_b, page_size)]
    planes = [{}, {}]       # page -> stamp, per pool
    expected = {}           # uid -> (side, {position: stamp})
    fresh = iter(range(10**6))

    def check():
        for uid, (side, stamps) in expected.items():
            for pos, page in enumerate(pools[side].tables[uid]):
                if pos in stamps:
                    assert planes[side][page] == stamps[pos], \
                        (uid, pos, page)

    def prune():
        for uid in [u for u, (side, _) in expected.items()
                    if u not in pools[side].tables]:
            del expected[uid]       # evicted / dropped / resumed-trimmed

    for opcode, uid, arg in ops:
        side = arg % 2
        kv, plane = pools[side], planes[side]
        if opcode == 0 and all(uid not in p.tables for p in pools):
            key = tuple(uid * 101 + j for j in range(1 + arg))
            try:
                table = kv.register_prefill(uid, key)
            except PoolExhausted:
                continue
            stamps = {j: next(fresh) for j in range(len(table))}
            for j, page in enumerate(table):    # engine: _scatter_pages
                plane[page] = stamps[j]
            expected[uid] = (side, stamps)
        elif opcode == 1 and all(uid not in p.tables for p in pools):
            keys = sorted(kv._donors)
            if not keys:
                continue
            key = keys[arg % len(keys)]
            donor = kv.find_donor(key)
            if donor is not None and donor in expected:
                kv.share(uid, donor, key)       # no page writes, no stamps
                n = len(kv.tables[uid])
                dstamps = expected[donor][1]
                expected[uid] = (side, {j: dstamps[j] for j in range(n)
                                        if j in dstamps})
        elif opcode == 2:                       # decode step: COW + write
            active = sorted(kv._active)
            if not active:
                continue
            u = active[arg % len(active)]
            kv_len = len(kv.tokens[u])
            try:
                copies = kv.prepare_step([u], [kv_len])
            except PoolExhausted:
                continue
            for src, dst in copies:             # engine: _copy_pages
                plane[dst] = plane[src]
            j = kv_len // page_size             # engine: requant writeback
            if u in expected:
                # the decode gather dequantizes THIS step through the
                # post-COW table — every committed position (including a
                # just-copied write page) must carry its expected stamp
                for pos, page in enumerate(kv.tables[u]):
                    if pos in expected[u][1]:
                        assert plane.get(page) == expected[u][1][pos], \
                            (u, pos, page)
                stamp = next(fresh)
                plane[kv.tables[u][j]] = stamp
                expected[u][1][j] = stamp
            kv.append_tokens([u], [arg])
        elif opcode == 3:                       # interrupt
            active = sorted(kv._active)
            if active:
                kv.deactivate(active[arg % len(active)])
        elif opcode == 4:                       # migrate -> other pool
            movable = sorted(kv.tables)
            if not movable:
                continue
            u = movable[arg % len(movable)]
            ex = kv.export_pages(u)
            moved = [plane.get(p) for p in ex.pages]
            try:
                new_pages = pools[1 - side].import_pages(ex)
            except PoolExhausted:
                continue                        # donor copy intact
            for p, stamp in zip(new_pages, moved):
                if stamp is not None:           # engine: scales_k/v scatter
                    planes[1 - side][p] = stamp
            if u in expected:
                expected[u] = (1 - side, expected[u][1])
            kv.release_seq(u)
        elif opcode == 5 and uid in kv.tables:  # finish
            kv.release_seq(uid)
        prune()
        check()
        for p in pools:
            p.check_invariants()
    for p in pools:
        p.release_many(list(p.tables))
        assert p.pool.pages_in_use == 0


@cases(max_examples=20,
       num_pages=integers(3, 6),
       plen=integers(6, 30))
def test_failed_prefill_rolls_back_partial_allocation(num_pages, plen):
    """A register_prefill that exhausts the pool mid-allocation must not
    leak the pages it already grabbed."""
    kv = PagedKVCache(num_pages, page_size=2)
    key = tuple(range(plen))
    if kv._pages_for_rows(plen) <= num_pages - 1:
        kv.register_prefill(99, key)                    # fits: occupy + keep
        kv.check_invariants()
        return
    with pytest.raises(PoolExhausted):
        kv.register_prefill(99, key)
    assert 99 not in kv.tables
    assert kv.pool.pages_in_use == 0, "partial allocation leaked"
    kv.check_invariants()
