"""Unit tests for the streaming quantile helper and per-tenant metrics
(repro.core.metrics.ReservoirQuantile / TenantStat)."""
import random

import pytest

from repro.core.metrics import ReservoirQuantile, RolloutMetrics, TenantStat


# -- ReservoirQuantile --------------------------------------------------------

def test_empty_reservoir():
    r = ReservoirQuantile()
    assert r.count == 0
    assert r.mean == 0.0
    assert r.quantile(0.5) == 0.0
    assert r.summary()["max"] == 0.0


def test_exact_below_size():
    r = ReservoirQuantile(size=128)
    xs = list(range(100))
    for x in xs:
        r.add(x)
    assert r.count == 100
    assert r.min == 0 and r.max == 99
    assert r.mean == pytest.approx(49.5)
    # quantiles are exact (linear interpolation over the full data)
    assert r.quantile(0.0) == 0
    assert r.quantile(1.0) == 99
    assert r.quantile(0.5) == pytest.approx(49.5)


def test_bounded_memory_and_estimation():
    r = ReservoirQuantile(size=256, seed="t")
    rng = random.Random(1)
    for _ in range(20_000):
        r.add(rng.uniform(0, 100))
    assert len(r._items) == 256          # memory bound holds
    assert r.count == 20_000             # exact counters keep counting
    # a uniform[0,100] stream: the sampled median is near 50
    assert 35 < r.quantile(0.5) < 65


def test_deterministic_across_instances():
    def fill():
        r = ReservoirQuantile(size=64, seed="det")
        for i in range(5_000):
            r.add((i * 37) % 1000)
        return r
    a, b = fill(), fill()
    assert a._items == b._items
    assert a.summary() == b.summary()


def test_merge_exact_when_small():
    a = ReservoirQuantile(size=128)
    b = ReservoirQuantile(size=128)
    for i in range(40):
        a.add(i)
    for i in range(40, 80):
        b.add(i)
    a.merge(b)
    assert a.count == 80
    assert a.min == 0 and a.max == 79
    assert a.quantile(0.5) == pytest.approx(39.5)


def test_merge_stays_bounded():
    a = ReservoirQuantile(size=32, seed="m")
    b = ReservoirQuantile(size=32, seed="m2")
    for i in range(100):
        a.add(i)
        b.add(1000 + i)
    a.merge(b)
    assert len(a._items) <= 32
    assert a.count == 200
    assert a.max == 1099


def test_summary_shape():
    r = ReservoirQuantile()
    r.add(1.0)
    r.add(3.0)
    s = r.summary()
    assert set(s) == {"count", "mean", "p50", "p95", "p99", "max"}
    assert s["count"] == 2
    assert s["p50"] == pytest.approx(2.0)


# -- TenantStat / RolloutMetrics ---------------------------------------------

def test_tenant_get_or_create():
    m = RolloutMetrics(capacity=4)
    st = m.tenant("a")
    st.arrivals += 3
    assert m.tenant("a").arrivals == 3
    assert set(m.tenants) == {"a"}


def test_tenant_merge():
    x, y = TenantStat(), TenantStat()
    x.arrivals, x.completed = 5, 4
    y.arrivals, y.shed = 2, 1
    x.latency.add(1.0)
    y.latency.add(3.0)
    x.merge(y)
    assert x.arrivals == 7 and x.completed == 4 and x.shed == 1
    assert x.latency.count == 2
    assert x.latency.quantile(0.5) == pytest.approx(2.0)


def test_metrics_merge_folds_tenants():
    a = RolloutMetrics(capacity=4)
    b = RolloutMetrics(capacity=4)
    a.tenant("t1").tokens = 10
    b.tenant("t1").tokens = 5
    b.tenant("t2").tokens = 7
    a.merge(b)
    assert a.tenant("t1").tokens == 15
    assert a.tenant("t2").tokens == 7


def test_summary_omits_tenants_when_empty():
    m = RolloutMetrics(capacity=4)
    assert "tenants" not in m.summary()   # non-serving output is unchanged
    m.tenant("a").arrivals = 1
    s = m.summary()
    assert "tenants" in s and "a" in s["tenants"]


def test_tenant_summary_throughput():
    m = RolloutMetrics(capacity=4)
    m.record(running=4, dt=2.0, new_tokens=8)
    m.tenant("a").tokens = 8
    rec = m.tenant_summary()["a"]
    assert rec["throughput_tok_per_s"] == pytest.approx(4.0)


# -- MetricsSnapshot (the unified typed observability record) -----------------

def test_snapshot_mapping_surface():
    from repro.core.metrics import MetricsSnapshot
    s = MetricsSnapshot(source="x", values={"a": 1.0, "b": 2.0})
    assert s["a"] == 1.0
    assert s.get("b") == 2.0 and s.get("zzz", -1) == -1
    assert "a" in s and "zzz" not in s
    assert list(s) == ["a", "b"] and len(s) == 2
    assert dict(s) == {"a": 1.0, "b": 2.0}       # keys()-driven coercion
    d = {"pre": 0}
    d.update(s)                                  # legacy dict.update path
    assert d == {"pre": 0, "a": 1.0, "b": 2.0}
    assert bool(MetricsSnapshot(source="e")) is False
    assert bool(s) is True


def test_snapshot_to_dict_renders_children():
    from repro.core.metrics import MetricsSnapshot
    child = MetricsSnapshot(source="replica0", values={"tokens": 3.0})
    s = MetricsSnapshot(source="group", values={"n": 2.0},
                        children={"replicas": [child],
                                  "tenants": {"a": {"arrivals": 1}}})
    d = s.to_dict()
    assert d == {"n": 2.0, "replicas": [{"tokens": 3.0}],
                 "tenants": {"a": {"arrivals": 1}}}


def test_rollout_metrics_snapshot_matches_summary():
    m = RolloutMetrics(capacity=4)
    m.record(running=4, dt=2.0, new_tokens=8)
    m.update_time_total = 1.0
    m.update_time_stalled = 0.25
    m.batch_skipped = 3
    snap = m.snapshot()
    assert snap.source == "rollout"
    assert snap.to_dict() == m.summary()
    assert snap["batch_skipped"] == 3
    assert snap["update_overlap_frac"] == pytest.approx(0.75)
    assert m.snapshot(source="serving").source == "serving"


def test_overlap_frac_gauges():
    m = RolloutMetrics(capacity=4)
    assert m.update_overlap_frac == 0.0          # no updates yet
    m.update_time_total = 2.0
    m.update_time_stalled = 2.0
    assert m.update_overlap_frac == 0.0          # fully serialized
    m.update_time_stalled = 0.0
    assert m.update_overlap_frac == 1.0          # fully hidden
    m.record(running=4, dt=4.0)
    assert m.trainer_busy_frac == pytest.approx(0.5)


def test_merge_sums_overlap_counters():
    a, b = RolloutMetrics(capacity=4), RolloutMetrics(capacity=4)
    a.update_time_total, a.update_time_stalled, a.batch_skipped = 1.0, 0.5, 1
    b.update_time_total, b.update_time_stalled, b.batch_skipped = 3.0, 1.5, 2
    a.merge(b)
    assert a.update_time_total == 4.0
    assert a.update_time_stalled == 2.0
    assert a.batch_skipped == 3


def test_engine_group_emits_snapshots():
    from repro.core.metrics import MetricsSnapshot
    from repro.rollout.group import EngineGroup
    from repro.rollout.sim import SimEngine
    g = EngineGroup([SimEngine(capacity=2, max_gen_len=4, seed=i)
                     for i in range(2)])
    cs = g.cache_stats()
    assert isinstance(cs, MetricsSnapshot) and cs.source == "engine_group"
    rs = g.replica_stats()
    assert [r.source for r in rs] == ["replica0", "replica1"]
    # record_cache consumes the snapshot through the Mapping surface
    m = RolloutMetrics(capacity=4)
    m.record_cache(cs)
