"""RL math: advantages, clipped loss, token-logprob alignment, and the
stitched-pi_old importance-sampling mechanics of partial mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import cases, integers

from repro.rl import advantages as A
from repro.rl.losses import LossConfig, ppo_clip_loss, token_logprobs


def test_reinforce_pp_normalisation():
    r = jnp.array([1.0, 0.0, 2.0, 0.5])
    mask = jnp.ones((4, 8))
    adv = A.reinforce_pp(r, mask)
    per_traj = np.asarray(adv[:, 0])
    assert abs(per_traj.mean()) < 1e-6
    assert abs(per_traj.std() - 1.0) < 1e-5


def test_reinforce_pp_batch_composition_matters():
    """Selective batching (paper §3.1): the same trajectory gets a
    different advantage depending on which batch it lands in — the
    mechanism behind the micro-curriculum's effect on Reinforce++."""
    mask = jnp.ones((2, 4))
    in_easy = A.reinforce_pp(jnp.array([1.0, 0.0]), mask)
    in_hard = A.reinforce_pp(jnp.array([1.0, 2.0]), mask)
    assert float(in_easy[0, 0]) > 0 > float(in_hard[0, 0])


def test_grpo_groups():
    r = jnp.array([1.0, 0.0, 3.0, 1.0])
    gid = jnp.array([0, 0, 1, 1])
    adv = A.grpo(r, gid, jnp.ones((4, 2)), num_groups=2)
    assert float(adv[0, 0]) > 0 > float(adv[1, 0])
    assert float(adv[2, 0]) > 0 > float(adv[3, 0])


def test_gae_matches_manual():
    rewards = jnp.zeros((1, 4)).at[0, 3].set(1.0)
    values = jnp.zeros((1, 5))
    mask = jnp.ones((1, 4))
    adv = np.asarray(A.gae(rewards, values, mask, gamma=1.0, lam=0.5))
    # manual backward recursion
    want = np.zeros(4)
    carry = 0.0
    for t in reversed(range(4)):
        delta = (1.0 if t == 3 else 0.0)
        carry = delta + 0.5 * carry
        want[t] = carry
    np.testing.assert_allclose(adv[0], want, atol=1e-6)


def test_token_logprobs_alignment():
    """Entry t holds log p(token_t | <t) from logits at t-1."""
    V = 5
    logits = jnp.log(jnp.eye(V)[None, :4] + 1e-9)   # position t predicts t
    tokens = jnp.array([[0, 0, 1, 2]])
    lp = np.asarray(token_logprobs(logits, tokens))
    assert lp[0, 0] == 0.0                   # position 0 padded
    assert lp[0, 1] > -1e-3                  # logits[0] predicts token 0
    # recompute explicitly
    ref = jax.nn.log_softmax(logits, -1)
    for t in range(1, 4):
        np.testing.assert_allclose(lp[0, t],
                                   np.asarray(ref[0, t - 1, tokens[0, t]]),
                                   atol=1e-5)


def test_ppo_clip_on_policy_ratio_one():
    lp = jnp.full((2, 4), -1.5)
    adv = jnp.ones((2, 4))
    mask = jnp.ones((2, 4))
    loss, m = ppo_clip_loss(lp, lp, adv, mask, LossConfig())
    assert abs(float(m["ratio_mean"]) - 1.0) < 1e-6
    assert abs(float(loss) + 1.0) < 1e-6     # -mean(adv)


def test_clip_higher_asymmetry():
    """DAPO clip-higher: positive-advantage ratios clip at 1+eps_high,
    negative at 1-eps_low."""
    cfg = LossConfig(clip_eps_low=0.2, clip_eps_high=0.3)
    old = jnp.zeros((1, 1))
    adv = jnp.ones((1, 1))
    mask = jnp.ones((1, 1))
    # ratio 1.5 > 1.3 -> clipped objective 1.3
    loss_hi, _ = ppo_clip_loss(jnp.log(jnp.full((1, 1), 1.5)), old, adv,
                               mask, cfg)
    assert abs(float(loss_hi) + 1.3) < 1e-5
    # ratio 0.5 with adv -1: min(unclipped, clipped) = min(-.5, -.8) = -.8
    loss_lo, _ = ppo_clip_loss(jnp.log(jnp.full((1, 1), 0.5)), old, -adv,
                               mask, cfg)
    assert abs(float(loss_lo) - 0.8) < 1e-5


@cases(max_examples=20, seed=integers(0, 2**31 - 1))
def test_whiten_property(seed):
    key = jax.random.PRNGKey(seed)
    adv = jax.random.normal(key, (4, 8)) * 3 + 1
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (4, 8)) > 0.3
            ).astype(jnp.float32)
    if float(mask.sum()) < 2:
        return
    w = A.whiten(adv, mask)
    n = float(mask.sum())
    mu = float((w * mask).sum() / n)
    var = float((jnp.square(w - mu) * mask).sum() / n)
    assert abs(mu) < 1e-4
    assert abs(var - 1.0) < 1e-2


def test_staleness_vs_trainer_version():
    """Partial mode: staleness must be measured against the TRAINER's
    current version (threaded from the orchestrator), not the entry's own
    newest version — the latter under-reports it as ~0."""
    from repro.core.buffer import BufferEntry
    from repro.rl.trainer import entries_to_batch

    e = BufferEntry(uid=0, prompt=[1, 2], meta=None,
                    generated=[3, 4, 5], logprobs=[-0.5, -0.6, -0.1],
                    versions=[0, 0, 1])
    _, info = entries_to_batch([e], lambda g, m: 1.0, pad_id=0, max_len=32,
                               current_version=3)
    # mean over tokens of (3-0, 3-0, 3-1) = 8/3
    assert abs(info["staleness_mean"] - 8 / 3) < 1e-6
    assert abs(info["staleness_max"] - 8 / 3) < 1e-6
    # the old buggy reference point (own max version) under-reports: 2/3
    _, info0 = entries_to_batch([e], lambda g, m: 1.0, pad_id=0, max_len=32,
                                current_version=1)
    assert abs(info0["staleness_mean"] - 2 / 3) < 1e-6


def test_grpo_group_ids_dense():
    """Responses sharing a prompt_id form one group; unrelated prompts
    must never collide (the old modulo mapping folded prompt ids 0 and B
    into the same group)."""
    import types

    from repro.core.buffer import BufferEntry
    from repro.rl.trainer import entries_to_batch

    def entry(uid, pid, reward):
        meta = types.SimpleNamespace(prompt_id=pid, reward=reward)
        return BufferEntry(uid=uid, prompt=[1], meta=meta,
                           generated=[2, 3], logprobs=[-1.0, -1.0],
                           versions=[0, 0])

    # prompt ids 100 and 104 collide under the old `pid % (B//k)` = pid % 2
    entries = [entry(0, 100, 1.0), entry(1, 100, 0.0),
               entry(2, 104, 3.0), entry(3, 104, 1.0)]
    batch, _ = entries_to_batch(entries, lambda g, m: m.reward, pad_id=0,
                                max_len=16, advantage_kind="grpo")
    adv = np.asarray(batch["advantages"])
    # within each prompt group the higher-reward response gets adv > 0
    assert float(adv[0, 1]) > 0 > float(adv[1, 1])
    assert float(adv[2, 1]) > 0 > float(adv[3, 1])


def test_overlong_prompt_skipped_with_warning():
    """A prompt >= max_len leaves no room for generated tokens: it must be
    skipped with a warning rather than trained on an all-zero loss mask."""
    from repro.core.buffer import BufferEntry
    from repro.rl.trainer import entries_to_batch

    ok = BufferEntry(uid=0, prompt=[1, 2], meta=None, generated=[3, 4],
                     logprobs=[-1.0, -1.0], versions=[0, 0])
    overlong = BufferEntry(uid=1, prompt=[1] * 40, meta=None, generated=[3],
                           logprobs=[-1.0], versions=[0])
    with pytest.warns(UserWarning, match="skipping 1"):
        batch, info = entries_to_batch([ok, overlong], lambda g, m: 1.0,
                                       pad_id=0, max_len=32)
    assert batch["tokens"].shape[0] == 1
    assert info["entries_skipped"] == 1
    assert float(np.asarray(batch["loss_mask"]).sum()) > 0
    with pytest.raises(ValueError, match="all .* entries were skipped"):
        entries_to_batch([overlong], lambda g, m: 1.0, pad_id=0, max_len=32)


def test_stitched_pi_old_importance_sampling():
    """Partial mode: a trajectory generated across two policy versions
    carries per-token behaviour logprobs; the trainer's ratio uses them
    exactly (paper §3.2 Eq. 1)."""
    from repro.core.buffer import BufferEntry
    from repro.rl.trainer import entries_to_batch

    e = BufferEntry(uid=0, prompt=[1, 2], meta=None,
                    generated=[3, 4, 5], logprobs=[-0.5, -0.6, -0.1],
                    versions=[0, 0, 1])
    batch, _ = entries_to_batch([e], lambda g, m: 1.0, pad_id=0, max_len=32)
    old = np.asarray(batch["old_logprobs"][0])
    mask = np.asarray(batch["loss_mask"][0])
    assert mask[:2].sum() == 0 and mask[2:5].sum() == 3
    np.testing.assert_allclose(old[2:5], [-0.5, -0.6, -0.1])
