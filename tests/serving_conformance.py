"""Serving-conformance suite: the executable contract of the always-on
serving tier (repro.serve).

Every (admission policy x scheduler policy) pair is driven through the
:class:`ServingOrchestrator` against both engine backends (SimEngine's
virtual clock and the real-decode SlotEngine under ``tick`` time) and a
``num_replicas in {1, 2, 4}`` EngineGroup sweep, so a new admission
registry entry inherits the whole contract:

  * per-tenant conservation — at teardown every tenant satisfies
    ``arrivals == completed + shed`` and ``admitted == completed ==
    consumed``; nothing is lost, duplicated, or silently dropped;
  * continuous-batching invariants — the buffer never advances a group
    epoch, ends empty, and the engine ends drained, on an unbounded
    arrival stream with no epoch boundary;
  * determinism — two same-seed runs produce byte-identical per-tenant
    event logs (all time comes from the simulated clock);
  * no-starvation under ``weighted_fair`` and deadline-honouring under
    ``slo_aware``, both as unit tests on the admission controllers and
    as end-to-end comparisons on a shared recorded trace;
  * fault composition — kill/stall plans (including horizon-free random
    plans) compose with the unbounded serving loop without losing
    conservation.

Any new admission policy must pass this file UNCHANGED (same bar as
``policy_conformance`` for scheduling policies).
"""
import pytest

from policy_conformance import CAPACITY, ENGINE_FACTORIES, MAX_GEN
from proptest import cases, integers, sampled_from
from repro.core.buffer import Mode, StatefulRolloutBuffer
from repro.core.engine_api import FaultEvent, FaultInjector
from repro.core.orchestrator import SortedRLConfig, UpdateRequest
from repro.core.policy import available_policies, make_policy
from repro.rollout.group import EngineGroup
from repro.rollout.sim import SimEngine, lognormal_lengths
from repro.serve import (BurstyArrivals, Ingress, PoissonArrivals,
                         QueuedRequest, ServingOrchestrator, ServingPolicy,
                         TenantQueue, TenantSpec, TraceArrivals,
                         available_admissions, make_admission, record_trace)

N_ARRIVALS = 16
SEED = 7

# the shared 2-tenant contract workload: a weighted batch tenant and a
# latency-sensitive interactive tenant with an SLO
TENANTS = (TenantSpec("batch", weight=1.0),
           TenantSpec("interactive", weight=4.0, latency_slo=2.0))
RATES = {"batch": 40.0, "interactive": 20.0}

# the full (admission x scheduler) cube runs on these: both engine
# backends plus the num_replicas {1, 2, 4} sweep (policy_conformance's
# factories, so the serving tier is tested on the exact same fleets)
MATRIX_ENGINES = ("sim", "slot", "group1_sim", "group2_sim", "group4_sim")
# PR-5 tail machinery + real-decode replicas: swept against every
# admission policy with the default scheduler
TAIL_ENGINES = ("group4_sim_async", "group2_sim_pack", "group2_slot")

# every registered scheduling policy composes with every admission
# policy ("serving" itself excluded: wrapping the wrapper is a no-op)
INNER_POLICIES = tuple(n for n in available_policies() if n != "serving")


def vocab_prompts(rng, tenant):
    # valid tiny-model vocab (the slot engines decode these for real)
    return [1, 1, 1, 2 + rng.randrange(5)]


def build(admission, inner, engine_name, tenants=TENANTS, process=None,
          seed=SEED):
    eng = ENGINE_FACTORIES[engine_name]()
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=CAPACITY,
                         group_size=1, update_batch=CAPACITY,
                         max_gen_len=MAX_GEN)
    if process is None:
        process = PoissonArrivals(RATES, seed=seed,
                                  prompt_sampler=vocab_prompts)
    ingress = Ingress(tenants, process)
    policy = ServingPolicy(inner=inner, admission=admission, ingress=ingress)
    batches = []

    def train_fn(req: UpdateRequest):
        batches.append((list(req.entries), req.group_epoch))

    # wall-clock engines get a fixed-tick serving clock; virtual-clock
    # engines serve on the engine clock itself
    tick = 0.05 if "slot" in engine_name else None
    orch = ServingOrchestrator(eng, buf, cfg, policy, train_fn, tick=tick)
    return orch, batches


_DRIVE_CACHE = {}


def drive(admission, inner, engine_name, n_arrivals=N_ARRIVALS):
    """Serve `n_arrivals` arrival events to completion (memoized — the
    run is deterministic and the invariant tests only read)."""
    key = (admission, inner, engine_name, n_arrivals)
    if key not in _DRIVE_CACHE:
        orch, batches = build(admission, inner, engine_name)
        orch.run_for(n_arrivals=n_arrivals)
        _DRIVE_CACHE[key] = (orch, batches)
    return _DRIVE_CACHE[key]


@pytest.fixture(params=sorted(available_admissions()))
def admission_name(request):
    return request.param


@pytest.fixture(params=INNER_POLICIES)
def inner_name(request):
    return request.param


@pytest.fixture(params=MATRIX_ENGINES)
def engine_name(request):
    return request.param


# -- registry surface ---------------------------------------------------------

def test_admission_registry_contract():
    names = available_admissions()
    for required in ("fifo", "weighted_fair", "slo_aware"):
        assert required in names
    for name in names:
        a = make_admission(name)
        assert callable(getattr(a, "select", None))
    with pytest.raises(KeyError):
        make_admission("no_such_admission")
    # the serving policy is a first-class registry citizen
    assert "serving" in available_policies()
    assert make_policy("serving").name == "serving"


# -- the contract: every (admission x scheduler) pair, every fleet ------------

def _assert_conserved(orch, batches):
    ing = orch.ingress
    total_completed = 0
    for name in ing.specs:
        st = orch.metrics.tenants.get(name)
        q = ing.queues[name]
        assert len(q) == 0, f"tenant {name}: requests left queued"
        if st is None:
            continue        # tenant saw no arrivals in this window
        assert st.arrivals == st.completed + st.shed, \
            f"tenant {name}: lost requests " \
            f"({st.arrivals} != {st.completed} + {st.shed})"
        assert st.admitted == st.completed == st.consumed, \
            f"tenant {name}: admitted/completed/consumed diverge"
        assert q.admitted == st.admitted
        total_completed += st.completed
    # event-log balance: the authoritative ingress log tells the same story
    kinds = {}
    for _, kind, _, _ in ing.events:
        kinds[kind] = kinds.get(kind, 0) + 1
    assert kinds.get("arrive", 0) == kinds.get("admit", 0) + kinds.get("shed", 0)
    assert kinds.get("done", 0) == kinds.get("admit", 0)
    # trained exactly once
    uids = [e.uid for b, _ in batches for e in b]
    assert len(uids) == len(set(uids)), "an entry trained twice"
    if orch.metrics.updates_gated == 0:
        assert len(uids) == total_completed


def _assert_continuous(orch):
    assert orch.buffer.group_epoch == 0, \
        "continuous batching must never advance a group epoch"
    assert not orch.buffer.entries, "buffer must end empty (bounded memory)"
    orch.buffer.check_invariants()
    assert orch.engine.free_slots() == orch.engine.capacity
    assert orch.ingress.drained()


def test_tenant_conservation(admission_name, inner_name, engine_name):
    orch, batches = drive(admission_name, inner_name, engine_name)
    _assert_conserved(orch, batches)


def test_continuous_batching_invariants(admission_name, inner_name,
                                        engine_name):
    orch, _ = drive(admission_name, inner_name, engine_name)
    _assert_continuous(orch)


def test_curriculum_composes(admission_name, inner_name, engine_name):
    # admission controls WHO enters; training order stays the wrapped
    # scheduler's contract
    orch, batches = drive(admission_name, inner_name, engine_name)
    policy = orch.policy
    if not policy.ordered_training:
        return
    for b, _ in batches:
        keys = [policy.train_order_key(e) for e in b]
        assert keys == sorted(keys), \
            f"batch not monotone in train_order_key: {keys}"


def test_tail_machinery(admission_name):
    # async stepping, drain-phase packing, migration, real-decode replicas
    for engine_name in TAIL_ENGINES:
        orch, batches = drive(admission_name, "sorted", engine_name)
        _assert_conserved(orch, batches)
        _assert_continuous(orch)


# -- determinism (all time from the simulated clock + seed) -------------------

@pytest.mark.parametrize("engine_name2", ["sim", "group2_sim", "slot"])
def test_same_seed_identical_event_logs(engine_name2):
    def run():
        orch, _ = build("weighted_fair", "sorted", engine_name2)
        orch.run_for(n_arrivals=N_ARRIVALS)
        return orch
    a, b = run(), run()
    assert a.ingress.events == b.ingress.events, \
        "same-seed runs must produce identical per-tenant event logs"
    # scheduling state is fully deterministic; only wall-clock-derived
    # rates (throughput, bubble attribution) may differ on a real engine
    def scrub(summary):
        return {t: {k: v for k, v in rec.items()
                    if k not in ("throughput_tok_per_s", "bubble_time")}
                for t, rec in summary.items()}
    assert scrub(a.metrics.tenant_summary()) \
        == scrub(b.metrics.tenant_summary())


def test_trace_replay_identity():
    # a recorded trace replays to the exact same serving run
    proc = PoissonArrivals(RATES, seed=SEED, prompt_sampler=vocab_prompts)
    trace = record_trace(proc, N_ARRIVALS)
    live, _ = build("fifo", "sorted", "sim")
    live.run_for(n_arrivals=N_ARRIVALS)
    replay, _ = build("fifo", "sorted", "sim",
                      process=TraceArrivals(trace))
    replay.run_for(n_arrivals=N_ARRIVALS)
    assert live.ingress.events == replay.ingress.events


def test_record_trace_roundtrip():
    proc = PoissonArrivals(RATES, seed=3)
    trace = record_trace(proc, 10)
    assert len(trace) == 10
    again = record_trace(TraceArrivals(trace), 10)
    assert again == sorted(trace)       # replay is time-ordered


# -- admission-controller unit contracts --------------------------------------

def _backlogged(spec, n, t0=0.0, dt=0.01, seq0=0):
    q = TenantQueue(spec)
    for i in range(n):
        q.offer(QueuedRequest(seq=seq0 + i, tenant=spec.name, prompt=[1],
                              t_arrival=t0 + i * dt,
                              deadline=(t0 + i * dt + spec.latency_slo
                                        if spec.latency_slo else None)),
                now=t0 + i * dt)
    return q


def test_fifo_is_global_arrival_order():
    qs = {"a": _backlogged(TenantSpec("a"), 3, t0=0.0, seq0=0),
          "b": _backlogged(TenantSpec("b"), 3, t0=0.005, seq0=100)}
    picked = make_admission("fifo").select(qs, 6, now=1.0)
    assert [p.t_arrival for p in picked] == sorted(p.t_arrival for p in picked)


def test_weighted_fair_proportional_shares():
    # deficit round robin: long-run admission shares match the weights
    qs = {"a": _backlogged(TenantSpec("a", weight=3.0), 40, seq0=0),
          "b": _backlogged(TenantSpec("b", weight=1.0), 40, seq0=100)}
    picked = make_admission("weighted_fair").select(qs, 16, now=1.0)
    by = {"a": 0, "b": 0}
    for p in picked:
        by[p.tenant] += 1
    assert by == {"a": 12, "b": 4}


def test_weighted_fair_never_starves():
    # fractional weight: the light tenant banks credit every visit and is
    # admitted within ceil(1/weight) rounds — bounded starvation
    qs = {"heavy": _backlogged(TenantSpec("heavy", weight=8.0), 500, seq0=0),
          "light": _backlogged(TenantSpec("light", weight=0.25), 500,
                               seq0=10_000)}
    adm = make_admission("weighted_fair")
    first_light = None
    light = 0
    for call in range(64):
        for p in adm.select(qs, 1, now=1.0):
            if p.tenant == "light":
                light += 1
                if first_light is None:
                    first_light = call
    assert light >= 1, "weighted_fair starved the light tenant"
    assert first_light is not None and first_light <= 8
    assert light < 64 - light, "weights were ignored"


def test_slo_aware_is_deadline_order():
    specs = {"fast": TenantSpec("fast", latency_slo=1.0),
             "slow": TenantSpec("slow", latency_slo=5.0),
             "none": TenantSpec("none")}
    # "none" arrived FIRST — fifo would pick it; EDF must not
    qs = {"none": _backlogged(specs["none"], 2, t0=0.0, seq0=200),
          "slow": _backlogged(specs["slow"], 2, t0=0.1, seq0=100),
          "fast": _backlogged(specs["fast"], 2, t0=0.2, seq0=0)}
    picked = make_admission("slo_aware").select(qs, 6, now=1.0)
    assert [p.tenant for p in picked] == ["fast", "fast", "slow", "slow",
                                         "none", "none"]
    fifo = make_admission("fifo").select(
        {"none": _backlogged(specs["none"], 1, t0=0.0),
         "fast": _backlogged(specs["fast"], 1, t0=0.2)}, 1, now=1.0)
    assert fifo[0].tenant == "none"


# -- end-to-end policy comparisons on a shared recorded trace -----------------

SLO_TENANTS = (TenantSpec("batch", weight=1.0, queue_capacity=256),
               TenantSpec("interactive", weight=8.0, latency_slo=0.5,
                          queue_capacity=256))


def _slo_trace(n=120, seed=11):
    # a batch tenant flooding in bursts over a low-rate interactive tenant
    proc = BurstyArrivals({"batch": 300.0, "interactive": 10.0}, seed=seed,
                          on_time=0.3, off_time=0.7)
    return record_trace(proc, n)


def _replay(admission, trace, tenants=SLO_TENANTS):
    orch, _ = build(admission, "sorted", "sim", tenants=tenants,
                    process=TraceArrivals(trace))
    orch.run_for(n_arrivals=len(trace))
    return orch.metrics.tenant_summary()


def test_slo_admission_honors_deadlines_end_to_end():
    """On the IDENTICAL bursty trace, slo_aware keeps the interactive
    tenant's tail latency strictly below fifo's (the deadline-blind
    baseline makes interactive wait behind the batch flood)."""
    trace = _slo_trace()
    fifo = _replay("fifo", trace)
    slo = _replay("slo_aware", trace)
    # same workload on both sides
    assert fifo["interactive"]["arrivals"] == slo["interactive"]["arrivals"]
    assert slo["interactive"]["latency"]["p99"] \
        < fifo["interactive"]["latency"]["p99"]
    assert slo["interactive"]["slo_misses"] <= fifo["interactive"]["slo_misses"]


def test_weighted_fair_no_starvation_end_to_end():
    # the weighted tenant's queueing delay drops vs the tenant-blind
    # baseline when a heavy tenant floods
    trace = _slo_trace()
    fifo = _replay("fifo", trace)
    wf = _replay("weighted_fair", trace)
    assert wf["interactive"]["queue_wait"]["p95"] \
        < fifo["interactive"]["queue_wait"]["p95"]
    # and the batch tenant still progresses (no lockout)
    assert wf["batch"]["completed"] == wf["batch"]["arrivals"] \
        - wf["batch"]["shed"]


# -- faults: plans compose with the unbounded serving loop --------------------

def _fleet(fault_injector=None, capacity_each=2, seeds=(0, 1)):
    return EngineGroup(
        [SimEngine(capacity=capacity_each, max_gen_len=MAX_GEN, seed=s,
                   kv_residency=True,
                   length_sampler=lognormal_lengths(median=3, sigma=0.8,
                                                    max_len=MAX_GEN))
         for s in seeds],
        migrate_kv=True, fault_injector=fault_injector)


def _serve_fleet(eng, admission="fifo", tenants=TENANTS, process=None,
                 n_arrivals=N_ARRIVALS, seed=SEED):
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=CAPACITY,
                         group_size=1, update_batch=CAPACITY,
                         max_gen_len=MAX_GEN)
    if process is None:
        process = PoissonArrivals(RATES, seed=seed,
                                  prompt_sampler=vocab_prompts)
    ingress = Ingress(tenants, process)
    policy = ServingPolicy(inner="sorted", admission=admission,
                           ingress=ingress)
    batches = []
    orch = ServingOrchestrator(eng, buf, cfg, policy,
                               lambda req: batches.append(req), tick=None)
    orch.run_for(n_arrivals=n_arrivals)
    return orch, batches


@pytest.mark.chaos
def test_kill_mid_stream_conserves():
    """A replica killed mid-stream with tenants in flight: the survivors
    absorb the re-homed work and every tenant still conserves."""
    eng = _fleet(FaultInjector([FaultEvent(step=3, replica=0, kind="kill")]))
    orch, batches = _serve_fleet(eng)
    assert orch.metrics.replica_deaths == 1
    trained = [e.uid for req in batches for e in req.entries]
    assert len(trained) == len(set(trained))
    _assert_conserved(orch, [(req.entries, req.group_epoch)
                             for req in batches])
    _assert_continuous(orch)


@pytest.mark.chaos
def test_fault_plan_without_horizon():
    # horizon-free plans: steps have unbounded support, same seed gives
    # the same plan, and due() beyond any step is a cheap no-op
    a = FaultInjector.random_plan(seed=7, n_replicas=2, horizon=None,
                                  n_faults=3)
    b = FaultInjector.random_plan(seed=7, n_replicas=2, horizon=None,
                                  n_faults=3)
    assert [(f.step, f.replica, f.kind) for f in a.plan] \
        == [(f.step, f.replica, f.kind) for f in b.plan]
    assert all(f.step >= 1 for f in a.plan)
    assert a.due(10 ** 9) == []
    c = FaultInjector.random_plan(seed=8, n_replicas=2, horizon=None,
                                  n_faults=3)
    assert [(f.step, f.replica) for f in c.plan] \
        != [(f.step, f.replica) for f in a.plan]


@pytest.mark.chaos
def test_stall_plan_composes_with_serving():
    # a stalled replica parks mid-stream, resumes, and the loop neither
    # wedges nor loses work — no horizon anywhere
    eng = _fleet(FaultInjector([FaultEvent(step=2, replica=0, kind="stall",
                                           duration=3),
                                FaultEvent(step=9, replica=1, kind="stall",
                                           duration=2)]))
    orch, batches = _serve_fleet(eng)
    _assert_conserved(orch, [(req.entries, req.group_epoch)
                             for req in batches])
    _assert_continuous(orch)


# -- proptest: random interleavings on a 2-tenant, 2-replica fleet ------------

@pytest.mark.chaos
@cases(max_examples=15, _seed=5,
       seed=integers(0, 10_000),
       admission=sampled_from(["fifo", "weighted_fair", "slo_aware"]),
       cap=integers(1, 6),
       n_arr=integers(5, 40),
       rate_limit=sampled_from([None, 3.0, 15.0]),
       fault_kind=sampled_from([None, "kill", "stall"]),
       fault_step=integers(1, 30))
def test_random_interleavings_conserve(seed, admission, cap, n_arr,
                                       rate_limit, fault_kind, fault_step):
    """Random arrivals x admission x bounded queues x rate limits x
    faults: per-tenant conservation, bounded queue depth, and zero leaks
    at teardown.  Faults target replica 0 only, so the fleet always
    retains capacity and the stream must fully drain."""
    tenants = (TenantSpec("a", weight=2.0, queue_capacity=cap,
                          rate_limit=rate_limit),
               TenantSpec("b", weight=1.0, latency_slo=1.0,
                          queue_capacity=cap))
    inj = None
    if fault_kind is not None:
        inj = FaultInjector([FaultEvent(step=fault_step, replica=0,
                                        kind=fault_kind, duration=2)])
    eng = _fleet(inj, seeds=(seed % 100, seed % 100 + 1))
    process = PoissonArrivals({"a": 30.0, "b": 10.0}, seed=seed,
                              prompt_sampler=vocab_prompts)
    orch, batches = _serve_fleet(eng, admission=admission, tenants=tenants,
                                 process=process, n_arrivals=n_arr)
    ing = orch.ingress
    for name in ("a", "b"):
        st = orch.metrics.tenants.get(name)
        q = ing.queues[name]
        assert q.depth_peak <= cap, "bounded queue exceeded its capacity"
        assert len(q) == 0
        if st is not None:
            assert st.arrivals == st.completed + st.shed
            assert st.admitted == st.completed == st.consumed
    assert not orch.buffer.entries, "leaked buffer entries at teardown"
    assert not orch.engine.active_uids(), "leaked engine slots at teardown"
    uids = [e.uid for req in batches for e in req.entries]
    assert len(uids) == len(set(uids))
