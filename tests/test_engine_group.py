"""EngineGroup unit + regression tests beyond the shared conformance
suite: balancer registry behaviour, greedy token-identity vs the single
engine, replica metrics flowing through the orchestrator, and the
session-level num_replicas wiring."""
import pytest

from engine_conformance import _tiny_model, make_group_sim
from repro.core.buffer import BufferEntry, Mode, StatefulRolloutBuffer
from repro.core.orchestrator import RolloutOrchestrator, SortedRLConfig
from repro.core.policy import make_policy
from repro.rollout.group import (EngineGroup, available_balancers,
                                 make_balancer)
from repro.rollout.sim import SimEngine


def _greedy_slot(capacity):
    from repro.rollout.engine import SlotEngine
    t = _tiny_model()
    return SlotEngine(t["model"], lambda: t["params"], capacity=capacity,
                      max_total_len=64, max_gen_len=8, eos_id=-1,
                      pad_id=t["pad"], temperature=0.0)


def _drain_tokens(eng, entries):
    toks = {e.uid: [] for e in entries}
    eng.submit(entries, version=0)
    steps = 0
    while eng.active_uids():
        for ev in eng.step():
            toks[ev.uid].append(ev.token)
        steps += 1
        assert steps < 1000
    return toks


def _prompts(n):
    return [[1, 2 + i % 5, 3, 4 + (i * 7) % 11] for i in range(n)]


# -- balancer registry --------------------------------------------------------

def test_balancer_registry_surface():
    names = available_balancers()
    for required in ("least_tokens", "least_loaded", "round_robin"):
        assert required in names
    with pytest.raises(KeyError):
        make_balancer("no_such_balancer")


def test_least_tokens_routes_away_from_heavy_replica():
    """The length-aware default sends fresh work to the replica with the
    least estimated outstanding tokens, not just the most free slots."""
    eng = make_group_sim()
    # occupy replica 0 with one entry: its est load is now positive
    eng.submit([BufferEntry(uid=0, prompt=[1, 2, 3])], version=0)
    assert dict(eng._home)[0] == 0
    eng.submit([BufferEntry(uid=1, prompt=[4, 5, 6])], version=0)
    assert dict(eng._home)[1] == 1, "fresh entry must avoid the loaded replica"


def test_round_robin_cycles_replicas():
    eng = make_group_sim(capacity=4, n_replicas=2)
    eng.balancer = make_balancer("round_robin")
    # fully distinct prefill prefixes, or prefix co-location would
    # (correctly) override the balancer and keep the group together
    es = [BufferEntry(uid=i, prompt=[7 + i, 8, 9]) for i in range(4)]
    eng.submit(es, version=0)
    assert [dict(eng._home)[i] for i in range(4)] == [0, 1, 0, 1]


def test_length_hint_override_drives_routing():
    """A caller-supplied length hint is honoured: the replica already
    carrying the 'long' entry is avoided even when slot counts tie."""
    hints = {0: 1000.0, 1: 1.0, 2: 1.0}
    eng = make_group_sim(capacity=4, n_replicas=2)
    eng.length_hint = lambda e: hints[e.uid]
    eng.submit([BufferEntry(uid=0, prompt=[1, 2])], version=0)   # r0: 1000
    eng.submit([BufferEntry(uid=1, prompt=[3, 4])], version=0)   # r1: light
    eng.submit([BufferEntry(uid=2, prompt=[5, 6])], version=0)   # r1 again
    homes = dict(eng._home)
    assert homes[0] == 0 and homes[1] == 1 and homes[2] == 1


def test_empty_prefill_key_does_not_co_route():
    """Single-token prompts all share the empty prefill prefix, which the
    page cache never shares — they must spread by the balancer instead of
    piling onto one replica."""
    eng = make_group_sim(capacity=4, n_replicas=2)
    eng.submit([BufferEntry(uid=i, prompt=[5 + i]) for i in range(4)],
               version=0)
    homes = [dict(eng._home)[i] for i in range(4)]
    assert sorted(homes) == [0, 0, 1, 1], homes


# -- token identity -----------------------------------------------------------

def test_group_greedy_token_identical_to_single_engine():
    """Pinned: greedy decode through EngineGroup(n=4) is token-identical
    per uid to the single SlotEngine on the same prompts — sharding the
    rollout must not change any trajectory."""
    prompts = _prompts(8)
    single = _greedy_slot(capacity=8)
    base = _drain_tokens(single, [BufferEntry(uid=i, prompt=list(p))
                                  for i, p in enumerate(prompts)])
    group = EngineGroup([_greedy_slot(capacity=2) for _ in range(4)])
    got = _drain_tokens(group, [BufferEntry(uid=i, prompt=list(p))
                                for i, p in enumerate(prompts)])
    assert got == base


# -- metrics flow -------------------------------------------------------------

def test_group_metrics_flow_through_orchestrator():
    """RolloutOrchestrator surfaces the group gauges (steal_count,
    replica_busy, replica_bubble_ratio) via cache_stats plumbing for
    any replica type — including sim replicas with no page pool."""
    eng = make_group_sim()
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=4, group_size=2,
                         update_batch=4, max_gen_len=6)
    orch = RolloutOrchestrator(eng, buf, cfg, make_policy("sorted"),
                               lambda req: None)
    orch.run_group(_prompts(8))
    s = orch.metrics.summary()
    assert s["replica_busy"] > 0.0
    assert 0.0 <= s["replica_bubble_ratio"] <= 1.0
    assert s["steal_count"] >= 0
    stats = eng.replica_stats()
    assert len(stats) == 2
    assert all(0.0 <= r["bubble_ratio"] <= 1.0 for r in stats)


def test_group_clock_is_modeled_concurrent():
    """The group clock accumulates the max per-replica delta of each
    submit/step/sync phase: monotone, at least the slowest replica's
    total advance (phases overlap), at most the sequential sum."""
    eng = make_group_sim()
    base = [r.clock for r in eng.replicas]
    t0 = eng.clock
    eng.submit([BufferEntry(uid=i, prompt=[1, 2, 3]) for i in range(4)],
               version=0)
    clocks = [eng.clock]
    while eng.active_uids():
        eng.step()
        clocks.append(eng.clock)
    eng.sync_weights(1)
    clocks.append(eng.clock)
    assert clocks == sorted(clocks) and clocks[-1] > t0
    advances = [r.clock - b for r, b in zip(eng.replicas, base)]
    total = eng.clock - t0
    assert max(advances) <= total + 1e-9
    assert total <= sum(advances) + 1e-9


def test_group_sync_weights_broadcasts():
    eng = make_group_sim()
    eng.sync_weights(5)
    assert eng.version == 5
    assert all(r.version == 5 for r in eng.replicas)


# -- session wiring -----------------------------------------------------------

def test_session_builds_engine_group():
    from repro.rl.session import RLSession, SessionConfig
    cfg = SessionConfig(task="logic", policy="sorted", engine="sim",
                        num_replicas=4, rollout_batch=32, update_batch=32,
                        group_size=2, n_groups=1, mode=Mode.PARTIAL,
                        max_gen_len=64)
    sess = RLSession.from_config(cfg)
    assert isinstance(sess.engine, EngineGroup)
    assert len(sess.engine.replicas) == 4
    assert sess.engine.capacity == 32
    assert sess.orchestrator.cfg.num_replicas == 4
    out = sess.run()
    assert out["rollout_metrics"]["replica_busy"] > 0.0


def test_session_rejects_indivisible_replica_split():
    from repro.rl.session import RLSession, SessionConfig
    cfg = SessionConfig(task="logic", engine="sim", num_replicas=3,
                        rollout_batch=32)
    with pytest.raises(ValueError):
        RLSession.from_config(cfg)


def test_session_single_replica_stays_plain_engine():
    from repro.rl.session import RLSession, SessionConfig
    cfg = SessionConfig(task="logic", engine="sim", num_replicas=1,
                        rollout_batch=8, update_batch=8, n_groups=1,
                        max_gen_len=32)
    sess = RLSession.from_config(cfg)
    assert isinstance(sess.engine, SimEngine)
