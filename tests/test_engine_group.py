"""EngineGroup unit + regression tests beyond the shared conformance
suite: balancer registry behaviour, greedy token-identity vs the single
engine (lockstep AND async+migration), replica metrics flowing through
the orchestrator, async stepping, cross-replica KV migration (steal +
drain-phase tail packing), the least_tokens EWMA length estimator, and
the session-level num_replicas wiring."""
import pytest

from engine_conformance import _tiny_model, make_group_sim, make_slot
from repro.core.buffer import BufferEntry, Mode, StatefulRolloutBuffer
from repro.core.orchestrator import RolloutOrchestrator, SortedRLConfig
from repro.core.policy import make_policy
from repro.rollout.group import (EngineGroup, available_balancers,
                                 make_balancer)
from repro.rollout.sim import SimEngine


def _greedy_slot(capacity):
    from repro.rollout.engine import SlotEngine
    t = _tiny_model()
    return SlotEngine(t["model"], lambda: t["params"], capacity=capacity,
                      max_total_len=64, max_gen_len=8, eos_id=-1,
                      pad_id=t["pad"], temperature=0.0)


def _drain_tokens(eng, entries):
    toks = {e.uid: [] for e in entries}
    eng.submit(entries, version=0)
    steps = 0
    while eng.active_uids():
        for ev in eng.step():
            toks[ev.uid].append(ev.token)
        steps += 1
        assert steps < 1000
    return toks


def _prompts(n):
    return [[1, 2 + i % 5, 3, 4 + (i * 7) % 11] for i in range(n)]


# -- balancer registry --------------------------------------------------------

def test_balancer_registry_surface():
    names = available_balancers()
    for required in ("least_tokens", "least_loaded", "round_robin"):
        assert required in names
    with pytest.raises(KeyError):
        make_balancer("no_such_balancer")


def test_least_tokens_routes_away_from_heavy_replica():
    """The length-aware default sends fresh work to the replica with the
    least estimated outstanding tokens, not just the most free slots."""
    eng = make_group_sim()
    # occupy replica 0 with one entry: its est load is now positive
    eng.submit([BufferEntry(uid=0, prompt=[1, 2, 3])], version=0)
    assert dict(eng._home)[0] == 0
    eng.submit([BufferEntry(uid=1, prompt=[4, 5, 6])], version=0)
    assert dict(eng._home)[1] == 1, "fresh entry must avoid the loaded replica"


def test_round_robin_cycles_replicas():
    eng = make_group_sim(capacity=4, n_replicas=2)
    eng.balancer = make_balancer("round_robin")
    # fully distinct prefill prefixes, or prefix co-location would
    # (correctly) override the balancer and keep the group together
    es = [BufferEntry(uid=i, prompt=[7 + i, 8, 9]) for i in range(4)]
    eng.submit(es, version=0)
    assert [dict(eng._home)[i] for i in range(4)] == [0, 1, 0, 1]


def test_length_hint_override_drives_routing():
    """A caller-supplied length hint is honoured: the replica already
    carrying the 'long' entry is avoided even when slot counts tie."""
    hints = {0: 1000.0, 1: 1.0, 2: 1.0}
    eng = make_group_sim(capacity=4, n_replicas=2)
    eng.length_hint = lambda e: hints[e.uid]
    eng.submit([BufferEntry(uid=0, prompt=[1, 2])], version=0)   # r0: 1000
    eng.submit([BufferEntry(uid=1, prompt=[3, 4])], version=0)   # r1: light
    eng.submit([BufferEntry(uid=2, prompt=[5, 6])], version=0)   # r1 again
    homes = dict(eng._home)
    assert homes[0] == 0 and homes[1] == 1 and homes[2] == 1


def test_empty_prefill_key_does_not_co_route():
    """Single-token prompts all share the empty prefill prefix, which the
    page cache never shares — they must spread by the balancer instead of
    piling onto one replica."""
    eng = make_group_sim(capacity=4, n_replicas=2)
    eng.submit([BufferEntry(uid=i, prompt=[5 + i]) for i in range(4)],
               version=0)
    homes = [dict(eng._home)[i] for i in range(4)]
    assert sorted(homes) == [0, 0, 1, 1], homes


# -- token identity -----------------------------------------------------------

def test_group_greedy_token_identical_to_single_engine():
    """Pinned: greedy decode through EngineGroup(n=4) is token-identical
    per uid to the single SlotEngine on the same prompts — sharding the
    rollout must not change any trajectory."""
    prompts = _prompts(8)
    single = _greedy_slot(capacity=8)
    base = _drain_tokens(single, [BufferEntry(uid=i, prompt=list(p))
                                  for i, p in enumerate(prompts)])
    group = EngineGroup([_greedy_slot(capacity=2) for _ in range(4)])
    got = _drain_tokens(group, [BufferEntry(uid=i, prompt=list(p))
                                for i, p in enumerate(prompts)])
    assert got == base


# -- async stepping -----------------------------------------------------------

def _hetero_async_group():
    """Sim replicas with a 4x step-cost spread: the fast replica must fit
    several micro-steps into the straggler's one-step window."""
    from repro.rollout.sim import SimCostModel
    lengths = {i: 12 for i in range(8)}
    return EngineGroup(
        [SimEngine(capacity=2, max_gen_len=64, seed=i, length_table=lengths,
                   cost=SimCostModel(t_fixed=5e-3 if i == 0 else 20e-3))
         for i in range(2)],
        async_step=True)


def test_async_step_catches_up_fast_replicas():
    eng = _hetero_async_group()
    eng.submit([BufferEntry(uid=i, prompt=[1, 2 + i]) for i in range(4)],
               version=0)
    evs = eng.step()
    by_uid = {}
    for ev in evs:
        by_uid[ev.uid] = by_uid.get(ev.uid, 0) + 1
    fast = [u for u in by_uid if dict(eng._home)[u] == 0]
    slow = [u for u in by_uid if dict(eng._home)[u] == 1]
    assert all(by_uid[u] == 1 for u in slow), "straggler stepped once"
    assert all(by_uid[u] > 1 for u in fast), \
        "fast replica should micro-step inside the straggler's window"


def test_async_step_merge_is_replica_major_and_conserves():
    """Async events stay grouped by replica (replica order), each uid's
    token stream is a single contiguous-order substream, and every uid
    finishes exactly once."""
    eng = _hetero_async_group()
    eng.submit([BufferEntry(uid=i, prompt=[1, 2 + i]) for i in range(4)],
               version=0)
    done = {}
    steps = 0
    while eng.active_uids():
        homes = dict(eng._home)
        evs = eng.step()
        replicas_seen = [homes[ev.uid] for ev in evs]
        assert replicas_seen == sorted(replicas_seen), \
            "merged stream must be replica-major"
        for ev in evs:
            if ev.done:
                done[ev.uid] = done.get(ev.uid, 0) + 1
        steps += 1
        assert steps < 1000
    assert done == {i: 1 for i in range(4)}
    assert eng.free_slots() == eng.capacity


def test_async_clock_advances_by_straggler_window():
    """The group clock charges the max per-replica in-call time, not the
    sum — async replicas overlap."""
    eng = _hetero_async_group()
    eng.submit([BufferEntry(uid=i, prompt=[1, 2 + i]) for i in range(4)],
               version=0)
    r_clocks = [r.clock for r in eng.replicas]
    t0 = eng.clock
    eng.step()
    dt = eng.clock - t0
    deltas = [r.clock - c for r, c in zip(eng.replicas, r_clocks)]
    assert abs(dt - max(deltas)) < 1e-12
    assert dt < sum(deltas)


# -- cross-replica KV migration (steal + drain pack) --------------------------

def test_steal_with_migration_resumes_with_zero_reprefill():
    """migrate_kv=True turns the steal path's re-prefill into a page-span
    migration: the stolen entry lands on the thief with its KV resident
    and resumes for free; the donor keeps nothing behind."""
    eng = EngineGroup([make_slot(capacity=2) for _ in range(2)],
                      migrate_kv=True)
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    uids = buf.load_prompts([[1, 2, 3, 4, 5], [6, 7, 8, 9, 2]])
    buf.mark_running(uids)
    eng.submit(buf.running(), version=0)
    home0 = dict(eng._home)[uids[0]]
    for _ in range(2):
        for ev in eng.step():
            buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
            if ev.done:
                buf.mark_done(ev.uid, ev.finish_reason)
    for uid in eng.interrupt():
        buf.scavenge(uid)
    # saturate uid0's home replica so the resubmit must steal
    eng.submit([BufferEntry(uid=100 + i, prompt=[3, 1, 4, 1 + i])
                for i in range(3)], version=0)
    assert eng.replicas[home0].free_slots() == 0
    run_before = eng.cache_stats()["prefill_tokens_run"]
    victim = buf.entries[uids[0]]
    buf.mark_running([victim.uid])
    eng.submit([victim], version=0)
    st = eng.cache_stats()
    assert eng.steal_count == 1 and eng.steal_migrations == 1
    assert st["prefill_tokens_run"] == run_before, \
        "migrated steal must not re-prefill"
    assert st["resumed_without_prefill"] >= 1
    assert st["migrated_pages"] >= 1
    assert victim.uid not in eng.replicas[home0].kv.tables, \
        "donor kept dead pages after migrating the span"
    while eng.active_uids():
        eng.step()
    for r in eng.replicas:
        r.kv.check_invariants()


def test_drain_pack_consolidates_tail_and_releases_replicas():
    """Once in-flight work fits on fewer replicas, drain_pack migrates the
    tail onto them: donors go fully idle (released from the busy set) and
    every packed entry still finishes exactly once."""
    lengths = {i: 40 for i in range(8)}
    eng = EngineGroup([SimEngine(capacity=2, max_gen_len=64, seed=i,
                                 length_table=lengths)
                       for i in range(4)], balancer="drain_pack")
    assert eng.drain_pack and eng.migrate_kv
    eng.submit([BufferEntry(uid=i, prompt=[1, 2 + i]) for i in range(8)],
               version=0)
    # empty six slots unevenly: survivors sit on two different replicas
    homes = dict(eng._home)
    survivors = []
    for rep in (0, 2):
        survivors.append(next(u for u, h in homes.items() if h == rep))
    eng.interrupt([u for u in range(8) if u not in survivors])
    eng.step()                       # quiet-interval guard: no pack yet
    assert eng.packed_entries == 0
    eng.step()                       # pack runs before the decode dispatch
    assert eng.packed_entries == 1
    active_per_rep = [len(r.active_uids()) for r in eng.replicas]
    assert sorted(active_per_rep) == [0, 0, 0, 2], active_per_rep
    done = set()
    steps = 0
    while eng.active_uids():
        for ev in eng.step():
            if ev.done:
                assert ev.uid not in done
                done.add(ev.uid)
        steps += 1
        assert steps < 1000
    assert done == set(survivors)


def test_drain_pack_skips_when_group_is_full():
    lengths = {i: 20 for i in range(4)}
    eng = EngineGroup([SimEngine(capacity=2, max_gen_len=64, seed=i,
                                 length_table=lengths) for i in range(2)],
                      balancer="drain_pack")
    eng.submit([BufferEntry(uid=i, prompt=[1, 2 + i]) for i in range(4)],
               version=0)
    eng.step()
    assert eng.packed_entries == 0, "a full group has no tail to pack"


def test_drain_pack_greedy_token_identical_with_migration():
    """Acceptance pin (extends, not relaxes, the lockstep identity): with
    async stepping AND migration enabled (drain_pack balancer), greedy
    EngineGroup(n=4) stays token-identical per uid to the single engine —
    a packed slot resumes mid-flight on another replica with bit-equal
    KV."""
    prompts = _prompts(8)
    single = _greedy_slot(capacity=8)
    entries = [BufferEntry(uid=i, prompt=list(p))
               for i, p in enumerate(prompts)]
    single.submit(entries, version=0)
    base = {e.uid: [] for e in entries}
    # interrupt six uids after 2 steps: the tail shrinks to 2 entries
    for _ in range(2):
        for ev in single.step():
            base[ev.uid].append(ev.token)
    single.interrupt([u for u in range(8) if u not in (0, 5)])
    while single.active_uids():
        for ev in single.step():
            base[ev.uid].append(ev.token)

    group = EngineGroup([_greedy_slot(capacity=2) for _ in range(4)],
                        balancer="drain_pack", async_step=True)
    got = {e.uid: [] for e in entries}
    group.submit([BufferEntry(uid=i, prompt=list(p))
                  for i, p in enumerate(prompts)], version=0)
    for _ in range(2):
        for ev in group.step():
            got[ev.uid].append(ev.token)
    group.interrupt([u for u in range(8) if u not in (0, 5)])
    steps = 0
    while group.active_uids():
        for ev in group.step():
            got[ev.uid].append(ev.token)
        steps += 1
        assert steps < 1000
    assert {u: got[u] for u in (0, 5)} == {u: base[u] for u in (0, 5)}
    assert group.packed_entries >= 1, "tail never consolidated"
    assert group.cache_stats()["migrated_pages"] >= 1
    for r in group.replicas:
        r.kv.check_invariants()


# -- least_tokens EWMA length estimator ---------------------------------------

def test_ewma_hint_error_shrinks_with_observed_completions():
    """The routing hint starts from an uninformed prior (half the gen
    budget) and converges toward observed completion lengths — the
    groundwork for the backlog's length-hint learning."""
    true_len = 10
    lengths = {i: true_len for i in range(64)}
    eng = EngineGroup([SimEngine(capacity=4, max_gen_len=512, seed=i,
                                 length_table=lengths) for i in range(2)])
    probe = BufferEntry(uid=999, prompt=[1, 2])
    err0 = abs(eng._hint(probe) - true_len)
    errs = [err0]
    for start in range(0, 32, 8):
        eng.submit([BufferEntry(uid=u, prompt=[1, 2 + u])
                    for u in range(start, start + 8)], version=0)
        while eng.active_uids():
            eng.step()
        errs.append(abs(eng._hint(probe) - true_len))
    assert errs[-1] < errs[0], errs
    assert errs[-1] < 1.0, f"EWMA should converge near {true_len}: {errs}"
    assert all(a >= b - 1e-9 for a, b in zip(errs, errs[1:])), \
        f"hint error must shrink as completions are observed: {errs}"


def test_caller_length_hint_overrides_ewma():
    lengths = {i: 10 for i in range(16)}
    eng = EngineGroup([SimEngine(capacity=4, max_gen_len=512, seed=i,
                                 length_table=lengths) for i in range(2)])
    eng.submit([BufferEntry(uid=u, prompt=[1, 2 + u]) for u in range(8)],
               version=0)
    while eng.active_uids():
        eng.step()
    assert eng._ewma_len is not None
    probe = BufferEntry(uid=999, prompt=[1, 2])
    eng.length_hint = lambda e: 333.0
    assert eng._hint(probe) == 333.0, "caller hint must override the EWMA"
    eng.length_hint = None
    assert eng._hint(probe) < 100.0          # back on the learned estimate


# -- simulator residency (paged-engine resume semantics) ----------------------

def test_sim_residency_resume_is_free_and_counted():
    eng = SimEngine(capacity=2, max_gen_len=32, seed=0, kv_residency=True,
                    length_table={0: 20, 1: 20})
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    uids = buf.load_prompts([[1, 2, 3], [4, 5, 6]])
    buf.mark_running(uids)
    eng.submit(buf.running(), version=0)
    run0 = eng.prefill_tokens_run
    assert run0 == 6
    for _ in range(2):
        for ev in eng.step():
            buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
    for uid in eng.interrupt():
        buf.scavenge(uid)
    resumed = buf.pending()
    buf.mark_running([e.uid for e in resumed])
    clock_before = eng.clock
    eng.submit(resumed, version=0)
    assert eng.clock == clock_before, "resident resume must charge nothing"
    st = eng.cache_stats()
    assert st["prefill_tokens_run"] == run0
    assert st["resumed_without_prefill"] == 2
    assert st["prefill_tokens_saved"] > 0


def test_sim_strict_sync_drops_residency():
    """kv_retain_across_sync=False mirrors the paged cache: a weight sync
    invalidates every modeled residency, so post-sync re-rolls charge a
    fresh prefill instead of resuming pre-sync KV for free."""
    eng = SimEngine(capacity=2, max_gen_len=32, seed=0, kv_residency=True,
                    kv_retain_across_sync=False, length_table={0: 20, 1: 20})
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    uids = buf.load_prompts([[1, 2, 3], [4, 5, 6]])
    buf.mark_running(uids)
    eng.submit(buf.running(), version=0)
    for _ in range(2):
        for ev in eng.step():
            buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
    for uid in eng.interrupt():
        buf.scavenge(uid)
    eng.sync_weights(1)
    resumed = buf.pending()
    buf.mark_running([e.uid for e in resumed])
    clock_before = eng.clock
    eng.submit(resumed, version=1)
    assert eng.clock > clock_before, \
        "stale residency must not serve a free resume under strict sync"
    assert eng.cache_stats()["resumed_without_prefill"] == 0


def test_drain_pack_routes_around_exhausted_destination_pool():
    """A destination-local import failure (exhausted page pool) must not
    strand the tail: packing falls through to the next keep replica that
    can actually take the span."""
    starved = make_slot(capacity=2, num_pages=2)    # 1 usable page
    roomy = [make_slot(capacity=2) for _ in range(2)]
    eng = EngineGroup([starved] + roomy, drain_pack=True)
    # fully distinct prompts, or prefix co-routing would pile them up
    eng.submit([BufferEntry(uid=i, prompt=[2 + i] * 4 + [6 + i])
                for i in range(3)], version=0)
    homes = dict(eng._home)
    assert sorted(homes.values()) == [0, 1, 2], "entries must spread"
    # 3 in-flight over capacity 6: packing wants keep=[r0, r1], donor=r2 —
    # but r0's pool is full with its own active entry, so r2's entry must
    # land on r1 instead of aborting the pass
    eng.step()                       # quiet-interval guard: no pack yet
    eng.step()
    assert eng.packed_entries == 1
    assert [len(r.active_uids()) for r in eng.replicas] == [1, 2, 0]
    done = set()
    steps = 0
    while eng.active_uids():
        for ev in eng.step():
            if ev.done:
                assert ev.uid not in done
                done.add(ev.uid)
        steps += 1
        assert steps < 200
    assert done == {0, 1, 2}
    for r in eng.replicas:
        r.kv.check_invariants()


def test_sim_without_residency_keeps_charging_resumes():
    eng = SimEngine(capacity=2, max_gen_len=32, seed=0,
                    length_table={0: 20, 1: 20})
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    uids = buf.load_prompts([[1, 2, 3], [4, 5, 6]])
    buf.mark_running(uids)
    eng.submit(buf.running(), version=0)
    for _ in range(2):
        for ev in eng.step():
            buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
    for uid in eng.interrupt():
        buf.scavenge(uid)
    resumed = buf.pending()
    buf.mark_running([e.uid for e in resumed])
    clock_before = eng.clock
    eng.submit(resumed, version=0)
    assert eng.clock > clock_before, "default sim must re-charge the prefix"
    assert eng.cache_stats()["resumed_without_prefill"] == 0


# -- metrics flow -------------------------------------------------------------

def test_group_metrics_flow_through_orchestrator():
    """RolloutOrchestrator surfaces the group gauges (steal_count,
    replica_busy, replica_bubble_ratio) via cache_stats plumbing for
    any replica type — including sim replicas with no page pool."""
    eng = make_group_sim()
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=4, group_size=2,
                         update_batch=4, max_gen_len=6)
    orch = RolloutOrchestrator(eng, buf, cfg, make_policy("sorted"),
                               lambda req: None)
    orch.run_group(_prompts(8))
    s = orch.metrics.summary()
    assert s["replica_busy"] > 0.0
    assert 0.0 <= s["replica_bubble_ratio"] <= 1.0
    assert s["steal_count"] >= 0
    stats = eng.replica_stats()
    assert len(stats) == 2
    assert all(0.0 <= r["bubble_ratio"] <= 1.0 for r in stats)


def test_group_clock_is_modeled_concurrent():
    """The group clock accumulates the max per-replica delta of each
    submit/step/sync phase: monotone, at least the slowest replica's
    total advance (phases overlap), at most the sequential sum."""
    eng = make_group_sim()
    base = [r.clock for r in eng.replicas]
    t0 = eng.clock
    eng.submit([BufferEntry(uid=i, prompt=[1, 2, 3]) for i in range(4)],
               version=0)
    clocks = [eng.clock]
    while eng.active_uids():
        eng.step()
        clocks.append(eng.clock)
    eng.sync_weights(1)
    clocks.append(eng.clock)
    assert clocks == sorted(clocks) and clocks[-1] > t0
    advances = [r.clock - b for r, b in zip(eng.replicas, base)]
    total = eng.clock - t0
    assert max(advances) <= total + 1e-9
    assert total <= sum(advances) + 1e-9


def test_group_sync_weights_broadcasts():
    eng = make_group_sim()
    eng.sync_weights(5)
    assert eng.version == 5
    assert all(r.version == 5 for r in eng.replicas)


# -- session wiring -----------------------------------------------------------

def test_session_builds_engine_group():
    from repro.rl.session import RLSession, SessionConfig
    cfg = SessionConfig(task="logic", policy="sorted", engine="sim",
                        num_replicas=4, rollout_batch=32, update_batch=32,
                        group_size=2, n_groups=1, mode=Mode.PARTIAL,
                        max_gen_len=64)
    sess = RLSession.from_config(cfg)
    assert isinstance(sess.engine, EngineGroup)
    assert len(sess.engine.replicas) == 4
    assert sess.engine.capacity == 32
    assert sess.orchestrator.cfg.num_replicas == 4
    out = sess.run()
    assert out["rollout_metrics"]["replica_busy"] > 0.0


def test_session_rejects_indivisible_replica_split():
    from repro.rl.session import RLSession, SessionConfig
    cfg = SessionConfig(task="logic", engine="sim", num_replicas=3,
                        rollout_batch=32)
    with pytest.raises(ValueError):
        RLSession.from_config(cfg)


def test_session_single_replica_stays_plain_engine():
    from repro.rl.session import RLSession, SessionConfig
    cfg = SessionConfig(task="logic", engine="sim", num_replicas=1,
                        rollout_batch=8, update_batch=8, n_groups=1,
                        max_gen_len=32)
    sess = RLSession.from_config(cfg)
    assert isinstance(sess.engine, SimEngine)


# -- fault injection, re-homing, and elasticity -------------------------------

def test_fault_injector_plan_is_deterministic_and_validated():
    from repro.core.engine_api import FaultEvent, FaultInjector
    a = FaultInjector.random_plan(seed=7, n_replicas=4, horizon=50,
                                  n_faults=5)
    b = FaultInjector.random_plan(seed=7, n_replicas=4, horizon=50,
                                  n_faults=5)
    assert a.plan == b.plan, "same seed must give the same fault plan"
    c = FaultInjector.random_plan(seed=8, n_replicas=4, horizon=50,
                                  n_faults=5)
    assert a.plan != c.plan
    inj = FaultInjector([(3, 1, "kill"), (3, 0, "stall", 2)])
    assert [f.kind for f in inj.due(3)] == ["stall", "kill"]  # sorted
    assert inj.due(4) == []
    with pytest.raises(ValueError):
        FaultInjector([(0, 1, "kill")])         # steps are 1-based
    with pytest.raises(ValueError):
        FaultInjector([(3, 1, "explode")])      # unknown fault kind


def test_interrupt_targets_current_holder_not_stale_home():
    """Regression: targeted interrupts must resolve the uid's holder from
    live slot state.  A stale home record (left behind by a steal
    migration) once sent the interrupt to a replica that no longer held
    the entry, leaking the real slot."""
    eng = make_group_sim(capacity=4, n_replicas=2)
    eng.submit([BufferEntry(uid=0, prompt=[1, 2, 3])], version=0)
    holder = dict(eng._home)[0]
    eng._home[0] = 1 - holder           # poison: point home at the peer
    got = eng.interrupt([0])
    assert got == [0]
    assert eng.replicas[holder].free_slots() == 2, \
        "interrupt must free the slot on the actual holder"
    assert eng.free_slots() == eng.capacity


def test_drained_replica_rejoins_on_new_work():
    """Regression: a replica released by drain-phase packing is still
    ALIVE — a late-arriving submit must be able to route onto it (and its
    slots must count as free), instead of treating it like a fenced
    replica."""
    lengths = {i: 40 for i in range(16)}
    eng = EngineGroup([SimEngine(capacity=2, max_gen_len=64, seed=i,
                                 length_table=lengths)
                       for i in range(4)], balancer="drain_pack")
    eng.submit([BufferEntry(uid=i, prompt=[1, 2 + i]) for i in range(8)],
               version=0)
    homes = dict(eng._home)
    survivors = [next(u for u, h in homes.items() if h == rep)
                 for rep in (0, 2)]
    eng.interrupt([u for u in range(8) if u not in survivors])
    eng.step()
    eng.step()                          # pack: survivors consolidate
    assert eng.packed_entries == 1
    idle = [i for i, r in enumerate(eng.replicas) if not r.active_uids()]
    assert len(idle) == 3
    # all drained slots still count toward the group's free capacity
    assert eng.free_slots() == 6
    fresh = [BufferEntry(uid=100 + i, prompt=[9 + i, 8, 7])
             for i in range(6)]
    eng.submit(fresh, version=0)        # needs the drained replicas
    new_homes = dict(eng._home)
    assert any(new_homes[e.uid] in idle for e in fresh), \
        "new work must be routable onto drained-but-alive replicas"
    assert set(eng.active_uids()) == set(survivors) | {e.uid for e in fresh}
    evs = eng.step()
    assert {ev.uid for ev in evs} >= {e.uid for e in fresh}, \
        "rejoined replicas must actually step their new work"


@pytest.mark.parametrize("balancer", ["round_robin", "least_loaded",
                                      "least_tokens", "weighted_tokens"])
def test_dead_replica_never_selected(balancer):
    """Regression: a fenced replica's SlotTable reads fully free after
    shutdown — no balancer may route new work onto it."""
    from repro.core.engine_api import FaultEvent
    eng = EngineGroup([SimEngine(capacity=2, max_gen_len=8, seed=i)
                       for i in range(2)], balancer=balancer)
    eng._apply_fault(FaultEvent(step=1, replica=1, kind="kill"))
    assert eng.capacity == 2 and eng.free_slots() == 2
    es = [BufferEntry(uid=i, prompt=[5 + i, 6, 7]) for i in range(2)]
    eng.submit(es, version=0)
    assert all(h == 0 for h in dict(eng._home).values())
    assert not eng.replicas[1].active_uids()
    with pytest.raises(AssertionError):
        eng.submit([BufferEntry(uid=9, prompt=[1, 2])], version=0)


def test_kill_rehomes_actives_to_survivor_with_free_slots():
    from repro.core.engine_api import FaultEvent, FaultInjector
    inj = FaultInjector([FaultEvent(step=2, replica=1, kind="kill")])
    eng = EngineGroup([SimEngine(capacity=2, max_gen_len=16, seed=i,
                                 kv_residency=True,
                                 length_table={0: 12, 1: 12})
                       for i in range(2)], migrate_kv=True,
                      fault_injector=inj)
    eng.submit([BufferEntry(uid=0, prompt=[1, 2, 3]),
                BufferEntry(uid=1, prompt=[4, 5, 6])], version=0)
    assert dict(eng._home) == {0: 0, 1: 1}
    eng.step()
    evs = eng.step()                    # kill fires: uid1 transplants to r0
    assert eng.alive == [True, False]
    assert eng.rehomed_entries == 1 and eng.rerolled_entries == 0
    assert dict(eng._home)[1] == 0
    assert sorted(eng.active_uids()) == [0, 1]
    assert {ev.uid for ev in evs} == {0, 1}, "transplant resumes same step"
    assert eng.take_failed_uids() == []


def test_stall_pauses_replica_without_losing_work():
    from repro.core.engine_api import FaultEvent, FaultInjector
    inj = FaultInjector([FaultEvent(step=2, replica=1, kind="stall",
                                    duration=2)])
    eng = EngineGroup([SimEngine(capacity=1, max_gen_len=16, seed=i,
                                 length_table={0: 8, 1: 8})
                       for i in range(2)], fault_injector=inj)
    eng.submit([BufferEntry(uid=0, prompt=[1, 2, 3]),
                BufferEntry(uid=1, prompt=[4, 5, 6])], version=0)
    assert {ev.uid for ev in eng.step()} == {0, 1}
    for _ in range(2):                  # stalled steps: only replica 0 runs
        assert {ev.uid for ev in eng.step()} == {0}
    assert {ev.uid for ev in eng.step()} == {0, 1}, "stall must expire"
    assert eng.alive == [True, True] and eng.replica_deaths == 0


def test_slow_fault_inflates_replica_step_cost():
    from repro.core.engine_api import FaultEvent, FaultInjector
    inj = FaultInjector([FaultEvent(step=1, replica=1, kind="slow",
                                    duration=4, factor=8.0)])
    eng = EngineGroup([SimEngine(capacity=1, max_gen_len=32, seed=0,
                                 length_table={0: 20, 1: 20})
                       for i in range(2)], fault_injector=inj)
    eng.submit([BufferEntry(uid=0, prompt=[1, 2, 3]),
                BufferEntry(uid=1, prompt=[4, 5, 6])], version=0)
    for _ in range(3):
        eng.step()
    assert eng.replica_step_cost(1) > 2.0 * eng.replica_step_cost(0)
    for _ in range(3):                  # past duration: throttle restored
        eng.step()
    assert eng.replicas[1].throttle_factor == 1.0


def test_weighted_tokens_routes_around_slow_replica():
    """The throughput-weighted balancer sends fresh work to the replica
    with the cheapest observed step time, not just the fewest tokens."""
    eng = EngineGroup([SimEngine(capacity=2, max_gen_len=32, seed=i,
                                 length_table={i: 24 for i in range(8)})
                       for i in range(2)], balancer="weighted_tokens")
    eng.replicas[1].throttle(6.0)
    eng.submit([BufferEntry(uid=0, prompt=[1, 2, 3]),
                BufferEntry(uid=1, prompt=[4, 5, 6])], version=0)
    assert dict(eng._home) == {0: 0, 1: 1}   # cold start: index order
    for _ in range(3):
        eng.step()                      # observe per-replica step costs
    eng.submit([BufferEntry(uid=2, prompt=[7, 8, 9])], version=0)
    assert dict(eng._home)[2] == 0, \
        "fresh work must prefer the fast replica despite equal loads"


def test_scale_down_migrates_and_scale_up_extends():
    entries = [BufferEntry(uid=i, prompt=[1, 2 + i, 3]) for i in range(4)]
    eng = EngineGroup([SimEngine(capacity=2, max_gen_len=32, seed=i,
                                 kv_residency=True,
                                 length_table={i: 20 for i in range(8)})
                       for i in range(2)], elastic=True)
    eng.submit(entries, version=0)
    eng.step()
    eng.scale_down(1)                   # graceful drain of replica 1
    assert eng.alive == [True, False] and eng.scale_events == 1
    # the survivor is slot-full, so replica 1's actives re-home as
    # RESIDENT KV on replica 0 and come back for a resubmit — a graceful
    # drain never re-rolls salvageable state
    assert eng.rehomed_entries == 2 and eng.rerolled_entries == 0
    assert eng.capacity == 2 and len(eng.active_uids()) == 2
    parked = eng.take_failed_uids()
    assert len(parked) == 2
    assert all(dict(eng._home)[u] == 0 for u in parked)
    with pytest.raises(AssertionError):
        eng.scale_down(0)               # never scale away the last replica
    j = eng.scale_up(SimEngine(capacity=4, max_gen_len=32, seed=9,
                               kv_residency=True))
    assert j == 2 and eng.scale_events == 2 and eng.capacity == 6
    assert eng.replicas[j].version == eng.version
    eng.submit([entries[u] for u in parked], version=0)
    eng.submit([BufferEntry(uid=10, prompt=[8, 9])], version=0)
    assert dict(eng._home)[10] == j, "new capacity must absorb new work"
    done, steps = set(), 0
    while eng.active_uids():
        done |= {ev.uid for ev in eng.step() if ev.done}
        steps += 1
        assert steps < 500
    assert done == {0, 1, 2, 3, 10}, "every entry finishes exactly once"


def test_session_wires_fault_plan_and_elastic():
    from repro.rl.session import RLSession, SessionConfig
    cfg = SessionConfig(task="logic", policy="sorted", engine="sim",
                        num_replicas=2, rollout_batch=8, update_batch=8,
                        group_size=2, n_groups=1, mode=Mode.PARTIAL,
                        max_gen_len=32, fault_plan=[(3, 1, "kill")],
                        elastic=True)
    sess = RLSession.from_config(cfg)
    assert sess.engine.fault_injector is not None
    assert sess.engine.elastic
    out = sess.run()
    assert out["rollout_metrics"]["replica_deaths"] == 1


def test_session_rejects_fault_plan_on_single_replica():
    from repro.rl.session import RLSession, SessionConfig
    cfg = SessionConfig(task="logic", engine="sim", num_replicas=1,
                        rollout_batch=8, fault_plan=[(3, 0, "kill")])
    with pytest.raises(ValueError):
        RLSession.from_config(cfg)
