"""Autoscaler-conformance suite: the observe -> scale loop's contract.

The :mod:`repro.rollout.autoscaler` controller turns the group's windowed
Eq. 4 bubble (and the serving tier's backlog age) into actual
``EngineGroup.scale_down``/``scale_up`` calls.  This suite pins:

  * the policy registry contract (string names, protocol instances,
    unknown-name errors) — mirroring the scheduler/balancer/admission
    registry suites;
  * controller mechanics in isolation: the fleet never drops below
    ``min_replicas`` or grows past ``max_replicas``, ``confirm_steps``
    hysteresis gates every action, ``cooldown`` spaces consecutive
    actions on the group clock, and growth without a replica factory is
    a no-op;
  * warm scale_up: minted replicas join at the group's weight version,
    mixed ``cap_total`` fleets route work onto the new replica
    (``round_robin`` and ``weighted_tokens`` swept), and ``scale_up``
    immediately after a kill restores capacity at the same fleet size;
  * the full scheduling contract under autoscaling: conservation, the
    group barrier, buffer invariants, and a drained fleet survive an
    aggressively thrashing policy, replica-swept {2, 4} over the sorted
    and pipelined schedulers — and the whole run is deterministic under
    a fixed seed (identical event logs, token counts, clocks);
  * signal-accounting regressions the loop exposed: the serving tier's
    bubble attribution counts distinct busy slots (async micro-steps
    emit >1 event per uid), ``rollout_until_harvest`` recomputes its
    harvest threshold every iteration (mid-loop admission used to see a
    stale cap), and ``scale_down`` releases unclaimed resident KV
    through the ``residency_dropped`` gauge instead of silently fencing
    it away;
  * a chaos proptest interleaving autoscaler ticks with kill / stall /
    scale faults on a real two-pool SlotEngine fleet: page-pool
    invariants hold after every operation and fenced replicas hold
    nothing (the fast sim-fleet variant runs in the seconds lane).
"""
import pytest

from chaos_conformance import _fleet_invariants
from engine_conformance import make_slot
from policy_conformance import CAPACITY, GROUP, MAX_GEN, N_PROMPTS, prompts
from proptest import cases, integers, lists, tuples
from repro.core.buffer import BufferEntry, Mode, StatefulRolloutBuffer
from repro.core.engine_api import FaultEvent, StepEvent
from repro.core.metrics import RolloutMetrics
from repro.core.orchestrator import (RolloutOrchestrator, SortedRLConfig,
                                     UpdateRequest)
from repro.core.policy import AdmitRequest, BasePolicy, make_policy
from repro.rollout.autoscaler import (Autoscaler, AutoscalerPolicy,
                                      MetricsWindow, available_autoscalers,
                                      make_autoscaler)
from repro.rollout.group import EngineGroup
from repro.rollout.sim import SimEngine, lognormal_lengths
from repro.serve import (BurstyArrivals, Ingress, ServingOrchestrator,
                         ServingPolicy, TenantSpec, TraceArrivals)


# -- fleet / policy helpers ---------------------------------------------------

def make_sim(capacity=1, seed=0, max_gen=MAX_GEN, lengths=None, **kw):
    if lengths is not None:
        kw["length_table"] = lengths
    else:
        kw.setdefault("length_sampler",
                      lognormal_lengths(median=3, sigma=0.8, max_len=max_gen))
    kw.setdefault("kv_residency", True)
    return SimEngine(capacity=capacity, max_gen_len=max_gen, seed=seed, **kw)


def sim_fleet(n, capacity=1, max_gen=MAX_GEN, lengths=None, **kw):
    kw.setdefault("migrate_kv", True)
    return EngineGroup([make_sim(capacity=capacity, seed=i, max_gen=max_gen,
                                 lengths=lengths) for i in range(n)],
                       elastic=True, **kw)


class ConstantPolicy:
    """Minimal AutoscalerPolicy instance: a constant proposal — isolates
    the controller's clamp / hysteresis / cooldown mechanics from any
    signal logic."""
    name = "constant"

    def __init__(self, want: int):
        self.want = want

    def propose(self, view) -> int:
        return self.want


class SequencePolicy:
    """Propose a scripted sequence (then hold 0) — drives the hysteresis
    streak through exact reset scenarios."""
    name = "sequence"

    def __init__(self, seq):
        self.seq = list(seq)
        self.i = 0

    def propose(self, view) -> int:
        want = self.seq[self.i] if self.i < len(self.seq) else 0
        self.i += 1
        return want


class ThrashPolicy:
    """Alternate shed / grow every tick — the adversarial driver for the
    scheduling-contract tests: maximum scale churn the controller will
    permit, still deterministic."""
    name = "thrash"

    def __init__(self):
        self.t = 0

    def propose(self, view) -> int:
        self.t += 1
        if self.t % 2 and view.can_shed:
            return -1
        if view.can_grow:
            return 1
        return 0


# -- registry contract --------------------------------------------------------

def test_registry_lists_builtin_policies():
    names = available_autoscalers()
    assert "bubble_target" in names and "queue_depth" in names


@pytest.mark.parametrize("name", ["bubble_target", "queue_depth"])
def test_registry_builds_protocol_instances(name):
    p = make_autoscaler(name)
    assert isinstance(p, AutoscalerPolicy)
    assert p.name == name


def test_registry_unknown_name_raises_with_listing():
    with pytest.raises(KeyError, match="bubble_target"):
        make_autoscaler("nope")


def test_controller_accepts_instance_and_kwargs():
    asc = Autoscaler(ConstantPolicy(0))
    assert asc.policy.name == "constant"
    asc = Autoscaler("bubble_target",
                     policy_kwargs=dict(high=0.7, low=0.2))
    assert asc.policy.high == 0.7 and asc.policy.low == 0.2


def test_metrics_window_deltas_and_fullness():
    w = MetricsWindow(1.0)
    assert not w.full and w.bubble() == 0.0
    # cumulative integrals: 2 slots, busy half the time
    for t, cap, busy in [(0.0, 0.0, 0.0), (0.5, 1.0, 0.5), (1.0, 2.0, 1.0)]:
        w.push(t, {"replica_cap_time": cap, "replica_busy_time": busy})
    assert w.full and w.covered == 1.0
    assert w.bubble() == pytest.approx(0.5)
    # old observations roll off; the newest out-of-span one stays as the
    # delta base, so the window is the (1.0, 2.5] slice only
    w.push(2.5, {"replica_cap_time": 5.0, "replica_busy_time": 3.5})
    assert len(w) == 2 and w.covered >= w.span
    assert w.bubble() == pytest.approx((5.0 - 2.0 - (3.5 - 1.0)) / 3.0)


# -- controller mechanics -----------------------------------------------------

@pytest.mark.parametrize("n,floor", [(2, 1), (4, 1), (4, 2)])
def test_fleet_never_drops_below_min_replicas(n, floor):
    eng = sim_fleet(n)
    asc = Autoscaler(ConstantPolicy(-1), min_replicas=floor,
                     cooldown=0.0, confirm_steps=1)
    for _ in range(3 * n):
        asc.tick(eng)
        assert sum(eng.alive) >= floor
    assert sum(eng.alive) == floor
    assert len(asc.events) == n - floor
    assert all(e.direction == -1 for e in asc.events)


def test_fleet_never_grows_past_max_replicas():
    eng = sim_fleet(2)
    asc = Autoscaler(ConstantPolicy(+1), factory=lambda i: make_sim(seed=i),
                     max_replicas=4, cooldown=0.0, confirm_steps=1)
    for _ in range(8):
        asc.tick(eng)
        assert sum(eng.alive) <= 4
    assert sum(eng.alive) == 4 and len(eng.replicas) == 4
    assert len(asc.events) == 2
    assert all(e.direction == +1 for e in asc.events)


def test_grow_without_factory_is_a_noop():
    eng = sim_fleet(2)
    asc = Autoscaler(ConstantPolicy(+1), cooldown=0.0, confirm_steps=1)
    for _ in range(4):
        asc.tick(eng)
    assert not asc.events and len(eng.replicas) == 2


def test_confirm_steps_gates_every_action():
    eng = sim_fleet(8)
    asc = Autoscaler(ConstantPolicy(-1), cooldown=0.0, confirm_steps=3)
    fired = [bool(asc.tick(eng)) for _ in range(6)]
    # streak resets after each action: fire on ticks 3 and 6 only
    assert fired == [False, False, True, False, False, True]


@pytest.mark.parametrize("seq", [[-1, 0, -1, 0, -1, 0],
                                 [-1, 1, -1, 1, -1, 1]])
def test_streak_resets_on_zero_and_direction_flip(seq):
    eng = sim_fleet(4)
    asc = Autoscaler(SequencePolicy(seq), cooldown=0.0, confirm_steps=2,
                     factory=lambda i: make_sim(seed=i))
    for _ in seq:
        asc.tick(eng)
    assert not asc.events, \
        "an interrupted streak must never reach confirm_steps"


def test_cooldown_spaces_actions_on_the_group_clock():
    # one long entry keeps the clock advancing; idle peers are shed but
    # never faster than one action per cooldown span
    eng = sim_fleet(6, lengths={0: 40})
    eng.submit([BufferEntry(uid=0, prompt=[1, 2, 3])], version=0)
    cooldown = 0.09
    asc = Autoscaler(ConstantPolicy(-1), min_replicas=1,
                     cooldown=cooldown, confirm_steps=1)
    for _ in range(40):
        if not eng.active_uids():
            break
        eng.step()
        asc.tick(eng)
    assert len(asc.events) >= 2, "the clock advanced; sheds must fire"
    times = [e.t for e in asc.events]
    for a, b in zip(times, times[1:]):
        assert b - a >= cooldown - 1e-9, (times, cooldown)


def test_shed_skips_undrainable_fleets():
    # every live slot busy and no survivor headroom: no drainable victim
    eng = sim_fleet(2, capacity=2, lengths={u: 30 for u in range(4)})
    eng.submit([BufferEntry(uid=u, prompt=[1, 2, 3]) for u in range(4)],
               version=0)
    asc = Autoscaler(ConstantPolicy(-1), cooldown=0.0, confirm_steps=1)
    for _ in range(4):
        eng.step()
        asc.tick(eng)
    assert not asc.events and sum(eng.alive) == 2, \
        "shedding a full fleet would re-roll live work for nothing"


# -- warm scale_up: version sync, mixed capacity, routing ---------------------

def drain(eng, buf=None):
    done, steps = [], 0
    while eng.active_uids():
        for ev in eng.step():
            if buf is not None:
                buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
            if ev.done:
                done.append(ev.uid)
        steps += 1
        assert steps < 500
    return done


def test_scale_up_mints_warm_mixed_capacity_replica():
    eng = sim_fleet(2, capacity=2)
    eng.sync_weights(3)
    asc = Autoscaler(ConstantPolicy(+1), cooldown=0.0, confirm_steps=1,
                     factory=lambda i: make_sim(capacity=5, seed=10 + i),
                     max_replicas=3)
    ev = asc.tick(eng)
    assert ev is not None and ev.direction == +1 and ev.replica == 2
    new = eng.replicas[2]
    assert new.capacity == 5, "mixed cap_total fleets are allowed"
    assert new.version == 3, "minted replicas join at the group version"
    assert eng.capacity == 9 and eng.free_slots() == 9
    # the grown, heterogeneous fleet still takes and finishes a full wave
    wave = [BufferEntry(uid=u, prompt=[1, 2, 3]) for u in range(9)]
    eng.submit(wave, version=3)
    assert eng.free_slots() == 0
    assert sorted(drain(eng)) == list(range(9))


@pytest.mark.parametrize("balancer", ["round_robin", "weighted_tokens"])
def test_routing_spreads_across_grown_fleet(balancer):
    eng = sim_fleet(2, capacity=2, balancer=balancer)
    asc = Autoscaler(ConstantPolicy(+1), cooldown=0.0, confirm_steps=1,
                     factory=lambda i: make_sim(capacity=2, seed=10 + i),
                     max_replicas=3)
    asc.tick(eng)
    assert len(eng.replicas) == 3
    eng.submit([BufferEntry(uid=u, prompt=[1, 2, 3]) for u in range(6)],
               version=0)
    for i in range(3):
        assert eng.replicas[i].active_uids(), \
            f"{balancer} left grown replica {i} idle under a full wave"
    assert sorted(drain(eng)) == list(range(6))


def test_scale_up_after_kill_restores_same_fleet_size():
    eng = sim_fleet(2, capacity=2, lengths={u: 6 for u in range(4)})
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    uids = buf.load_prompts([[1, 2, 3, 4 + i] for i in range(4)])
    buf.mark_running(uids)
    eng.submit(buf.running(), version=0)
    eng.step()
    eng._apply_fault(FaultEvent(step=1, replica=1, kind="kill"))
    assert sum(eng.alive) == 1
    idx = eng.scale_up(make_sim(capacity=2, seed=9,
                                lengths={u: 6 for u in range(4)}))
    assert idx == 2 and sum(eng.alive) == 2, \
        "scale_up right after a kill restores the fleet size"
    assert eng.capacity == 4
    # the kill's orphans resubmit (rehomed-resident or re-rolled) and the
    # whole wave still completes exactly once on the reshaped fleet
    for uid in eng.take_failed_uids():
        buf.scavenge(uid)
    resubmit = buf.pending()
    if resubmit:
        buf.mark_running([e.uid for e in resubmit])
        eng.submit(resubmit, version=0)
    done = drain(eng, buf)
    assert sorted(done) == sorted(uids)
    assert not eng.replicas[1].active_uids(), "fenced replica holds nothing"
    st = eng.cache_stats()
    assert st["replica_deaths"] == 1.0 and st["scale_events"] >= 1.0


# -- the scheduling contract under autoscaling, replica-swept -----------------

_DRIVE_CACHE = {}


def autoscaled_drive(policy_name, n_replicas, seed=0):
    key = (policy_name, n_replicas, seed)
    if key not in _DRIVE_CACHE:
        _DRIVE_CACHE[key] = _autoscaled_drive(policy_name, n_replicas, seed)
    return _DRIVE_CACHE[key]


def _autoscaled_drive(policy_name, n_replicas, seed, n_groups=2):
    cap = CAPACITY // n_replicas

    def mk(i):
        return SimEngine(capacity=cap, max_gen_len=MAX_GEN, seed=seed + i,
                         kv_residency=True,
                         length_sampler=lognormal_lengths(
                             median=3, sigma=0.8, max_len=MAX_GEN))

    eng = EngineGroup([mk(i) for i in range(n_replicas)],
                      migrate_kv=True, elastic=True)
    asc = Autoscaler(ThrashPolicy(), factory=mk, min_replicas=1,
                     max_replicas=n_replicas + 2, window=0.05,
                     cooldown=0.0, confirm_steps=1)
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=CAPACITY,
                         group_size=GROUP, update_batch=CAPACITY,
                         max_gen_len=MAX_GEN)
    batches = []

    def train_fn(req: UpdateRequest):
        batches.append((list(req.entries), req.group_epoch))

    orch = RolloutOrchestrator(eng, buf, cfg, make_policy(policy_name),
                               train_fn, autoscaler=asc)
    if policy_name == "pipelined":
        for g in range(n_groups):
            orch.policy.queue_group(prompts(N_PROMPTS, start=g))
        orch.run_queued()
    else:
        for g in range(n_groups):
            orch.run_group(prompts(N_PROMPTS, start=g))
    return orch, batches, asc, n_groups * N_PROMPTS


@pytest.fixture(params=["sorted", "pipelined"])
def sched_name(request):
    return request.param


@pytest.fixture(params=[2, 4])
def n_replicas(request):
    return request.param


def test_autoscaled_conservation(sched_name, n_replicas):
    """Scale churn loses no uid and duplicates none — and the thrashing
    policy did churn the fleet."""
    orch, batches, asc, loaded = autoscaled_drive(sched_name, n_replicas)
    assert asc.events, "the thrash policy must actually drive scale events"
    uids = [e.uid for b, _ in batches for e in b]
    assert len(uids) == len(set(uids)), "an entry trained twice"
    assert sorted(uids) == list(range(loaded))


def test_autoscaled_group_barrier(sched_name, n_replicas):
    orch, batches, _, _ = autoscaled_drive(sched_name, n_replicas)
    lifecycles = [e.lifecycle for b, _ in batches for e in b]
    assert lifecycles == sorted(lifecycles), \
        "a scale event let a later group train before an earlier one"
    if orch.policy.strict_group_barrier:
        for b, epoch in batches:
            assert all(e.lifecycle == epoch for e in b)


def test_autoscaled_fleet_drains_within_bounds(sched_name, n_replicas):
    orch, _, asc, _ = autoscaled_drive(sched_name, n_replicas)
    orch.buffer.check_invariants()
    assert orch.buffer.group_clear()
    assert orch.engine.free_slots() == orch.engine.capacity
    assert asc.min_replicas <= sum(orch.engine.alive)
    assert sum(orch.engine.alive) <= asc.max_replicas
    for i, r in enumerate(orch.engine.replicas):
        if not orch.engine.alive[i]:
            assert not r.active_uids(), "fenced replica still holds work"


def test_autoscaled_run_is_deterministic():
    a = _autoscaled_drive("sorted", 2, seed=7)
    b = _autoscaled_drive("sorted", 2, seed=7)
    assert a[2].events == b[2].events, "scale-event logs must reproduce"
    assert [[e.uid for e in bt] for bt, _ in a[1]] == \
           [[e.uid for e in bt] for bt, _ in b[1]]
    assert a[0].engine.clock == b[0].engine.clock
    assert a[0].metrics.tokens_generated == b[0].metrics.tokens_generated


# -- the builtin signals end to end -------------------------------------------

def test_bubble_target_sheds_the_drain_tail_to_the_floor():
    """One straggler past a short bulk: the windowed bubble crosses the
    high-water mark during the drain and the controller sheds every idle
    replica down to min_replicas — while all work still trains.  Eq. 4
    counts idle slots on *running* replicas, so the replicas need spare
    capacity (cap 2, one straggler) for the signal to register."""
    lengths = {0: 12, 1: 2, 2: 2, 3: 2}
    eng = sim_fleet(4, capacity=2, max_gen=16, lengths=lengths)
    asc = Autoscaler("bubble_target", min_replicas=1, window=0.1,
                     cooldown=0.0, confirm_steps=1,
                     policy_kwargs=dict(high=0.3, low=0.0))
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=4, group_size=2,
                         update_batch=4, max_gen_len=16)
    batches = []
    orch = RolloutOrchestrator(eng, buf, cfg, make_policy("sorted"),
                               lambda req: batches.append(list(req.entries)),
                               autoscaler=asc)
    orch.run_group([[1, 1, 1, 2 + i] for i in range(4)])
    downs = [e for e in asc.events if e.direction < 0]
    assert downs, "the drain tail must trigger sheds"
    assert all(e.window_bubble >= 0.3 for e in downs)
    assert sum(eng.alive) == 1, "idle replicas shed to the floor"
    assert sorted(e.uid for b in batches for e in b) == [0, 1, 2, 3]


def test_bubble_target_grows_under_starved_pending_work():
    def mk(i):
        return make_sim(capacity=2, seed=i, max_gen=8,
                        lengths={u: 6 for u in range(6)})

    eng = EngineGroup([mk(0)], elastic=True, migrate_kv=True)
    asc = Autoscaler("bubble_target", factory=mk, max_replicas=3,
                     window=0.5, cooldown=0.0, confirm_steps=2)
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=6, group_size=2,
                         update_batch=6, max_gen_len=8)
    batches = []
    orch = RolloutOrchestrator(eng, buf, cfg, make_policy("sorted"),
                               lambda req: batches.append(list(req.entries)),
                               autoscaler=asc)
    orch.run_group([[1, 1, 1, 2 + i] for i in range(6)])
    ups = [e for e in asc.events if e.direction > 0]
    assert ups, "pending work starved of slots on a hot fleet must grow it"
    assert len(eng.replicas) > 1
    assert sorted(e.uid for b in batches for e in b) == list(range(6))


def test_queue_depth_scales_serving_fleet_and_conserves_requests():
    def mk(i):
        return SimEngine(capacity=2, max_gen_len=64, seed=3 + i,
                         length_sampler=lognormal_lengths(
                             median=8.0, sigma=1.0, max_len=64))

    eng = EngineGroup([mk(0), mk(1)], elastic=True)
    asc = Autoscaler("queue_depth", factory=mk, min_replicas=1,
                     max_replicas=4, window=1.0, cooldown=0.5,
                     policy_kwargs=dict(wait_frac=0.5, target_wait=2.0,
                                        idle_bubble=0.5))
    tenants = (TenantSpec("batch", weight=1.0, queue_capacity=512),
               TenantSpec("interactive", weight=8.0, latency_slo=1.0,
                          queue_capacity=512))
    ingress = Ingress(tenants, BurstyArrivals(
        {"batch": 120.0, "interactive": 15.0}, seed=11,
        on_time=0.3, off_time=0.7))
    policy = ServingPolicy(inner="sorted", admission="slo_aware",
                           ingress=ingress)
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=4, group_size=1,
                         update_batch=4, max_gen_len=64)
    orch = ServingOrchestrator(eng, buf, cfg, policy, lambda req: None,
                               autoscaler=asc)
    orch.run_for(n_arrivals=80)
    assert any(e.direction > 0 for e in asc.events), \
        "backlog age under SLO pressure must add replicas"
    assert 1 <= sum(eng.alive) <= 4
    for name, t in orch.metrics.tenant_summary().items():
        assert t["arrivals"] == t["completed"] + t["shed"], (name, t)


def test_session_wires_autoscaler_and_replica_factory():
    from repro.rl.session import RLSession, SessionConfig
    cfg = SessionConfig(task="logic", policy="sorted", engine="sim",
                        num_replicas=2, rollout_batch=8, update_batch=8,
                        group_size=2, n_groups=1, mode=Mode.PARTIAL,
                        max_gen_len=32, autoscaler="bubble_target",
                        autoscaler_kwargs={"high": 0.6},
                        autoscaler_window=0.5, min_replicas=1,
                        max_replicas=4)
    sess = RLSession.from_config(cfg)
    asc = sess.orchestrator.autoscaler
    assert asc is not None and asc.policy.name == "bubble_target"
    assert asc.policy.high == 0.6
    assert asc.min_replicas == 1 and asc.max_replicas == 4
    assert asc.window.span == 0.5
    assert sess.engine.elastic, "an autoscaler implies an elastic group"
    # the factory mints warm shard-sized replicas through the same
    # closure that built the starting fleet
    minted = asc.factory(len(sess.engine.replicas))
    assert minted.capacity == cfg.rollout_batch // cfg.num_replicas
    sess.run()          # the wired session still trains end to end


def test_session_autoscaler_forces_group_even_for_one_replica():
    from repro.rl.session import RLSession, SessionConfig
    cfg = SessionConfig(task="logic", engine="sim", num_replicas=1,
                        rollout_batch=8, update_batch=8, n_groups=1,
                        max_gen_len=32, autoscaler="bubble_target")
    sess = RLSession.from_config(cfg)
    assert isinstance(sess.engine, EngineGroup), \
        "scaling needs a group: a bare engine cannot add replicas"
    assert sess.engine.elastic
    assert sess.orchestrator.autoscaler.max_replicas == 1


# -- signal-accounting regression pins ----------------------------------------

def test_serving_bubble_counts_distinct_busy_slots():
    """Async micro-steps emit >1 event per uid per group step; the bubble
    attribution must count distinct busy slots, not events, or idle time
    clamps to zero and tenants are never charged."""
    eng = EngineGroup([SimEngine(capacity=2, max_gen_len=8, seed=i)
                       for i in range(2)], async_step=True)
    ingress = Ingress((TenantSpec("batch", queue_capacity=8),),
                      TraceArrivals([(0.0, "batch", [1, 2, 3])]))
    policy = ServingPolicy(inner="sorted", admission="fifo", ingress=ingress)
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=4, group_size=1,
                         update_batch=4, max_gen_len=8)
    orch = ServingOrchestrator(eng, buf, cfg, policy, lambda req: None)
    ingress.pump(0.0)                     # a queued, unadmitted arrival
    assert sum(len(q) for q in ingress.queues.values()) == 1
    uid = buf.load_prompts([[1, 2, 3]])[0]
    buf.mark_running([uid])
    ev = StepEvent(uid=uid, token=1, logprob=0.0, done=False)
    # 3 catch-up events for ONE busy slot over 1s of group clock: the
    # other 3 of 4 slots idled while the batch tenant had queued work
    orch._apply_events([ev, ev, ev], t0=eng.clock - 1.0)
    assert orch.metrics.tenant("batch").bubble_time == pytest.approx(3.0)


class MidloopAdmitPolicy(BasePolicy):
    """Admits a second wave after the first decode step and records the
    harvest threshold every harvest_now sees — the stale-threshold pin."""
    name = "midloop_admit"

    def __init__(self):
        self.admitted = False
        self.stepped = False
        self.seen = []

    def admit_next_group(self, view):
        if self.admitted or not self.stepped:
            return None
        self.admitted = True
        return AdmitRequest(prompts=prompts(N_PROMPTS, start=1))

    def harvest_now(self, view) -> bool:
        self.stepped = True
        self.seen.append(view.harvest_threshold)
        return False


def test_harvest_threshold_tracks_midloop_admission():
    """rollout_until_harvest must recompute its threshold every iteration:
    a policy that admits mid-loop (pipelined lookahead, serving ingress)
    grows the unconsumed set, and a threshold frozen at loop entry would
    cap harvests at the stale pre-admission count for the whole epoch."""
    eng = make_sim(capacity=4)
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=CAPACITY,
                         group_size=GROUP, update_batch=2 * N_PROMPTS,
                         max_gen_len=MAX_GEN)
    policy = MidloopAdmitPolicy()
    orch = RolloutOrchestrator(eng, buf, cfg, policy, lambda req: None)
    orch.run_group(prompts(N_PROMPTS))
    assert policy.admitted
    assert policy.seen[0] == N_PROMPTS
    assert max(policy.seen) == 2 * N_PROMPTS, \
        "the threshold must catch up to mid-loop admission"


def test_scale_down_releases_unclaimed_residency():
    """Resident KV no survivor accepts is released explicitly and counted
    in the residency_dropped gauge — not silently wiped by the fence."""
    eng = sim_fleet(2, capacity=1)
    eng.submit([BufferEntry(uid=0, prompt=[1, 2, 3])], version=0)
    home = eng._home[0]
    eng.interrupt([0])                   # uid 0 parks as resident KV
    survivor = 1 - home
    eng.replicas[survivor].import_entry = lambda handle: False
    eng.scale_down(home)
    assert eng.residency_dropped == 1
    assert 0 not in eng._home
    assert eng.cache_stats().get("residency_dropped") == 1.0
    # the gauge flows through the orchestrator metrics unchanged
    m = RolloutMetrics(capacity=eng.capacity)
    m.record_cache(eng.cache_stats())
    assert m.residency_dropped == 1
    assert m.snapshot().get("residency_dropped") == 1


def test_drop_donor_residency_counts_only_real_losses():
    eng = sim_fleet(2, capacity=1)
    eng.submit([BufferEntry(uid=0, prompt=[1, 2, 3])], version=0)
    home = eng._home[0]
    eng.interrupt([0])
    assert eng._drop_donor_residency(home, 0) is True
    assert eng._drop_donor_residency(home, 0) is False, \
        "a second drop holds nothing and must not double-count"
    assert eng._drop_donor_residency(home, 99) is False
    assert eng.residency_dropped == 1


# -- chaos proptest: autoscaler ticks under fault interleavings ---------------

def _tick_op(asc, eng, usel):
    asc.tick(eng, pending=usel % 3, running=len(eng.active_uids()))


def _chaos_autoscaled(eng, mk_replica, ops, invariants):
    asc = Autoscaler(ThrashPolicy(), factory=mk_replica, min_replicas=1,
                     max_replicas=4, window=0.05, cooldown=0.0,
                     confirm_steps=1)
    next_uid = 0
    for op, rsel, usel in ops:
        alive = eng._alive_indices()
        if op == 0 and eng.free_slots() > 0:            # submit fresh work
            e = BufferEntry(uid=next_uid,
                            prompt=[1, 2 + next_uid % 7, 3, 4 + usel % 5])
            next_uid += 1
            eng.submit([e], version=0)
        elif op == 1:                                   # decode step
            eng.step()
        elif op == 2 and eng.active_uids():             # targeted interrupt
            active = sorted(eng.active_uids())
            eng.interrupt([active[usel % len(active)]])
        elif op == 3 and len(alive) > 1:                # fail-stop kill
            eng._apply_fault(FaultEvent(step=1,
                                        replica=alive[rsel % len(alive)],
                                        kind="kill"))
        elif op == 4:                                   # transient stall
            eng._apply_fault(FaultEvent(step=1,
                                        replica=alive[rsel % len(alive)],
                                        kind="stall", duration=1 + usel % 3))
        elif op in (5, 6, 7):                           # autoscaler tick
            _tick_op(asc, eng, usel)
        eng.take_failed_uids()
        invariants(eng)
    return asc


def _sim_fleet_ok(eng):
    assert 1 <= sum(eng.alive) <= len(eng.replicas)
    for i, r in enumerate(eng.replicas):
        if not eng.alive[i]:
            assert not r.active_uids(), "fenced replica still decoding"
            assert not r._resident, "fenced replica holds residency"


@cases(max_examples=12,
       ops=lists(tuples(integers(0, 7), integers(0, 3), integers(0, 9)),
                 min_size=6, max_size=26))
def test_autoscaler_chaos_sim_fleet_invariants(ops):
    """Seconds-lane chaos: autoscaler ticks interleaved with submit /
    step / interrupt / kill / stall on a sim fleet — the fleet shape
    stays within bounds and fenced replicas hold nothing."""
    eng = sim_fleet(2, capacity=2)
    _chaos_autoscaled(eng, lambda i: make_sim(capacity=2, seed=50 + i),
                      ops, _sim_fleet_ok)


@pytest.mark.slow
@pytest.mark.chaos
@cases(max_examples=6,
       ops=lists(tuples(integers(0, 7), integers(0, 3), integers(0, 9)),
                 min_size=6, max_size=22))
def test_autoscaler_chaos_slot_fleet_holds_pool_invariants(ops):
    """Real-decode chaos: autoscaler ticks interleaved with kill / stall
    faults on a paged SlotEngine fleet — page-pool refcounts stay
    consistent after every op, fenced replicas hold zero pages, and
    teardown leaks nothing."""
    eng = EngineGroup([make_slot(capacity=2, eos_id=-1) for _ in range(2)],
                      migrate_kv=True, elastic=True)
    _chaos_autoscaled(eng, lambda i: make_slot(capacity=2, eos_id=-1),
                      ops, _fleet_invariants)
    eng.interrupt()
    for i in eng._alive_indices():
        eng.replicas[i].shutdown()
    for r in eng.replicas:
        assert r.kv.pool.pages_in_use == 0, "pages leaked at teardown"
        assert (r.kv.pool.refcount == 0).all()
        assert not r.kv._donors and not r.kv._donor_keys
