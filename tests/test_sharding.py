"""Unit tests for update-batch data sharding
(repro.distributed.sharding: data_shard_count / pad_update_batch /
shard_update_batch) and its wiring into entries_to_batch.

Runs on however many CPU devices the test process has (usually 1): the
mesh is built over the available devices, so the padding/placement logic
is exercised without requiring a multi-chip host.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.sharding import (axis_rules, data_shard_count,
                                        pad_update_batch, shard_update_batch)


def _mesh():
    from jax.sharding import Mesh
    devs = np.array(jax.devices()).reshape(-1)
    return Mesh(devs, ("data",))


def _batch(B, W=8, pad=7):
    return {
        "tokens": jnp.full((B, W), 3, jnp.int32),
        "loss_mask": jnp.ones((B, W), jnp.float32),
        "advantages": jnp.ones((B,), jnp.float32),
    }


def test_shard_count_outside_context():
    assert data_shard_count() == 1


def test_shard_count_under_rules():
    mesh = _mesh()
    with axis_rules(mesh, {"batch": "data"}):
        assert data_shard_count() == mesh.shape["data"]
    with axis_rules(mesh, {"batch": None}):
        assert data_shard_count() == 1      # replicated batch: one slice
    assert data_shard_count() == 1          # context restored


def test_pad_update_batch_inert_rows():
    b = pad_update_batch(_batch(5), multiple=4, pad_token=7)
    assert all(x.shape[0] == 8 for x in b.values())
    # pad rows are inert: tokens all pad_token, everything else zero
    assert np.all(np.asarray(b["tokens"])[5:] == 7)
    assert np.all(np.asarray(b["loss_mask"])[5:] == 0.0)
    assert np.all(np.asarray(b["advantages"])[5:] == 0.0)
    # real rows untouched
    assert np.all(np.asarray(b["tokens"])[:5] == 3)


def test_pad_update_batch_identity_when_aligned():
    b = _batch(8)
    assert pad_update_batch(b, multiple=4) is b
    assert pad_update_batch(b, multiple=1) is b
    assert pad_update_batch(b, multiple=0) is b


def test_shard_update_batch_identity_outside_context():
    b = _batch(5)
    assert shard_update_batch(b) is b


def test_shard_update_batch_places_and_pads():
    mesh = _mesh()
    n = mesh.shape["data"]
    with axis_rules(mesh, {"batch": "data"}):
        out = shard_update_batch(_batch(5), pad_token=7)
    B = out["tokens"].shape[0]
    assert B % n == 0 and B >= 5
    for x in out.values():
        assert x.sharding.mesh.shape == mesh.shape
    # values survive placement
    assert np.all(np.asarray(out["tokens"])[:5] == 3)


def test_entries_to_batch_shards_under_rules():
    """entries_to_batch routes through shard_update_batch: under rules the
    batch comes back padded to the shard count with an inert loss mask on
    pad rows, so the loss and advantage statistics see only real rows."""
    from repro.core.buffer import BufferEntry, EntryState
    from repro.rl.trainer import entries_to_batch

    def entry(uid, gen):
        return BufferEntry(uid=uid, prompt=[1, 2, 3], meta=None,
                           generated=list(gen),
                           logprobs=[-0.5] * len(gen),
                           versions=[0] * len(gen),
                           state=EntryState.DONE, finish_reason="eos")

    entries = [entry(i, range(4, 4 + i + 1)) for i in range(3)]
    reward = lambda gen, meta: 1.0
    plain, info = entries_to_batch(entries, reward, pad_id=0, max_len=64,
                                   current_version=0)
    mesh = _mesh()
    with axis_rules(mesh, {"batch": "data"}):
        sharded, info2 = entries_to_batch(entries, reward, pad_id=0,
                                          max_len=64, current_version=0)
    assert info == info2                       # stats ignore pad rows
    n = mesh.shape["data"]
    want = plain["tokens"].shape[0] + (-3) % n
    assert sharded["tokens"].shape[0] == want
    real = np.asarray(sharded["loss_mask"])[:3]
    assert np.array_equal(real, np.asarray(plain["loss_mask"]))
    assert np.all(np.asarray(sharded["loss_mask"])[3:] == 0.0)
    with pytest.raises(KeyError):
        sharded["nope"]
