"""SlotEngine behaviour (real JAX decode), data generators/verifiers,
optimizer, and checkpoint round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
from proptest import cases, integers

from repro.core.buffer import BufferEntry, Mode
from repro.data import logic, math_synth
from repro.models.model import build_model
from repro.rollout.engine import SlotEngine
from repro.train.loop import tiny_lm_config

KEY = jax.random.PRNGKey(0)


def _tiny():
    cfg = tiny_lm_config(len(logic.VOCAB), d_model=64, layers=2, heads=2)
    m = build_model(cfg)
    return m, m.init_params(KEY)


def test_engine_greedy_matches_direct_decode():
    """Greedy generation through the slot engine == hand-rolled decode."""
    m, params = _tiny()
    vocab = logic.VOCAB
    prompt = [vocab.bos_id, 7, 8, 9]
    eng = SlotEngine(m, lambda: params, capacity=4, max_total_len=64,
                     max_gen_len=8, eos_id=vocab.eos_id,
                     pad_id=vocab.pad_id, temperature=0.0)
    e = BufferEntry(uid=0, prompt=list(prompt))
    eng.submit([e], 0)
    toks, lps = [], []
    while eng.active_uids():
        for ev in eng.step():
            toks.append(ev.token)
            lps.append(ev.logprob)
    # direct: repeated full forward, argmax
    cur = list(prompt)
    want = []
    for _ in range(len(toks)):
        logits, _ = m.forward(params, {"tokens": jnp.asarray([cur])})
        nxt = int(jnp.argmax(logits[0, -1]))
        want.append(nxt)
        cur.append(nxt)
        if nxt == vocab.eos_id:
            break
    assert toks == want
    assert all(np.isfinite(lps))


def test_engine_slot_reuse_and_interrupt():
    m, params = _tiny()
    vocab = logic.VOCAB
    eng = SlotEngine(m, lambda: params, capacity=2, max_total_len=48,
                     max_gen_len=4, eos_id=-1, pad_id=vocab.pad_id,
                     temperature=1.0)
    es = [BufferEntry(uid=i, prompt=[vocab.bos_id, 5 + i]) for i in range(2)]
    eng.submit(es, 0)
    assert eng.free_slots() == 0
    eng.step()
    out = eng.interrupt()
    assert sorted(out) == [0, 1]
    assert eng.free_slots() == 2
    # slots are reusable after interruption
    eng.submit([BufferEntry(uid=9, prompt=[vocab.bos_id, 3])], 1)
    evs = eng.step()
    assert evs[0].uid == 9


def test_engine_partial_resume_prefix_consistency():
    """Submitting an entry with a scavenged prefix continues from exactly
    that prefix (greedy continuation matches an uninterrupted run when
    weights don't change)."""
    m, params = _tiny()
    vocab = logic.VOCAB
    prompt = [vocab.bos_id, 11, 12]

    def gen(max_gen, entry):
        eng = SlotEngine(m, lambda: params, capacity=1, max_total_len=64,
                         max_gen_len=max_gen, eos_id=-1,
                         pad_id=vocab.pad_id, temperature=0.0)
        eng.submit([entry], 0)
        toks = []
        while eng.active_uids():
            for ev in eng.step():
                toks.append(ev.token)
        return toks

    full = gen(8, BufferEntry(uid=0, prompt=list(prompt)))
    first = gen(4, BufferEntry(uid=1, prompt=list(prompt)))
    # NB max_gen_len is the TOTAL per-trajectory budget: the resumed entry
    # already carries 4 generated tokens, so the budget must be 8
    resumed = gen(8, BufferEntry(uid=2, prompt=list(prompt),
                                 generated=list(first),
                                 logprobs=[-1.0] * 4, versions=[0] * 4))
    assert first + resumed == full


# -- data ---------------------------------------------------------------------

def test_puzzle_unique_and_verifier():
    import random
    rng = random.Random(0)
    for n in (3, 4, 5):
        pz = logic.generate_puzzle(rng, n)
        assert pz.unique()
        meta = logic.LogicMeta(solution=pz.solution, n=n)
        perfect = logic.encode_solution(pz)
        assert logic.verify(perfect, meta) >= 2.0 - 1e-6
        wrong = list(perfect)
        # flip one role token
        for i, t in enumerate(wrong):
            w = logic.VOCAB.itos[t]
            if w in logic.ROLES:
                wrong[i] = logic.VOCAB.stoi[
                    logic.ROLES[1 - logic.ROLES.index(w)]]
                break
        assert logic.verify(wrong, meta) < logic.verify(perfect, meta)
        assert logic.verify([], meta) == 0.0


@cases(max_examples=30, seed=integers(0, 10_000))
def test_puzzle_statements_consistent(seed):
    import random
    rng = random.Random(seed)
    pz = logic.generate_puzzle(rng, rng.randint(3, 6))
    assert pz.consistent(pz.solution)


def test_math_verifier():
    import random
    rng = random.Random(1)
    toks, meta = math_synth.generate(rng, 2)
    v = math_synth.MATH_VOCAB
    good = v.encode([str(meta.answer), "<eos>"])
    assert math_synth.verify(good, meta) >= 1.2 - 1e-6
    bad = v.encode([str((meta.answer + 1) % 10), "<eos>"])
    assert math_synth.verify(bad, meta) < 1.0


# -- optimizer / checkpoint ----------------------------------------------------

def test_adamw_converges_quadratic():
    from repro.train.optimizer import (AdamWConfig, adamw_update,
                                       init_opt_state)
    cfg = AdamWConfig(lr=0.1, grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, m = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert float(m["grad_norm"]) < 1.0


def test_grad_clip():
    from repro.train.optimizer import (AdamWConfig, adamw_update,
                                       init_opt_state)
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params, cfg)
    _, _, m = adamw_update(params, {"w": jnp.full(3, 100.0)}, opt, cfg)
    assert float(m["grad_norm"]) > 100


def test_checkpoint_roundtrip(tmp_path):
    from repro.train import checkpoint as ck
    from repro.train.optimizer import AdamWConfig, init_opt_state
    m, params = _tiny()
    opt = init_opt_state(params, AdamWConfig())
    path = str(tmp_path / "ckpt.npz")
    ck.save(path, params, opt, meta={"step": 3})
    tmpl_p = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    tmpl_o = jax.tree.map(lambda x: jnp.zeros_like(x), opt)
    p2, o2 = ck.restore(path, tmpl_p, tmpl_o)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hlo_cost_trip_counts():
    from repro.launch.hlo_cost import analyse_hlo

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=13)
        return c

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(scanned).lower(x, w).compile().as_text()
    c = analyse_hlo(txt)
    expect = 13 * (2 * 128 ** 3)
    assert 0.95 < c["flops"] / expect < 1.1


def test_grouped_loader():
    from repro.data.loader import GroupedLoader
    gen = logic.LogicTaskGenerator(seed=4)
    loader = GroupedLoader(gen, rollout_batch=8, group_size=2,
                           responses_per_prompt=2)
    prompts, metas = loader.next_group()
    assert len(prompts) == loader.prompts_per_group == 16
    # duplicated prompts share prompt_id (multi-response groups)
    ids = [m.prompt_id for m in metas]
    assert ids[0] == ids[1] and ids[0] != ids[2]
    assert loader.groups_served == 1
    p, m = next(loader.stream())
    assert isinstance(p, list) and m is not None


def test_math_rl_end_to_end():
    """§4.3 analog pipeline (integer-math verification) runs end to end."""
    from repro.core.buffer import Mode
    from repro.train.loop import RLExperimentConfig, run_math_rl
    cfg = RLExperimentConfig(strategy="sorted", mode=Mode.ON_POLICY,
                             rollout_batch=8, group_size=1, update_batch=8,
                             n_groups=1, sft_steps=20, d_model=64, layers=2,
                             eval_size=8, eval_every=100, max_gen_len=6,
                             max_total_len=64)
    out = run_math_rl(cfg)
    assert out["rollout_metrics"]["updates"] >= 1
    assert 0.0 <= out["final_eval"]["reward_mean"] <= 1.2
