import os
# Tests run single-device (the dry-run sets 512 host devices in its own
# process only).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
