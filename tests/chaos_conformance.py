"""Chaos-conformance suite: the scheduling contract under replica loss.

Every registered policy is driven through the shared RolloutOrchestrator
against an EngineGroup whose FaultInjector kills one replica mid-group
(step 3, while the first wave is in flight), over both engine backends
(discrete-event SimEngine and real-decode SlotEngine) and replica counts
{2, 4}.  With ``migrate_kv=True`` the group re-homes the dead replica's
in-flight entries onto survivors (resident-KV migration when no slot is
free), so the full contract must survive the fault:

  * conservation — no uid is lost or duplicated: every loaded prompt is
    still trained exactly once, re-rolls included;
  * group barrier — trained lifecycles never decrease and strict
    policies never mix epochs, even when a kill forces re-scheduling;
  * drain — the fleet ends empty on the surviving replicas and the
    death/re-home/re-roll counters record exactly what happened;
  * zero re-prefill — entries re-homed with migration resume from their
    migrated KV: total prefill work equals the no-fault workload.

Also hosts the chaos proptest: seeded random interleavings of
submit/step/interrupt/kill/stall/scale_down/scale_up against a two-pool
SlotEngine fleet, holding page-pool invariants (refcounts == tables,
donor-index consistency, zero leaked pages on fenced replicas) after
every operation — the PR-4/5 KV interleaving suites, fleet-level.
"""
import pytest

from engine_conformance import _tiny_model, make_slot
from policy_conformance import CAPACITY, GROUP, MAX_GEN, N_PROMPTS, prompts
from proptest import cases, integers, lists, tuples
from repro.core.buffer import BufferEntry, EntryState, Mode, \
    StatefulRolloutBuffer
from repro.core.engine_api import FaultEvent, FaultInjector
from repro.core.orchestrator import (RolloutOrchestrator, SortedRLConfig,
                                     UpdateRequest)
from repro.core.policy import available_policies, make_policy
from repro.rollout.group import EngineGroup
from repro.rollout.sim import SimEngine, lognormal_lengths
from test_kv_cache import _donor_index_consistent

pytestmark = pytest.mark.chaos

KILL_STEP = 3        # first wave is in flight: the dead replica is busy


def kill_last(n_replicas):
    """One fail-stop kill of the highest-index replica mid-group."""
    return FaultInjector([FaultEvent(step=KILL_STEP, replica=n_replicas - 1,
                                     kind="kill")])


def make_chaos_sim(n_replicas, migrate=True):
    return EngineGroup(
        [SimEngine(capacity=CAPACITY // n_replicas, max_gen_len=MAX_GEN,
                   seed=i, kv_residency=True,
                   length_sampler=lognormal_lengths(median=3, sigma=0.8,
                                                    max_len=MAX_GEN))
         for i in range(n_replicas)],
        migrate_kv=migrate, fault_injector=kill_last(n_replicas))


def make_chaos_slot(n_replicas):
    return EngineGroup(
        [make_slot(capacity=CAPACITY // n_replicas) for _ in range(n_replicas)],
        migrate_kv=True, fault_injector=kill_last(n_replicas))


CHAOS_FACTORIES = {
    "sim2": lambda: make_chaos_sim(2),
    "sim4": lambda: make_chaos_sim(4),
    "slot2": lambda: make_chaos_slot(2),
    "slot4": lambda: make_chaos_slot(4),
}
N_REPLICAS = {"sim2": 2, "sim4": 4, "slot2": 2, "slot4": 4}
# jit-heavy real-decode sweeps stay out of the seconds-scale lane
_PARAMS = [name if name.startswith("sim")
           else pytest.param(name, marks=pytest.mark.slow)
           for name in sorted(CHAOS_FACTORIES)]


def build(policy_name, engine_name, mode=Mode.PARTIAL, **policy_kwargs):
    eng = CHAOS_FACTORIES[engine_name]()
    buf = StatefulRolloutBuffer(mode)
    cfg = SortedRLConfig(mode=mode, rollout_batch=CAPACITY,
                         group_size=GROUP, update_batch=CAPACITY,
                         max_gen_len=MAX_GEN)
    policy = make_policy(policy_name, **policy_kwargs)
    batches = []

    def train_fn(req: UpdateRequest):
        batches.append((list(req.entries), req.group_epoch))

    return RolloutOrchestrator(eng, buf, cfg, policy, train_fn), batches


_DRIVE_CACHE = {}


def drive(policy_name, engine_name, n_groups=2):
    key = (policy_name, engine_name, n_groups)
    if key not in _DRIVE_CACHE:
        _DRIVE_CACHE[key] = _drive(policy_name, engine_name, n_groups)
    return _DRIVE_CACHE[key]


def _drive(policy_name, engine_name, n_groups):
    if policy_name == "ungrouped":
        stream = iter([(p, None) for p in prompts(n_groups * N_PROMPTS)])
        orch, batches = build(policy_name, engine_name,
                              prompt_stream=stream)
        orch.run_steps(n_updates=n_groups * GROUP)
        loaded = len(orch.buffer.entries)
    elif policy_name == "pipelined":
        orch, batches = build(policy_name, engine_name)
        for g in range(n_groups):
            orch.policy.queue_group(prompts(N_PROMPTS, start=g))
        orch.run_queued()
        loaded = n_groups * N_PROMPTS
    else:
        orch, batches = build(policy_name, engine_name)
        for g in range(n_groups):
            orch.run_group(prompts(N_PROMPTS, start=g))
        loaded = n_groups * N_PROMPTS
    return orch, batches, loaded


@pytest.fixture(params=_PARAMS)
def engine_name(request):
    return request.param


@pytest.fixture(params=available_policies())
def policy_name(request):
    return request.param


# -- the contract under a mid-group kill, every policy x backend x fleet ------

def test_chaos_conservation(policy_name, engine_name):
    """A replica death loses no uid and duplicates none."""
    orch, batches, loaded = drive(policy_name, engine_name)
    uids = [e.uid for b, _ in batches for e in b]
    assert len(uids) == len(set(uids)), "an entry trained twice after a kill"
    if policy_name == "ungrouped":
        consumed = {u for u, e in orch.buffer.entries.items()
                    if e.state == EntryState.CONSUMED}
        assert set(uids) == consumed
        assert len(uids) + sum(
            e.state != EntryState.CONSUMED
            for e in orch.buffer.entries.values()) == loaded
    else:
        assert sorted(uids) == list(range(loaded)), \
            "a kill must not lose or duplicate any loaded prompt"


def test_chaos_group_barrier(policy_name, engine_name):
    orch, batches, _ = drive(policy_name, engine_name)
    if policy_name == "ungrouped":
        return   # explicitly barrier-free
    lifecycles = [e.lifecycle for b, _ in batches for e in b]
    assert lifecycles == sorted(lifecycles), \
        "a kill let a later group train before an earlier one"
    if orch.policy.strict_group_barrier:
        for b, epoch in batches:
            assert all(e.lifecycle == epoch for e in b), \
                "strict policy mixed group epochs after a kill"


def test_chaos_death_recorded_and_fleet_drains(policy_name, engine_name):
    orch, batches, loaded = drive(policy_name, engine_name)
    st = orch.engine.cache_stats()
    assert st["replica_deaths"] == 1.0
    assert st["alive_replicas"] == N_REPLICAS[engine_name] - 1
    # the dying replica was mid-wave: its in-flight work was re-homed
    # (migrate_kv=True) or released for a re-roll — never dropped
    assert st["rehomed_entries"] + st["rerolled_entries"] >= 1
    # survivors drain the whole workload
    assert orch.engine.free_slots() == orch.engine.capacity
    if policy_name != "ungrouped":
        assert orch.buffer.group_clear()
        assert sum(len(b) for b, _ in batches) == loaded
    # counters surfaced through the orchestrator's metrics
    assert orch.metrics.replica_deaths == 1


def test_chaos_buffer_invariants(policy_name, engine_name):
    orch, _, _ = drive(policy_name, engine_name)
    orch.buffer.check_invariants()


# -- zero re-prefill for re-homed-with-migration entries ----------------------

def test_sim_rehome_resumes_with_zero_reprefill():
    """migrate_kv=True: total prefill work equals the no-fault workload —
    the dead replica's entries resume from migrated KV, not a re-run."""
    orch, _, loaded = drive("sorted", "sim4")
    st = orch.engine.cache_stats()
    assert st["rehomed_entries"] >= 1
    assert st["rerolled_entries"] == 0
    plen = len(prompts(1)[0])
    assert st["prefill_tokens_run"] == loaded * plen, \
        "re-homed entries must not pay a second prefill"


def test_sim_kill_without_migration_rerolls():
    """migrate_kv=False models hard KV loss: the dead replica's in-flight
    entries are released and re-rolled under the current policy version."""
    eng = make_chaos_sim(2, migrate=False)
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=CAPACITY,
                         group_size=GROUP, update_batch=CAPACITY,
                         max_gen_len=MAX_GEN)
    batches = []
    orch = RolloutOrchestrator(eng, buf, cfg, make_policy("sorted"),
                               lambda req: batches.append(list(req.entries)))
    orch.run_group(prompts(N_PROMPTS))
    st = eng.cache_stats()
    assert st["replica_deaths"] == 1.0
    assert st["rerolled_entries"] >= 1 and st["rehomed_entries"] == 0
    uids = sorted(e.uid for b in batches for e in b)
    assert uids == list(range(N_PROMPTS)), "re-rolls must conserve uids"
    # the re-rolled prompts paid a second prefill (nothing to resume from)
    plen = len(prompts(1)[0])
    assert st["prefill_tokens_run"] > N_PROMPTS * plen


def _greedy_slot(capacity):
    # temperature 0: the continuation is a pure function of the KV state,
    # so token identity proves the migrated pages are the right pages
    from repro.rollout.engine import SlotEngine
    t = _tiny_model()
    return SlotEngine(t["model"], lambda: t["params"], capacity=capacity,
                      max_total_len=64, max_gen_len=8, eos_id=-1,
                      pad_id=t["pad"], temperature=0.0)


@pytest.mark.slow
def test_slot_kill_rehomes_resident_kv_and_resumes_free():
    """Real-decode fleet: at kill time the survivor is slot-full, so the
    dying replica's entries re-home via RESIDENT-KV migration; once the
    survivor frees slots they resume from the migrated pages with zero
    re-prefill and token-identical continuations."""
    eng = EngineGroup([_greedy_slot(capacity=2) for _ in range(2)],
                      migrate_kv=True,
                      fault_injector=kill_last(2))
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    ps = [[1, 2, 3, 4 + i] for i in range(4)]
    uids = buf.load_prompts(ps)
    buf.mark_running(uids)
    eng.submit(buf.running(), version=0)
    victims = sorted(u for u, h in dict(eng._home).items() if h == 1)
    assert victims, "replica 1 must hold part of the wave"

    def pump():
        for ev in eng.step():
            buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
            if ev.done:
                buf.mark_done(ev.uid, ev.finish_reason)
    pump()                       # step 1
    pump()                       # step 2
    pump()                       # step 3: kill fires before dispatch
    st = eng.cache_stats()
    assert st["replica_deaths"] == 1.0
    assert st["rehomed_entries"] == len(victims), \
        "slot-full survivor: every victim re-homes via resident migration"
    assert st["migrated_pages"] >= 1
    failed = eng.take_failed_uids()
    assert sorted(failed) == victims
    for uid in failed:
        buf.scavenge(uid)        # partial mode: keeps generated tokens
    # drain the survivor's own wave, then resume the re-homed entries
    steps = 0
    while eng.active_uids():
        pump()
        steps += 1
        assert steps < 100
    run_before = eng.cache_stats()["prefill_tokens_run"]
    resumable = [buf.entries[u] for u in victims]
    buf.mark_running(victims)
    eng.submit(resumable, version=0)
    st = eng.cache_stats()
    assert st["prefill_tokens_run"] == run_before, \
        "re-homed-with-migration entries must resume at zero re-prefill"
    assert st["resumed_without_prefill"] >= len(victims)
    steps = 0
    while eng.active_uids():
        pump()
        steps += 1
        assert steps < 100
    # token identity: the migrated continuation matches an undisturbed run
    solo = _greedy_slot(capacity=4)
    ref = {}
    solo.submit([BufferEntry(uid=100 + i, prompt=list(p))
                 for i, p in enumerate(ps)], version=0)
    while solo.active_uids():
        for ev in solo.step():
            ref.setdefault(ev.uid - 100, []).append(ev.token)
    for i, u in enumerate(uids):
        assert list(buf.entries[u].generated) == ref[i], \
            f"uid {u}: kill+re-home changed the token stream"
    for i in eng._alive_indices():
        eng.replicas[i].kv.check_invariants()


# -- chaos proptest: random fault interleavings on a two-pool fleet -----------

def _fleet_invariants(eng):
    for i, r in enumerate(eng.replicas):
        if eng.alive[i]:
            r.kv.check_invariants()        # refcounts == page tables
            _donor_index_consistent(r.kv)
        else:
            assert r.kv.pool.pages_in_use == 0, \
                f"fenced replica {i} leaked pages after re-homing"
            assert not r.kv._donors and not r.kv._donor_keys


@pytest.mark.slow
@cases(max_examples=8,
       ops=lists(tuples(integers(0, 6), integers(0, 3), integers(0, 9)),
                 min_size=6, max_size=26))
def test_chaos_random_interleavings_hold_pool_invariants(ops):
    """Random interleavings of submit/step/interrupt/kill/stall/
    scale_down/scale_up against a two-pool SlotEngine fleet: after every
    operation each survivor's page pool stays internally consistent and
    fenced replicas hold zero pages; final shutdown leaks nothing."""
    eng = EngineGroup([make_slot(capacity=2, eos_id=-1) for _ in range(2)],
                      migrate_kv=True, elastic=True)
    next_uid = 0
    for op, rsel, usel in ops:
        alive = eng._alive_indices()
        if op == 0 and eng.free_slots() > 0:            # submit fresh work
            e = BufferEntry(uid=next_uid,
                            prompt=[1, 2 + next_uid % 7, 3, 4 + usel % 5])
            next_uid += 1
            eng.submit([e], version=0)
        elif op == 1:                                   # decode step
            eng.step()
        elif op == 2 and eng.active_uids():             # targeted interrupt
            active = sorted(eng.active_uids())
            eng.interrupt([active[usel % len(active)]])
        elif op == 3 and len(alive) > 1:                # fail-stop kill
            eng._apply_fault(FaultEvent(step=1, replica=alive[rsel % len(alive)],
                                        kind="kill"))
        elif op == 4:                                   # transient stall
            eng._apply_fault(FaultEvent(step=1, replica=alive[rsel % len(alive)],
                                        kind="stall", duration=1 + usel % 3))
        elif op == 5 and len(alive) > 1:                # graceful drain
            eng.scale_down(alive[rsel % len(alive)])
        elif op == 6 and len(eng.replicas) < 4:         # elastic grow
            eng.scale_up(make_slot(capacity=2, eos_id=-1))
        eng.take_failed_uids()      # re-rolls go back to the (absent) buffer
        _fleet_invariants(eng)
    eng.interrupt()                 # actives -> resident
    for i in eng._alive_indices():
        eng.replicas[i].shutdown()
    for r in eng.replicas:
        assert r.kv.pool.pages_in_use == 0, "pages leaked at teardown"
        assert (r.kv.pool.refcount == 0).all()
        assert not r.kv._donors and not r.kv._donor_keys, "donor index leaked"
