"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps,
assert_allclose vs the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (flash_attention, fused_sample,
                               paged_decode_attention,
                               paged_decode_attention_int8,
                               ragged_decode_attention)
from repro.kernels.ref import (KV_INT8_DECODE_ATOL, flash_attention_ref,
                               fused_sample_ref, gather_pages,
                               paged_decode_attention_int8_ref,
                               paged_decode_attention_ref,
                               quantize_pages_ref, ragged_decode_attention_ref)

pytestmark = pytest.mark.slow   # jit-heavy: Pallas interpret-mode sweeps

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,H,Kh,D,S,bk", [
    (4, 8, 2, 64, 256, 128),
    (2, 16, 16, 128, 512, 128),
    (3, 4, 1, 128, 384, 128),
    (1, 8, 4, 256, 256, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_decode_attention(B, H, Kh, D, S, bk, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Kh, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Kh, D), dtype)
    kv_len = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = ragged_decode_attention(q, k, v, kv_len, block_k=bk)
    ref = ragged_decode_attention_ref(q, k, v, kv_len)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_ragged_decode_attention_softcap():
    ks = jax.random.split(KEY, 4)
    B, H, Kh, D, S = 2, 4, 2, 64, 256
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, Kh, D))
    v = jax.random.normal(ks[2], (B, S, Kh, D))
    kv_len = jnp.array([100, 256])
    out = ragged_decode_attention(q, k, v, kv_len, softcap=20.0)
    ref = ragged_decode_attention_ref(q, k, v, kv_len, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_ragged_decode_length_one():
    """kv_len=1 edge: only the first cache row is attended."""
    ks = jax.random.split(KEY, 3)
    B, H, Kh, D, S = 2, 4, 4, 64, 128
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, Kh, D))
    v = jax.random.normal(ks[2], (B, S, Kh, D))
    kv_len = jnp.ones((B,), jnp.int32)
    out = ragged_decode_attention(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(v[:, 0]), atol=1e-5)


@pytest.mark.parametrize("B,H,Kh,D,P,N,nb", [
    (4, 8, 2, 64, 128, 9, 2),
    (2, 16, 16, 128, 128, 17, 3),
    (1, 4, 1, 256, 256, 5, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(B, H, Kh, D, P, N, nb, dtype):
    """Block-table kernel == attention over the gathered dense view."""
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp = jax.random.normal(ks[1], (N, P, Kh, D), dtype)
    vp = jax.random.normal(ks[2], (N, P, Kh, D), dtype)
    bt = jax.random.randint(ks[3], (B, nb), 0, N)
    kv_len = jax.random.randint(ks[4], (B,), 1, nb * P + 1)
    out = paged_decode_attention(q, kp, vp, bt, kv_len)
    ref = paged_decode_attention_ref(q, kp, vp, bt, kv_len)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_paged_matches_dense_on_shared_pages():
    """Two slots mapping the SAME physical prefix pages (GRPO sharing)
    attend exactly as two dense slots holding copies of that prefix."""
    ks = jax.random.split(KEY, 4)
    H, Kh, D, P, N = 4, 2, 64, 128, 6
    q = jax.random.normal(ks[0], (2, H, D))
    kp = jax.random.normal(ks[1], (N, P, Kh, D))
    vp = jax.random.normal(ks[2], (N, P, Kh, D))
    # slot 0: pages [1, 2]; slot 1 shares prefix page 1, then diverges to 3
    bt = jnp.array([[1, 2], [1, 3]], jnp.int32)
    kv_len = jnp.array([200, 170], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, kv_len)
    dense_k = gather_pages(kp, bt)
    dense_v = gather_pages(vp, bt)
    ref = ragged_decode_attention_ref(q, dense_k, dense_v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6,
                               rtol=2e-6)


def test_paged_decode_attention_softcap():
    ks = jax.random.split(KEY, 4)
    B, H, Kh, D, P, N, nb = 2, 4, 2, 64, 128, 7, 2
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (N, P, Kh, D))
    vp = jax.random.normal(ks[2], (N, P, Kh, D))
    bt = jax.random.randint(ks[3], (B, nb), 0, N)
    kv_len = jnp.array([100, 256], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, kv_len, softcap=20.0)
    ref = paged_decode_attention_ref(q, kp, vp, bt, kv_len, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


@pytest.mark.parametrize("B,S,H,Kh,D,w", [
    (2, 256, 4, 2, 64, 0),
    (1, 512, 8, 8, 128, 0),
    (2, 256, 4, 2, 64, 128),   # sliding window (gemma2 local layers)
    (1, 384, 6, 2, 64, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, Kh, D, w, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Kh, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Kh, D), dtype)
    bq = 128 if S % 128 == 0 else 64
    out = flash_attention(q, k, v, block_q=bq, block_k=bq, window=w)
    ref = flash_attention_ref(q, k, v, causal=True, window=w)
    tol = 3e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_softcap():
    ks = jax.random.split(KEY, 3)
    B, S, H, Kh, D = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Kh, D))
    v = jax.random.normal(ks[2], (B, S, Kh, D))
    out = flash_attention(q, k, v, softcap=50.0)
    ref = flash_attention_ref(q, k, v, softcap=50.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


def test_blockwise_matches_full_attention():
    """The pure-JAX blockwise (flash-style) path matches the reference."""
    from repro.models.layers import blockwise_attention, full_attention
    ks = jax.random.split(KEY, 3)
    B, S, H, Kh, D = 2, 4096, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Kh, D))
    v = jax.random.normal(ks[2], (B, S, Kh, D))
    out = blockwise_attention(q, k, v, causal=True)
    ref = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# -- packed (segment-masked) prefill ------------------------------------------

def _packed_segments(key, B, S, P, n_segs):
    """Random ragged packing: up to n_segs page-aligned segments per row,
    -1 padded tail."""
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 1 << 30)))
    seg = np.full((B, S), -1, np.int32)
    for b in range(B):
        off = 0
        for s in range(rng.integers(1, n_segs + 1)):
            span = int(rng.integers(1, max(2, (S - off) // P + 1))) * P
            if off + span > S:
                break
            seg[b, off:off + span] = s
            off += span
    return jnp.asarray(seg)


@pytest.mark.parametrize("B,S,P,w", [
    (2, 256, 64, 0),
    (1, 512, 128, 0),
    (2, 256, 64, 128),          # packed + sliding window
    (3, 384, 128, 0),           # non-pow2 grid
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_packed_segments(B, S, P, w, dtype):
    """Segment-masked flash kernel == oracle on ragged packed layouts:
    tokens never attend across segment boundaries, pad (-1) columns
    contribute nothing to real rows."""
    ks = jax.random.split(KEY, 4)
    H, Kh, D = 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Kh, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Kh, D), dtype)
    seg = _packed_segments(ks[3], B, S, P, 4)
    bq = 128 if S % 128 == 0 else 64
    out = flash_attention(q, k, v, seg_ids=seg, block_q=bq, block_k=bq,
                          window=w)
    ref = flash_attention_ref(q, k, v, causal=True, window=w, seg_ids=seg)
    tol = 3e-6 if dtype == jnp.float32 else 3e-2
    real = np.asarray(seg) >= 0
    np.testing.assert_allclose(np.asarray(out, np.float32)[real],
                               np.asarray(ref, np.float32)[real], atol=tol,
                               rtol=tol)


def test_flash_attention_packed_equals_solo_prefill():
    """Each segment of a packed row attends exactly as the same tokens
    would alone in their own (left-aligned) row — the property the packed
    prefill engine path relies on for token identity."""
    ks = jax.random.split(KEY, 3)
    S, H, Kh, D = 256, 4, 2, 64
    lens = [128, 64, 64]
    q = jax.random.normal(ks[0], (1, S, H, D))
    k = jax.random.normal(ks[1], (1, S, Kh, D))
    v = jax.random.normal(ks[2], (1, S, Kh, D))
    seg = jnp.asarray(np.repeat(np.arange(3), lens)[None, :], jnp.int32)
    packed = flash_attention(q, k, v, seg_ids=seg, block_q=64, block_k=64)
    off = 0
    for n in lens:
        solo = flash_attention_ref(q[:, off:off + n], k[:, off:off + n],
                                   v[:, off:off + n], causal=True)
        np.testing.assert_allclose(np.asarray(packed[:, off:off + n]),
                                   np.asarray(solo), atol=3e-6, rtol=3e-6)
        off += n


# -- fused sampling (streaming LM head) ---------------------------------------

@pytest.mark.parametrize("B,Dm,V,bv,topk", [
    (4, 64, 1000, 128, 1),      # ragged vocab tail
    (2, 128, 4096, 512, 1),
    (1, 64, 2048, 256, 8),      # top-k merge across blocks
    (3, 32, 515, 128, 4),       # vocab % block != 0 with k > 1
])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_fused_sample_matches_two_pass(B, Dm, V, bv, topk, softcap):
    """Fused matmul+top-k+logsumexp == materialise-the-logits oracle,
    including index order on ties (lowest index first)."""
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (B, Dm))
    w = jax.random.normal(ks[1], (Dm, V)) / np.sqrt(Dm)
    vals, idx, lse = fused_sample(x, w, top_k=topk, block_v=bv,
                                  softcap=softcap)
    rv, ri, rl = fused_sample_ref(x, w, top_k=topk, softcap=softcap)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(lse), np.asarray(rl), atol=1e-5,
                               rtol=1e-5)


def test_fused_sample_greedy_identity_on_ties():
    """Exact duplicate maxima across different vocab blocks: the fused
    kernel must return the FIRST occurrence, matching jnp.argmax."""
    Dm, V, bv = 16, 512, 128
    x = jnp.ones((1, Dm))
    w = np.zeros((Dm, V), np.float32)
    w[:, 37] = 1.0          # block 0
    w[:, 300] = 1.0         # identical logit in block 2
    _, idx, _ = fused_sample(x, jnp.asarray(w), top_k=1, block_v=bv)
    logits = jnp.einsum("bd,dv->bv", x, jnp.asarray(w))
    assert int(idx[0, 0]) == int(jnp.argmax(logits[0])) == 37


# -- int8 KV pages ------------------------------------------------------------

@pytest.mark.parametrize("B,H,Kh,D,P,N,nb", [
    (4, 8, 2, 64, 128, 9, 2),
    (2, 16, 16, 128, 128, 17, 3),
    (1, 4, 1, 256, 256, 5, 2),
])
def test_paged_decode_int8_matches_dequant_oracle(B, H, Kh, D, P, N, nb):
    """In-kernel dequant (scalar-prefetched per-page scales) == dequantize
    the whole pool then run the fp oracle."""
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, D))
    kp8, ksc = quantize_pages_ref(jax.random.normal(ks[1], (N, P, Kh, D)))
    vp8, vsc = quantize_pages_ref(jax.random.normal(ks[2], (N, P, Kh, D)))
    bt = jax.random.randint(ks[3], (B, nb), 0, N)
    kv_len = jax.random.randint(ks[4], (B,), 1, nb * P + 1)
    out = paged_decode_attention_int8(q, kp8, vp8, ksc, vsc, bt, kv_len)
    ref = paged_decode_attention_int8_ref(q, kp8, vp8, ksc, vsc, bt, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6,
                               rtol=2e-6)


def test_paged_decode_int8_error_within_documented_atol():
    """Quantize fp pages -> int8 decode output stays within the
    documented KV_INT8_DECODE_ATOL of the fp decode on the SAME pages.
    This is the tolerance README promises users of kv_quant="int8"."""
    ks = jax.random.split(KEY, 5)
    B, H, Kh, D, P, N, nb = 4, 8, 2, 64, 128, 9, 2
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (N, P, Kh, D))
    vp = jax.random.normal(ks[2], (N, P, Kh, D))
    bt = jax.random.randint(ks[3], (B, nb), 0, N)
    kv_len = jax.random.randint(ks[4], (B,), 1, nb * P + 1)
    kp8, ksc = quantize_pages_ref(kp)
    vp8, vsc = quantize_pages_ref(vp)
    out = paged_decode_attention_int8(q, kp8, vp8, ksc, vsc, bt, kv_len)
    fp = paged_decode_attention_ref(q, kp, vp, bt, kv_len)
    err = float(jnp.max(jnp.abs(out - fp)))
    assert err < KV_INT8_DECODE_ATOL, err


def test_int8_quantize_roundtrip_properties():
    """Per-page symmetric quantization invariants: all-zero pages are
    exact, scales are per-page (not global), and requantizing with an
    unchanged scale is idempotent on already-quantized cells."""
    ks = jax.random.split(KEY, 1)[0]
    pages = jax.random.normal(ks, (6, 32, 2, 16))
    pages = pages.at[0].set(0.0)
    q8, sc = quantize_pages_ref(pages)
    assert float(jnp.abs(q8[0].astype(jnp.float32)).max()) == 0.0
    assert sc.shape == (6,)
    deq = q8.astype(jnp.float32) * sc[:, None, None, None]
    q8b, _ = quantize_pages_ref(deq)
    np.testing.assert_array_equal(np.asarray(q8b), np.asarray(q8))
