"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps,
assert_allclose vs the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (flash_attention, paged_decode_attention,
                               ragged_decode_attention)
from repro.kernels.ref import (flash_attention_ref, gather_pages,
                               paged_decode_attention_ref,
                               ragged_decode_attention_ref)

pytestmark = pytest.mark.slow   # jit-heavy: Pallas interpret-mode sweeps

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,H,Kh,D,S,bk", [
    (4, 8, 2, 64, 256, 128),
    (2, 16, 16, 128, 512, 128),
    (3, 4, 1, 128, 384, 128),
    (1, 8, 4, 256, 256, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_decode_attention(B, H, Kh, D, S, bk, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Kh, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Kh, D), dtype)
    kv_len = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = ragged_decode_attention(q, k, v, kv_len, block_k=bk)
    ref = ragged_decode_attention_ref(q, k, v, kv_len)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_ragged_decode_attention_softcap():
    ks = jax.random.split(KEY, 4)
    B, H, Kh, D, S = 2, 4, 2, 64, 256
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, Kh, D))
    v = jax.random.normal(ks[2], (B, S, Kh, D))
    kv_len = jnp.array([100, 256])
    out = ragged_decode_attention(q, k, v, kv_len, softcap=20.0)
    ref = ragged_decode_attention_ref(q, k, v, kv_len, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_ragged_decode_length_one():
    """kv_len=1 edge: only the first cache row is attended."""
    ks = jax.random.split(KEY, 3)
    B, H, Kh, D, S = 2, 4, 4, 64, 128
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, Kh, D))
    v = jax.random.normal(ks[2], (B, S, Kh, D))
    kv_len = jnp.ones((B,), jnp.int32)
    out = ragged_decode_attention(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(v[:, 0]), atol=1e-5)


@pytest.mark.parametrize("B,H,Kh,D,P,N,nb", [
    (4, 8, 2, 64, 128, 9, 2),
    (2, 16, 16, 128, 128, 17, 3),
    (1, 4, 1, 256, 256, 5, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(B, H, Kh, D, P, N, nb, dtype):
    """Block-table kernel == attention over the gathered dense view."""
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp = jax.random.normal(ks[1], (N, P, Kh, D), dtype)
    vp = jax.random.normal(ks[2], (N, P, Kh, D), dtype)
    bt = jax.random.randint(ks[3], (B, nb), 0, N)
    kv_len = jax.random.randint(ks[4], (B,), 1, nb * P + 1)
    out = paged_decode_attention(q, kp, vp, bt, kv_len)
    ref = paged_decode_attention_ref(q, kp, vp, bt, kv_len)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_paged_matches_dense_on_shared_pages():
    """Two slots mapping the SAME physical prefix pages (GRPO sharing)
    attend exactly as two dense slots holding copies of that prefix."""
    ks = jax.random.split(KEY, 4)
    H, Kh, D, P, N = 4, 2, 64, 128, 6
    q = jax.random.normal(ks[0], (2, H, D))
    kp = jax.random.normal(ks[1], (N, P, Kh, D))
    vp = jax.random.normal(ks[2], (N, P, Kh, D))
    # slot 0: pages [1, 2]; slot 1 shares prefix page 1, then diverges to 3
    bt = jnp.array([[1, 2], [1, 3]], jnp.int32)
    kv_len = jnp.array([200, 170], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, kv_len)
    dense_k = gather_pages(kp, bt)
    dense_v = gather_pages(vp, bt)
    ref = ragged_decode_attention_ref(q, dense_k, dense_v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6,
                               rtol=2e-6)


def test_paged_decode_attention_softcap():
    ks = jax.random.split(KEY, 4)
    B, H, Kh, D, P, N, nb = 2, 4, 2, 64, 128, 7, 2
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (N, P, Kh, D))
    vp = jax.random.normal(ks[2], (N, P, Kh, D))
    bt = jax.random.randint(ks[3], (B, nb), 0, N)
    kv_len = jnp.array([100, 256], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, kv_len, softcap=20.0)
    ref = paged_decode_attention_ref(q, kp, vp, bt, kv_len, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


@pytest.mark.parametrize("B,S,H,Kh,D,w", [
    (2, 256, 4, 2, 64, 0),
    (1, 512, 8, 8, 128, 0),
    (2, 256, 4, 2, 64, 128),   # sliding window (gemma2 local layers)
    (1, 384, 6, 2, 64, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, Kh, D, w, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Kh, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Kh, D), dtype)
    bq = 128 if S % 128 == 0 else 64
    out = flash_attention(q, k, v, block_q=bq, block_k=bq, window=w)
    ref = flash_attention_ref(q, k, v, causal=True, window=w)
    tol = 3e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_softcap():
    ks = jax.random.split(KEY, 3)
    B, S, H, Kh, D = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Kh, D))
    v = jax.random.normal(ks[2], (B, S, Kh, D))
    out = flash_attention(q, k, v, softcap=50.0)
    ref = flash_attention_ref(q, k, v, softcap=50.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


def test_blockwise_matches_full_attention():
    """The pure-JAX blockwise (flash-style) path matches the reference."""
    from repro.models.layers import blockwise_attention, full_attention
    ks = jax.random.split(KEY, 3)
    B, S, H, Kh, D = 2, 4096, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Kh, D))
    v = jax.random.normal(ks[2], (B, S, Kh, D))
    out = blockwise_attention(q, k, v, causal=True)
    ref = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
