"""Controller behaviour against the simulator: grouped loading, early
termination, micro-curriculum ordering, bubble-ratio relations between the
strategies, and the §4.4.2 ablations."""
import random


from repro.core.buffer import Mode, StatefulRolloutBuffer
from repro.core.controller import (CanonicalController, PipelinedController,
                                   SortedRLConfig, SortedRLController,
                                   UngroupedController)
from repro.rollout.sim import SimEngine, lognormal_lengths


def _prompts(n, seed=0):
    rng = random.Random(seed)
    return [[1] * rng.randint(8, 32) for _ in range(n)]


def _run(strategy, mode=Mode.ON_POLICY, n=128, cap=32, update=32, group=4,
         seed=1, max_gen=512, sigma=0.8):
    eng = SimEngine(capacity=cap, max_gen_len=max_gen, seed=seed,
                    length_sampler=lognormal_lengths(median=60, sigma=sigma,
                                                     max_len=max_gen))
    buf = StatefulRolloutBuffer(mode)
    cfg = SortedRLConfig(mode=mode, rollout_batch=cap, group_size=group,
                         update_batch=update, max_gen_len=max_gen)
    batches = []

    def train_fn(entries, version):
        batches.append([e.gen_len for e in entries])

    if strategy == "sorted":
        ctl = SortedRLController(eng, buf, cfg, train_fn)
        ctl.run_group(_prompts(n, seed))
    elif strategy == "pipelined":
        ctl = PipelinedController(eng, buf, cfg, train_fn)
        ctl.queue_group(_prompts(n, seed))
        ctl.queue_group(_prompts(n, seed + 1))
        ctl.run_queued()
    else:
        ctl = CanonicalController(eng, buf, cfg, train_fn,
                                  sort_post_hoc=(strategy == "posthoc"))
        ctl.run_group(_prompts(n, seed))
    return ctl, batches


def test_all_prompts_trained_once():
    for strategy in ("sorted", "baseline", "posthoc"):
        ctl, batches = _run(strategy)
        assert sum(len(b) for b in batches) == 128, strategy


def test_micro_curriculum_sorted_batches():
    """Within each update batch the gen-lengths are sorted, and batch means
    trend upward within a group (the micro-curriculum)."""
    _, batches = _run("sorted")
    for b in batches:
        assert b == sorted(b)
    means = [sum(b) / len(b) for b in batches]
    # later batches are longer on average (allow one inversion for the
    # leftover batch)
    inversions = sum(means[i] > means[i + 1] for i in range(len(means) - 1))
    assert inversions <= 1, means


def test_bubble_ratio_ordering():
    """Sorted scheduling cuts the bubble vs the wait-for-all baseline by
    >50% (the paper's abstract claim)."""
    base, _ = _run("baseline", group=1, n=32, cap=32)
    # 4 sequential batches
    sortd, _ = _run("sorted", n=128, cap=32, group=4)
    assert base.metrics.bubble_ratio > 0.3
    assert sortd.metrics.bubble_ratio < 0.5 * base.metrics.bubble_ratio


def test_on_policy_discards_partial_keeps():
    on, _ = _run("sorted", mode=Mode.ON_POLICY)
    part, _ = _run("sorted", mode=Mode.PARTIAL)
    assert on.metrics.tokens_discarded > 0
    assert part.metrics.tokens_discarded == 0
    # partial mode finishes the same workload in less virtual time
    assert part.metrics.elapsed < on.metrics.elapsed


def test_early_termination_happens():
    ctl, _ = _run("sorted")
    assert ctl.metrics.harvests >= 4
    base, _ = _run("baseline")
    assert base.metrics.harvests == 0


def test_pipelined_preserves_group_order():
    ctl, batches = _run("pipelined")
    assert sum(len(b) for b in batches) == 256
    # bubble no worse than strict sorted on the same workload
    strict, _ = _run("sorted")
    assert ctl.metrics.bubble_ratio <= strict.metrics.bubble_ratio + 0.05


def test_ungrouped_starves_long_prompts():
    """Ablation §4.4.2: without the group barrier, harvested data biases
    short — mean trained length is well below the grouped controller's."""
    eng = SimEngine(capacity=32, max_gen_len=2048, seed=3,
                    length_sampler=lognormal_lengths(median=60, sigma=1.4,
                                                     max_len=2048))
    buf = StatefulRolloutBuffer(Mode.ON_POLICY)
    cfg = SortedRLConfig(rollout_batch=32, group_size=4, update_batch=32,
                         max_gen_len=2048)
    lens = []

    def train_fn(entries, version):
        lens.extend(e.gen_len for e in entries)

    stream = iter([(p, None) for p in _prompts(4096, seed=3)])
    ctl = UngroupedController(eng, buf, cfg, train_fn, prompt_stream=stream)
    ctl.run_steps(n_updates=8)
    _, grouped_batches = _run("sorted", seed=3, max_gen=2048, sigma=1.4)
    grouped_mean = sum(sum(b) for b in grouped_batches) / 128
    ungrouped_mean = sum(lens) / len(lens)
    assert ungrouped_mean < 0.8 * grouped_mean


def test_staleness_bounded_by_group():
    """Every trained token's policy version is within group_size updates of
    the update that consumes it (the paper's bounded-staleness argument)."""
    eng = SimEngine(capacity=32, max_gen_len=256, seed=5)
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=32, group_size=4,
                         update_batch=32, max_gen_len=256)
    worst = []

    def train_fn(entries, version):
        for e in entries:
            if e.versions:
                worst.append(version - min(e.versions))

    ctl = SortedRLController(eng, buf, cfg, train_fn)
    ctl.run_group(_prompts(128, 5))
    assert max(worst) <= cfg.group_size + 1


def test_fill_policy_tradeoff():
    """Beyond-paper: fresh_first trades staleness for bubble vs the
    resume_first default (see EXPERIMENTS.md)."""
    results = {}
    for policy in ("resume_first", "fresh_first"):
        eng = SimEngine(capacity=32, max_gen_len=2048, seed=7,
                        length_sampler=lognormal_lengths(median=200,
                                                         sigma=1.2,
                                                         max_len=2048))
        buf = StatefulRolloutBuffer(Mode.PARTIAL)
        cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=32,
                             group_size=4, update_batch=32,
                             max_gen_len=2048)
        stale = []
        ctl = SortedRLController(
            eng, buf, cfg,
            lambda e, v: stale.extend(x.staleness(v) for x in e),
            fill_policy=policy)
        ctl.run_group(_prompts(128, 7))
        results[policy] = (ctl.metrics.bubble_ratio,
                           sum(stale) / len(stale))
    assert results["fresh_first"][0] <= results["resume_first"][0] + 0.02
    assert results["fresh_first"][1] >= results["resume_first"][1] - 0.02


def test_resolved_threshold_honors_zero_and_none_distinctly():
    """harvest_threshold=0 must NOT coerce to update_batch (the old
    `x or default` bug): None means "default to update_batch", 0 means
    "harvest after every decode step"."""
    assert SortedRLConfig(update_batch=64).resolved_threshold() == 64
    assert SortedRLConfig(mode=Mode.PARTIAL, update_batch=64,
                          harvest_threshold=0).resolved_threshold() == 0
    assert SortedRLConfig(update_batch=64,
                          harvest_threshold=16).resolved_threshold() == 16
    # on-policy + threshold 0 would livelock (every step's progress is
    # scavenged away); the config must refuse it outright
    import pytest
    with pytest.raises(ValueError):
        SortedRLConfig(mode=Mode.ON_POLICY, harvest_threshold=0)
    # negative thresholds are the same always-harvest footgun in disguise
    with pytest.raises(ValueError):
        SortedRLConfig(mode=Mode.PARTIAL, harvest_threshold=-1)


def test_zero_harvest_threshold_scavenges_every_step_and_terminates():
    """harvest_threshold=0 in partial mode: maximum scavenging pressure —
    every rollout iteration is a single decode step followed by a full
    interrupt — and the group still drains with conservation intact."""
    from repro.core.orchestrator import RolloutOrchestrator
    from repro.core.policy import make_policy
    eng = SimEngine(capacity=8, max_gen_len=32, seed=3,
                    length_sampler=lognormal_lengths(median=6, sigma=0.8,
                                                     max_len=32))
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=8, group_size=2,
                         update_batch=8, max_gen_len=32,
                         harvest_threshold=0)
    trained = []
    orch = RolloutOrchestrator(eng, buf, cfg, make_policy("sorted"),
                               lambda req: trained.extend(req.entries))
    orch.run_group(_prompts(16, seed=3))
    assert len(trained) == 16
    assert orch.metrics.harvests >= orch.metrics.updates
    # the old coercion made 0 behave like update_batch; with 0 honored,
    # harvests vastly outnumber updates (one interrupt per decode step)
    assert orch.metrics.harvests > 2 * orch.metrics.updates
