"""Engine-conformance suite: the executable contract of EngineProtocol.

Every scenario runs IDENTICALLY against the discrete-event SimEngine and a
tiny-model SlotEngine (real JAX decode), so any future engine backend can
be added to ``ENGINES`` and inherit the whole contract:

  * free-slot accounting — submit/step/interrupt move slots between free
    and active exactly; capacity is never exceeded
  * event/uid consistency — step() emits exactly one event per active
    slot, in a stable order, for exactly the active uids
  * finish reasons — done events carry "eos" | "length"; non-done events
    carry None; done uids leave their slots immediately
  * interrupt idempotence — a second interrupt is a no-op returning []
  * scavenge/resume (both buffer modes) and oversubscription refill
    compose with StatefulRolloutBuffer without violating its invariants

Also pins down the SlotEngine hot-path guarantees of this PR: a loop-free
``step()`` and a bucketed (bounded) ``_prefill_cache``.
"""
import ast
import inspect
import math
import textwrap

import pytest

from repro.core.buffer import BufferEntry, Mode, StatefulRolloutBuffer
from repro.core.engine_api import EngineProtocol, SlotTable
from repro.rollout.sim import SimEngine

CAPACITY = 4
MAX_GEN = 6
MAX_TOTAL = 64

_TINY = {}


def _tiny_model():
    if not _TINY:
        import jax
        from repro.data import logic
        from repro.models.model import build_model
        from repro.train.loop import tiny_lm_config
        cfg = tiny_lm_config(len(logic.VOCAB), d_model=32, layers=1, heads=2)
        model = build_model(cfg)
        _TINY["model"] = model
        _TINY["params"] = model.init_params(jax.random.PRNGKey(0))
        _TINY["pad"] = logic.VOCAB.pad_id
    return _TINY


def make_sim(capacity=CAPACITY, max_gen=MAX_GEN):
    return SimEngine(capacity=capacity, max_gen_len=max_gen, seed=0)


def make_slot(capacity=CAPACITY, max_gen=MAX_GEN, eos_id=-1, **kw):
    from repro.rollout.engine import SlotEngine
    t = _tiny_model()
    # eos_id=-1: finishes are budget-driven, so scenarios are deterministic
    return SlotEngine(t["model"], lambda: t["params"], capacity=capacity,
                      max_total_len=MAX_TOTAL, max_gen_len=max_gen,
                      eos_id=eos_id, pad_id=t["pad"], temperature=1.0, **kw)


def _tiny_left_model():
    """Smallest left-padding (ssm) model — exercises the kv_start/width
    accounting path the transformer engine never touches."""
    if "left_model" not in _TINY:
        import jax
        import jax.numpy as jnp
        from repro.configs.base import get_smoke_config
        from repro.models.model import build_model
        cfg = get_smoke_config("xlstm_125m").replace(
            param_dtype=jnp.float32, compute_dtype=jnp.float32)
        model = build_model(cfg)
        assert model.padding_side == "left"
        _TINY["left_model"] = model
        _TINY["left_params"] = model.init_params(jax.random.PRNGKey(1))
    return _TINY["left_model"], _TINY["left_params"]


def make_slot_left(capacity=CAPACITY, max_gen=MAX_GEN, eos_id=-1,
                   max_total=MAX_TOTAL):
    from repro.rollout.engine import SlotEngine
    model, params = _tiny_left_model()
    return SlotEngine(model, lambda: params, capacity=capacity,
                      max_total_len=max_total, max_gen_len=max_gen,
                      eos_id=eos_id, pad_id=0, temperature=1.0)


def make_slot_dense(capacity=CAPACITY, max_gen=MAX_GEN, eos_id=-1):
    """Dense-cache SlotEngine (the pre-paging memory model, kept as an
    escape hatch for exotic cache layouts and as the oracle for the paged
    engine's token stream)."""
    from repro.rollout.engine import SlotEngine
    t = _tiny_model()
    return SlotEngine(t["model"], lambda: t["params"], capacity=capacity,
                      max_total_len=MAX_TOTAL, max_gen_len=max_gen,
                      eos_id=eos_id, pad_id=t["pad"], temperature=1.0,
                      paged=False)


def make_group_sim(capacity=CAPACITY, max_gen=MAX_GEN, n_replicas=2):
    """EngineGroup over SimEngine replicas (distinct seeds, shared total
    capacity) — the multi-replica facade must satisfy the whole contract."""
    from repro.rollout.group import EngineGroup
    assert capacity % n_replicas == 0
    return EngineGroup([SimEngine(capacity=capacity // n_replicas,
                                  max_gen_len=max_gen, seed=i)
                        for i in range(n_replicas)])


def make_group_slot(capacity=CAPACITY, max_gen=MAX_GEN, eos_id=-1,
                    n_replicas=2, **kw):
    """EngineGroup over paged SlotEngine replicas, each with its own
    page pool."""
    from repro.rollout.group import EngineGroup
    assert capacity % n_replicas == 0
    return EngineGroup([make_slot(capacity=capacity // n_replicas,
                                  max_gen=max_gen, eos_id=eos_id, **kw)
                        for _ in range(n_replicas)])


def make_group_mig(capacity=CAPACITY, max_gen=MAX_GEN, eos_id=-1,
                   n_replicas=2, **kw):
    """EngineGroup with cross-replica KV migration enabled: stolen
    entries carry their resident pages to the thief's pool instead of
    re-prefilling.  The whole single-engine contract must still hold."""
    from repro.rollout.group import EngineGroup
    assert capacity % n_replicas == 0
    return EngineGroup([make_slot(capacity=capacity // n_replicas,
                                  max_gen=max_gen, eos_id=eos_id, **kw)
                        for _ in range(n_replicas)], migrate_kv=True)


def make_slot_packed(capacity=CAPACITY, max_gen=MAX_GEN, eos_id=-1, **kw):
    """Packed ragged prefill: one segment-masked launch per fill wave."""
    return make_slot(capacity=capacity, max_gen=max_gen, eos_id=eos_id,
                     packed_prefill=True, **kw)


def make_slot_fused(capacity=CAPACITY, max_gen=MAX_GEN, eos_id=-1, **kw):
    """Fused greedy sampling (streaming LM head, no (B, V) round-trip).
    The flag only changes the decode compile at temperature 0; the
    contract must hold for sampled decode too."""
    return make_slot(capacity=capacity, max_gen=max_gen, eos_id=eos_id,
                     fused_sampling=True, **kw)


def make_slot_int8(capacity=CAPACITY, max_gen=MAX_GEN, eos_id=-1, **kw):
    """int8 KV pages with per-page scale planes."""
    return make_slot(capacity=capacity, max_gen=max_gen, eos_id=eos_id,
                     kv_quant="int8", **kw)


ENGINES = [("sim", make_sim), ("slot", make_slot),
           ("slot_dense", make_slot_dense), ("slot_left", make_slot_left),
           ("slot_packed", make_slot_packed), ("slot_fused", make_slot_fused),
           ("slot_int8", make_slot_int8),
           ("group_sim", make_group_sim), ("group_slot", make_group_slot),
           ("group_mig", make_group_mig)]


@pytest.fixture(params=[name for name, _ in ENGINES])
def engine_factory(request):
    return dict(ENGINES)[request.param]


def entries(n, start_uid=0, prompt_len=3):
    return [BufferEntry(uid=start_uid + i, prompt=[1] * prompt_len + [2 + i])
            for i in range(n)]


def checked_step(engine):
    """One engine step with the full event contract asserted."""
    before = sorted(engine.active_uids())
    free_before = engine.free_slots()
    evs = engine.step()
    assert sorted(ev.uid for ev in evs) == before, \
        "one event per active slot, for exactly the active uids"
    done_uids = {ev.uid for ev in evs if ev.done}
    assert set(engine.active_uids()) == set(before) - done_uids, \
        "done slots freed, others retained"
    assert engine.free_slots() == free_before + len(done_uids)
    for ev in evs:
        assert isinstance(ev.token, int)
        assert math.isfinite(ev.logprob)
        assert (ev.finish_reason is None) == (not ev.done)
        if ev.done:
            assert ev.finish_reason in ("eos", "length")
    return evs


def run_to_completion(engine, max_steps=10_000):
    all_events = []
    steps = 0
    while engine.active_uids():
        all_events.extend(checked_step(engine))
        steps += 1
        assert steps < max_steps, "engine failed to drain"
    return all_events


# -- scenarios ----------------------------------------------------------------

def test_protocol_surface(engine_factory):
    eng = engine_factory()
    assert isinstance(eng, EngineProtocol)
    assert eng.capacity == CAPACITY
    assert isinstance(eng.clock, float)
    assert eng.free_slots() == CAPACITY and eng.active_uids() == []
    eng.sync_weights(3)
    assert eng.version == 3


def test_submit_accounting(engine_factory):
    eng = engine_factory()
    es = entries(3)
    eng.submit(es, version=0)
    assert eng.free_slots() == CAPACITY - 3
    assert sorted(eng.active_uids()) == [0, 1, 2]
    # overfilling the remaining slot must raise
    with pytest.raises(AssertionError):
        eng.submit(entries(2, start_uid=10), version=0)
    eng.submit(entries(1, start_uid=10), version=0)
    assert eng.free_slots() == 0


def test_step_events_and_budget(engine_factory):
    eng = engine_factory()
    eng.submit(entries(CAPACITY), version=0)
    evs = run_to_completion(eng)
    assert eng.free_slots() == CAPACITY
    per_uid = {u: sum(1 for e in evs if e.uid == u)
               for u in range(CAPACITY)}
    # generation budget is a per-trajectory cap
    assert all(1 <= n <= MAX_GEN for n in per_uid.values()), per_uid
    assert all(sum(1 for e in evs if e.uid == u and e.done) == 1
               for u in per_uid)


def test_event_order_stable_while_resident(engine_factory):
    """While a set of requests stays resident, the per-step event order
    does not change (ascending slot order contract)."""
    eng = engine_factory()
    eng.submit(entries(CAPACITY), version=0)
    order0 = [ev.uid for ev in checked_step(eng)]
    while True:
        uids_before = set(eng.active_uids())
        evs = checked_step(eng)
        assert [ev.uid for ev in evs] == [u for u in order0
                                          if u in uids_before]
        if not eng.active_uids():
            break


def test_interrupt_idempotent(engine_factory):
    eng = engine_factory()
    eng.submit(entries(3), version=0)
    checked_step(eng)
    survivors = sorted(eng.active_uids())
    out = eng.interrupt()
    assert sorted(out) == survivors
    assert eng.free_slots() == CAPACITY and eng.active_uids() == []
    assert eng.interrupt() == []              # idempotent on empty
    assert eng.interrupt(uids=[99]) == []     # unknown uid: no-op


def test_interrupt_selective(engine_factory):
    eng = engine_factory()
    eng.submit(entries(3), version=0)
    out = eng.interrupt(uids=[1])
    assert out == [1]
    assert sorted(eng.active_uids()) == [0, 2]
    assert eng.free_slots() == CAPACITY - 2
    # slots freed by interrupt are immediately reusable
    eng.submit(entries(2, start_uid=20), version=0)
    assert eng.free_slots() == 0


@pytest.mark.parametrize("mode", [Mode.ON_POLICY, Mode.PARTIAL])
def test_scavenge_resume_cycle(engine_factory, mode):
    """interrupt -> buffer.scavenge -> resubmit honours per-mode semantics
    and the engine treats the scavenged prefix as part of the budget."""
    eng = engine_factory()
    buf = StatefulRolloutBuffer(mode)
    uids = buf.load_prompts([[1, 2, 3]] * 2)
    buf.mark_running(uids)
    eng.submit(buf.running(), version=0)
    for _ in range(2):
        for ev in checked_step(eng):
            buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
            if ev.done:
                buf.mark_done(ev.uid, ev.finish_reason)
    for uid in eng.interrupt():
        buf.scavenge(uid)
    buf.check_invariants()
    for e in buf.pending():
        assert (e.gen_len == 0) if mode == Mode.ON_POLICY else True
    # resume: remaining budget shrinks by the scavenged prefix
    resumed = buf.pending()
    if resumed:
        buf.mark_running([e.uid for e in resumed])
        prefixes = {e.uid: e.gen_len for e in resumed}
        eng.submit(resumed, version=1)
        evs = [ev for ev in run_to_completion(eng)]
        for ev in evs:
            buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 1)
            if ev.done:
                buf.mark_done(ev.uid, ev.finish_reason)
        for uid, prefix in prefixes.items():
            new = sum(1 for ev in evs if ev.uid == uid)
            assert prefix + new <= MAX_GEN
    buf.check_invariants()
    for e in buf.done():
        assert len(e.generated) == len(e.logprobs) == len(e.versions)


def test_oversubscription_refill(engine_factory):
    """More prompts than slots: refilling freed slots every step drains the
    whole workload with slot accounting intact throughout."""
    n = 3 * CAPACITY
    eng = engine_factory()
    buf = StatefulRolloutBuffer(Mode.ON_POLICY)
    buf.load_prompts([[1, 1 + i % 5] for i in range(n)])
    steps = 0
    while buf.pending() or buf.running():
        batch = buf.pending()[:eng.free_slots()]
        if batch:
            buf.mark_running([e.uid for e in batch])
            eng.submit(batch, version=0)
        assert len(eng.active_uids()) == len(buf.running())
        assert eng.free_slots() == CAPACITY - len(buf.running())
        for ev in checked_step(eng):
            buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
            if ev.done:
                buf.mark_done(ev.uid, ev.finish_reason)
        steps += 1
        assert steps < 10_000
    assert len(buf.done()) == n
    assert eng.free_slots() == CAPACITY
    buf.check_invariants()


def test_step_on_empty_engine(engine_factory):
    eng = engine_factory()
    assert eng.step() == []
    assert eng.free_slots() == CAPACITY


# -- SlotEngine hot-path guarantees (this PR's tentpole) ----------------------

def test_slot_engine_step_is_loop_free():
    """step() must stay vectorized: no per-slot Python for/while loop
    (comprehensions build the event list; state updates are array ops)."""
    from repro.rollout.engine import SlotEngine
    tree = ast.parse(textwrap.dedent(inspect.getsource(SlotEngine.step)))
    loops = [n for n in ast.walk(tree)
             if isinstance(n, (ast.For, ast.While, ast.AsyncFor))]
    assert not loops, "per-slot Python loop reintroduced in SlotEngine.step"


def test_prefill_cache_bounded_by_bucketing():
    """Submitting many distinct (width, batch) shapes compiles at most
    O(log max_total_len * log capacity) prefill variants, keyed by
    power-of-two buckets."""
    eng = make_slot(capacity=CAPACITY)
    uid = 0
    shapes = [(1, 1), (2, 1), (3, 2), (5, 3), (6, 4), (9, 2), (11, 1),
              (13, 3), (17, 4), (21, 2), (26, 1), (30, 4)]
    for plen, k in shapes:
        es = [BufferEntry(uid=uid + i, prompt=[1] * (plen + 1))
              for i in range(k)]
        uid += k
        eng.submit(es, version=0)
        eng.interrupt()
    n_width_buckets = int(math.log2(MAX_TOTAL)) + 1
    n_batch_buckets = int(math.ceil(math.log2(CAPACITY))) + 1
    assert len(eng._prefill_cache) <= n_width_buckets * n_batch_buckets
    # far fewer compiles than distinct submitted shapes
    assert len(eng._prefill_cache) < len(shapes)
    for width, kb, dtype_key in eng._prefill_cache:
        assert width == 1 << (width - 1).bit_length() or width == MAX_TOTAL
        assert kb == 1 << (kb - 1).bit_length() or kb == CAPACITY
        assert dtype_key == eng._kv_dtype_key


def test_prefill_and_decode_caches_keyed_by_kv_dtype():
    """Regression: an int8 engine and an fp engine with the same (width,
    batch) bucket must NOT share compiled prefill/decode entries — the KV
    dtype is part of every compile-cache key, so a shared cache dict (or
    a future engine pooling compiles across replicas) cannot alias an
    int8 page layout onto an fp one."""
    fp = make_slot(capacity=CAPACITY)
    q = make_slot(capacity=CAPACITY, kv_quant="int8")
    assert fp._kv_dtype_key != q._kv_dtype_key
    for uid, eng in ((0, fp), (100, q)):
        eng.submit([BufferEntry(uid=uid, prompt=[1, 2, 3])], version=0)
        eng.step()
    assert not set(fp._prefill_cache) & set(q._prefill_cache)
    assert not set(fp._paged_decode_cache) & set(q._paged_decode_cache)
    # fused-vs-unfused decode variants are distinct compiles too
    fz = make_slot(capacity=CAPACITY, fused_sampling=True)
    fz.temperature = 0.0
    fz.submit([BufferEntry(uid=7, prompt=[1, 2, 3])], version=0)
    fz.step()
    assert not set(fp._paged_decode_cache) & set(fz._paged_decode_cache)


def test_left_padding_bucketing_keeps_gen_headroom():
    """Width bucketing must not eat a left-padding model's generation
    budget: a prompt wider than max_total_len/2 would bucket to
    max_total_len, set kv_len there, and terminate after one token."""
    max_gen = 8
    eng = make_slot_left(capacity=1, max_gen=max_gen, max_total=MAX_TOTAL)
    plen = MAX_TOTAL // 2 + 4                     # pow2-buckets to MAX_TOTAL
    eng.submit([BufferEntry(uid=0, prompt=[1] * plen)], version=0)
    assert int(eng.slots.kv_len[0]) + max_gen < MAX_TOTAL, \
        "bucketed width left no room for the generation budget"
    evs = run_to_completion(eng)
    assert len(evs) == max_gen                    # full budget generated
    assert evs[-1].done and evs[-1].finish_reason == "length"


def test_slot_table_shared_by_both_engines():
    """Both engines expose the same SlotTable host state — the struct any
    new backend should reuse."""
    from repro.rollout.engine import SlotEngine   # noqa: F401
    for _, factory in ENGINES:
        eng = factory()
        assert isinstance(eng.slots, SlotTable)
        assert eng.slots.capacity == eng.capacity


# -- paged KV cache accounting (PR 3 tentpole) --------------------------------
#
# The default SlotEngine above already runs every scenario on the paged
# memory model; these cases additionally pin down the page-pool contract:
# prefix sharing, copy-on-write isolation, resume-without-reprefill, and
# zero leaked pages/references at quiescence.

def _drained_pool_is_clean(eng):
    assert not eng.active_uids()
    st = eng.cache_stats()
    assert st["pages_in_use"] == 0, st
    assert (eng.kv.pool.refcount == 0).all()
    eng.kv.check_invariants()


def group_entries(g, prompt_len=8, start_uid=0):
    """A GRPO-style group: identical prompt, one entry per member."""
    return [BufferEntry(uid=start_uid + i, prompt=[1] * prompt_len)
            for i in range(g)]


def test_paged_group_prefills_shared_prompt_once():
    """G same-prompt members cost ONE prefill of the shared prefix; the
    other G-1 map the same pages, and every reference drops to zero when
    the group finishes (no leaked pages)."""
    g, plen = 4, 8
    eng = make_slot()
    eng.submit(group_entries(g, plen), version=0)
    st = eng.cache_stats()
    assert st["prefill_tokens_run"] == plen - 1
    assert st["prefill_tokens_saved"] == (g - 1) * (plen - 1)
    assert st["shared_prefills"] == g - 1
    run_to_completion(eng)
    _drained_pool_is_clean(eng)


def test_paged_cow_keeps_group_members_isolated():
    """Members sharing a partial prefix page diverge via copy-on-write;
    the paged token streams match the dense engine's exactly (greedy)."""
    def run(factory):
        eng = factory()
        es = [BufferEntry(uid=i, prompt=[1, 2, 3, 4, 2 + i])
              for i in range(3)] + [BufferEntry(uid=9, prompt=[3, 1, 4])]
        eng.submit(es, version=0)
        toks = {e.uid: [] for e in es}
        while eng.active_uids():
            for ev in checked_step(eng):
                toks[ev.uid].append(ev.token)
        return toks

    def greedy_paged():
        eng = make_slot()
        eng.temperature = 0.0
        return eng

    def greedy_dense():
        eng = make_slot_dense()
        eng.temperature = 0.0
        return eng

    paged, dense = run(greedy_paged), run(greedy_dense)
    assert paged == dense, (paged, dense)


def _greedy_stream(factory, prompts, max_gen=MAX_GEN):
    eng = factory(capacity=8, max_gen=max_gen)
    eng.temperature = 0.0
    es = [BufferEntry(uid=i, prompt=list(p)) for i, p in enumerate(prompts)]
    eng.submit(es, version=0)
    toks = {e.uid: [] for e in es}
    while eng.active_uids():
        for ev in checked_step(eng):
            toks[ev.uid].append(ev.token)
    return eng, toks


_RAGGED_PROMPTS = [[1, 2, 3, 4, 5, 6, 7, 8, 9, 2], [3, 1, 4], [1, 5, 9, 2, 6],
                   [2, 7, 1, 8, 2, 8, 1], [1, 2]]


def test_packed_prefill_greedy_token_identity():
    """Packed ragged prefill must produce byte-for-byte the same greedy
    token streams as the bucketed dense-prefill path: segment masking +
    per-segment positions make each packed prefix's KV identical to a
    solo prefill."""
    _, base = _greedy_stream(make_slot, _RAGGED_PROMPTS)
    eng, packed = _greedy_stream(make_slot_packed, _RAGGED_PROMPTS)
    assert packed == base, (packed, base)
    assert eng.prefill_launches == 1        # one launch for the whole wave


def test_packed_prefill_one_launch_per_fill_wave():
    """N waves of ragged submits = exactly N packed launches, versus the
    bucketed path which launches once per wave too but at kb x width
    padded cost; the counter is the roofline metric smoke rows pin."""
    eng = make_slot_packed()
    eng.temperature = 0.0
    for wave, plens in enumerate([(9, 3, 5), (7, 2)]):
        eng.submit([BufferEntry(uid=10 * wave + i, prompt=[1] * n + [2 + i])
                    for i, n in enumerate(plens)], version=0)
        assert eng.prefill_launches == wave + 1
        eng.interrupt()
    assert eng.cache_stats()["prefill_launches"] == 2.0


def test_fused_sampling_greedy_token_identity():
    """Fused (streaming) greedy sampling must match the two-pass
    argmax-over-full-logits path exactly, including first-occurrence
    tie-breaks, and must report the same logprobs."""
    eng_b = make_slot(capacity=8)
    eng_f = make_slot_fused(capacity=8)
    for eng in (eng_b, eng_f):
        eng.temperature = 0.0
    out = {}
    for name, eng in (("base", eng_b), ("fused", eng_f)):
        es = [BufferEntry(uid=i, prompt=list(p))
              for i, p in enumerate(_RAGGED_PROMPTS)]
        eng.submit(es, version=0)
        toks = {e.uid: [] for e in es}
        lps = {e.uid: [] for e in es}
        while eng.active_uids():
            for ev in checked_step(eng):
                toks[ev.uid].append(ev.token)
                lps[ev.uid].append(ev.logprob)
        out[name] = (toks, lps)
    assert out["base"][0] == out["fused"][0], out
    for uid, ref in out["base"][1].items():
        for a, b in zip(ref, out["fused"][1][uid]):
            assert abs(a - b) < 1e-4, (uid, a, b)


def test_int8_kv_decode_stays_close_to_fp():
    """int8 pages are lossy but bounded: the quantized engine completes
    every rollout and its early greedy tokens (decoding off freshly
    quantized prefill pages) match fp — gross quantization bugs flip the
    very first token."""
    _, base = _greedy_stream(make_slot, _RAGGED_PROMPTS, max_gen=4)
    eng, quant = _greedy_stream(make_slot_int8, _RAGGED_PROMPTS, max_gen=4)
    assert set(quant) == set(base)
    first_match = sum(quant[u][0] == base[u][0] for u in base)
    assert first_match == len(base), (quant, base)
    assert eng.kv_quant == "int8"
    _drained_pool_is_clean(eng)


def test_int8_scale_planes_follow_cow_and_migration():
    """Per-page scale planes must travel with their pages: COW copies the
    scale row to the new page, and export->import lands the scales on the
    destination pool so a migrated entry keeps decoding identically."""
    import numpy as np
    src = make_slot_int8(capacity=2)
    src.temperature = 0.0
    # shared prompt => shared pages => COW on divergence
    src.submit(group_entries(2, prompt_len=10), version=0)
    for _ in range(3):
        checked_step(src)
    assert src.cache_stats()["cow_copies"] >= 1
    uid = src.active_uids()[0]
    handle = src.export_entry(uid)
    assert handle["kv_quant"] == "int8"
    ex = handle["kv"]
    np.testing.assert_array_equal(
        handle["scales_k"], np.asarray(src.kv_scales["k"][:, ex.pages]))
    dst = make_slot_int8(capacity=2)
    assert dst.import_entry(handle)
    pages = list(dst.kv.tables[uid])
    np.testing.assert_array_equal(
        np.asarray(dst.kv_scales["k"][:, pages]), handle["scales_k"])
    np.testing.assert_array_equal(
        np.asarray(dst.cache["k"][:, pages]), handle["pages_k"])
    # fp pool refuses int8 bytes (and vice versa)
    assert not make_slot(capacity=2).import_entry(handle)
    src.discard_entry(uid)
    run_to_completion(dst)
    run_to_completion(src)
    _drained_pool_is_clean(dst)


def test_resident_resume_rate_counts_attempts():
    """resume_attempts counts every try_resume of a previously
    interrupted uid — hits AND misses — so resident_resume_rate is a real
    hit rate, not resumed/resumed."""
    eng = make_slot()
    es = entries(2)
    eng.submit(es, version=0)
    checked_step(eng)
    eng.interrupt()
    # uid 0 resumes resident; uid 1's pages get evicted first => miss
    # (evict via the memory-pressure path, which keeps the interrupted
    # mark — an explicit release_seq is a deliberate drop, not a miss)
    del eng.kv._resident[es[1].uid]
    eng.kv._drop(es[1].uid)
    eng.submit([BufferEntry(uid=e.uid, prompt=list(e.prompt),
                            generated=[2]) for e in es], version=0)
    st = eng.cache_stats()
    assert st["resume_attempts"] == 2.0
    assert st["resumed_without_prefill"] == 1.0
    assert st["resident_resume_rate"] == pytest.approx(0.5)
    assert st["pool_capacity_tokens"] == (eng.num_pages - 1) * eng.page_size


@pytest.mark.parametrize("mode", [Mode.ON_POLICY, Mode.PARTIAL])
def test_paged_resume_without_reprefill(mode):
    """Interrupted entries keep pages resident: resubmitting scavenged
    entries runs ZERO new prefill tokens (observable via cache_stats),
    and the pool is clean after the resumed rollout drains."""
    eng = make_slot()
    buf = StatefulRolloutBuffer(mode)
    uids = buf.load_prompts([[1, 2, 3, 4, 5], [1, 2, 3, 4, 5]])
    buf.mark_running(uids)
    eng.submit(buf.running(), version=0)
    for _ in range(2):
        for ev in checked_step(eng):
            buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
            if ev.done:
                buf.mark_done(ev.uid, ev.finish_reason)
    for uid in eng.interrupt():
        buf.scavenge(uid)
    run_before = eng.cache_stats()["prefill_tokens_run"]
    saved_before = eng.cache_stats()["prefill_tokens_saved"]
    assert eng.cache_stats()["resident_seqs"] == 2
    resumed = buf.pending()
    buf.mark_running([e.uid for e in resumed])
    eng.submit(resumed, version=1)
    st = eng.cache_stats()
    assert st["prefill_tokens_run"] == run_before, "resume re-ran prefill"
    assert st["resumed_without_prefill"] == 2
    assert st["prefill_tokens_saved"] > saved_before
    for ev in run_to_completion(eng):
        buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 1)
        if ev.done:
            buf.mark_done(ev.uid, ev.finish_reason)
    buf.check_invariants()
    _drained_pool_is_clean(eng)


def test_paged_oversubscribed_pool_with_shared_prefixes():
    """A pool too small for CAPACITY dense sequences still serves a
    shared-prompt group: the prefix pages are mapped, not copied.  Dense
    sizing here would need capacity * ceil(31/16) = 8 pages; sharing fits
    in 6 (2 prefix + at most 4 COW write pages)."""
    plen = 25                       # pre = 24 rows = 1.5 pages of 16
    eng = make_slot(num_pages=7)    # 6 usable + garbage page
    assert eng.paged
    eng.submit(group_entries(CAPACITY, plen), version=0)
    evs = run_to_completion(eng)
    assert sum(1 for e in evs if e.done) == CAPACITY
    st = eng.cache_stats()
    assert st["prefill_tokens_run"] == plen - 1
    assert st["prefill_tokens_saved"] == (CAPACITY - 1) * (plen - 1)
    _drained_pool_is_clean(eng)


def test_paged_strict_sync_invalidates_stale_kv():
    """kv_retain_across_sync=False: a weight sync drops pre-sync resident
    prefixes, so scavenged entries re-prefill under the fresh policy
    (exact dense semantics — the on-policy re-roll setting)."""
    eng = make_slot(kv_retain_across_sync=False)
    e = BufferEntry(uid=0, prompt=[1, 2, 3, 4])
    eng.submit([e], version=0)
    checked_step(eng)
    eng.interrupt()
    run_before = eng.cache_stats()["prefill_tokens_run"]
    eng.sync_weights(1)
    assert eng.cache_stats()["pages_in_use"] == 0, "stale resident kept"
    eng.submit([e], version=1)
    st = eng.cache_stats()
    assert st["prefill_tokens_run"] > run_before, "resume skipped prefill"
    assert st["resumed_without_prefill"] == 0
    assert st["stale_kv_reuses"] == 0
    run_to_completion(eng)
    _drained_pool_is_clean(eng)


def test_paged_retaining_sync_reuses_and_counts_stale_kv():
    """Default (partial-mode) setting: resident pages survive the sync —
    the paper's cache mechanism — and each reuse of pre-sync KV is
    observable via the stale_kv_reuses counter."""
    eng = make_slot()                       # kv_retain_across_sync=True
    e = BufferEntry(uid=0, prompt=[1, 2, 3, 4])
    eng.submit([e], version=0)
    checked_step(eng)
    eng.interrupt()
    run_before = eng.cache_stats()["prefill_tokens_run"]
    eng.sync_weights(1)
    assert eng.cache_stats()["pages_in_use"] > 0, "resident pages dropped"
    eng.submit([e], version=1)
    st = eng.cache_stats()
    assert st["prefill_tokens_run"] == run_before
    assert st["resumed_without_prefill"] == 1
    assert st["stale_kv_reuses"] == 1
    run_to_completion(eng)
    _drained_pool_is_clean(eng)


def test_paged_pool_pressure_evicts_resident_lru():
    """Resident (interrupted) sequences are reclaimed under pool pressure
    instead of failing the submit."""
    eng = make_slot(num_pages=9)    # 8 usable pages
    eng.submit([BufferEntry(uid=i, prompt=[2 + i] * 20) for i in range(4)],
               version=0)
    checked_step(eng)
    eng.interrupt()                 # 4 resident seqs x 2 pages = full pool
    assert eng.cache_stats()["pages_in_use"] == 8
    eng.submit([BufferEntry(uid=10 + i, prompt=[9 + i] * 20)
                for i in range(4)], version=0)
    st = eng.cache_stats()
    assert st["evictions"] >= 3, st
    run_to_completion(eng)
    eng.kv.check_invariants()


def test_paged_metrics_flow_through_orchestrator():
    """RolloutOrchestrator surfaces prefill-tokens-saved and page-pool
    occupancy for paged engines."""
    from repro.core.orchestrator import RolloutOrchestrator, SortedRLConfig
    from repro.core.policy import make_policy
    eng = make_slot()
    buf = StatefulRolloutBuffer(Mode.ON_POLICY)
    cfg = SortedRLConfig(rollout_batch=CAPACITY, group_size=1,
                         update_batch=CAPACITY, max_gen_len=MAX_GEN)
    orch = RolloutOrchestrator(eng, buf, cfg, make_policy("baseline"),
                               lambda req: None)
    orch.run_group([[1, 2, 3]] * CAPACITY)      # one shared-prompt group
    s = orch.metrics.summary()
    assert s["prefill_tokens_saved"] == (CAPACITY - 1) * 2
    assert 0.0 < s["page_occupancy_peak"] <= 1.0


# -- EngineGroup (multi-replica) cases ----------------------------------------
#
# The group fixtures above already run the whole EngineProtocol contract
# against EngineGroup; these cases additionally pin the group-only
# behaviour: deterministic event merging, conservation across the merge,
# and home-affinity resume vs work-stealing migration.

def test_group_event_merge_order_is_replica_major():
    """Merged step events are the per-replica streams concatenated in
    replica order (each ascending-slot), and per-uid routing is stable."""
    eng = make_group_sim()
    eng.submit(entries(CAPACITY), version=0)
    by_replica = [list(r.active_uids()) for r in eng.replicas]
    assert sorted(u for uids in by_replica for u in uids) == list(
        range(CAPACITY))
    expect = [u for uids in by_replica for u in uids]
    evs = checked_step(eng)
    assert [ev.uid for ev in evs] == expect
    # stable while resident: the merged order only loses finished uids
    while eng.active_uids():
        live = set(eng.active_uids())
        evs = checked_step(eng)
        assert [ev.uid for ev in evs] == [u for u in expect if u in live]


def test_group_conservation_across_replicas():
    """Replica-failure-free conservation: with every replica healthy, an
    oversubscribed workload drains with each uid finishing exactly once
    across the merged streams, and the replica loads sum to the total."""
    n = 3 * CAPACITY
    eng = make_group_sim()
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    buf.load_prompts([[1, 2 + i % 7] for i in range(n)])
    done_counts = {}
    steps = 0
    while buf.pending() or buf.running():
        batch = buf.pending()[:eng.free_slots()]
        if batch:
            buf.mark_running([e.uid for e in batch])
            eng.submit(batch, version=0)
        assert sum(len(r.active_uids()) for r in eng.replicas) == \
            len(buf.running())
        for ev in checked_step(eng):
            buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
            if ev.done:
                done_counts[ev.uid] = done_counts.get(ev.uid, 0) + 1
                buf.mark_done(ev.uid, ev.finish_reason)
        steps += 1
        assert steps < 10_000
    assert done_counts == {uid: 1 for uid in range(n)}
    assert eng.free_slots() == CAPACITY
    buf.check_invariants()


def test_group_home_affinity_resume_zero_reprefill():
    """Interrupted entries route back to their home replica where the KV
    pages stayed resident: the group resumes them with ZERO re-prefill,
    exactly like a single paged engine."""
    eng = make_group_slot()
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    uids = buf.load_prompts([[1, 2, 3, 4, 5], [6, 7, 8, 9, 2]])
    buf.mark_running(uids)
    eng.submit(buf.running(), version=0)
    homes = {u: dict(eng._home)[u] for u in uids}
    assert sorted(homes.values()) == [0, 1], "balancer did not spread"
    for _ in range(2):
        for ev in checked_step(eng):
            buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
            if ev.done:
                buf.mark_done(ev.uid, ev.finish_reason)
    for uid in eng.interrupt():
        buf.scavenge(uid)
    st = eng.cache_stats()
    run_before = st["prefill_tokens_run"]
    assert st["resident_seqs"] == 2
    resumed = buf.pending()
    buf.mark_running([e.uid for e in resumed])
    eng.submit(resumed, version=0)
    st = eng.cache_stats()
    assert st["prefill_tokens_run"] == run_before, "resume re-ran prefill"
    assert st["resumed_without_prefill"] == len(resumed)
    assert st["steal_count"] == 0
    assert all(dict(eng._home)[u] == homes[u] for u in
               [e.uid for e in resumed]), "resume left its home replica"
    for ev in run_to_completion(eng):
        buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
        if ev.done:
            buf.mark_done(ev.uid, ev.finish_reason)
    buf.check_invariants()
    for r in eng.replicas:
        r.kv.check_invariants()


def test_group_steal_migrates_when_home_is_full():
    """Work stealing: a scavenged entry whose home replica is saturated
    migrates to another replica (counted in steal_count), re-prefills
    there, and still finishes within its budget."""
    eng = make_group_slot()
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    uids = buf.load_prompts([[1, 2, 3, 4, 5], [6, 7, 8, 9, 2]])
    buf.mark_running(uids)
    eng.submit(buf.running(), version=0)
    home0 = dict(eng._home)[uids[0]]
    for _ in range(2):
        for ev in checked_step(eng):
            buf.record_tokens(ev.uid, [ev.token], [ev.logprob], 0)
            if ev.done:
                buf.mark_done(ev.uid, ev.finish_reason)
    for uid in eng.interrupt():
        buf.scavenge(uid)
    # saturate uid0's home replica with fresh work
    fillers = [BufferEntry(uid=100 + i, prompt=[3, 1, 4, 1 + i])
               for i in range(3)]
    eng.submit(fillers, version=0)
    assert eng.replicas[home0].free_slots() == 0
    run_before = eng.cache_stats()["prefill_tokens_run"]
    victim = buf.entries[uids[0]]
    prefix = victim.gen_len
    buf.mark_running([victim.uid])
    eng.submit([victim], version=0)
    st = eng.cache_stats()
    assert st["steal_count"] == 1
    assert dict(eng._home)[victim.uid] != home0, "steal stayed home"
    assert st["prefill_tokens_run"] > run_before, \
        "migrated resume cannot reuse the home replica's pages"
    # the abandoned residency must be dropped, not left to rot in the
    # old home's pool until LRU pressure reaches it
    assert victim.uid not in eng.replicas[home0].kv.tables, \
        "steal left dead resident pages on the old home replica"
    new = sum(1 for ev in run_to_completion(eng) if ev.uid == victim.uid)
    assert 1 <= prefix + new <= MAX_GEN
    for r in eng.replicas:
        r.kv.check_invariants()
