"""Chunked SSD (Mamba2) and chunkwise mLSTM against their sequential
oracles, plus decode-step equivalence for both recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked, ssd_decode, ssd_ref
from repro.models.xlstm import mlstm_chunked, mlstm_decode, mlstm_ref

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("B,T,H,P,G,N,chunk", [
    (2, 64, 4, 16, 1, 8, 16),
    (1, 48, 2, 8, 2, 4, 16),
    (2, 33, 4, 16, 1, 8, 16),    # non-divisible tail padding
])
def test_ssd_chunked_vs_ref(B, T, H, P, G, N, chunk):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, T, H, P))
    a_log = -jnp.abs(jax.random.normal(ks[1], (B, T, H))) * 0.2
    B_ = jax.random.normal(ks[2], (B, T, G, N))
    C_ = jax.random.normal(ks[3], (B, T, G, N))
    y, s = ssd_chunked(x, a_log, B_, C_, chunk)
    y_ref, s_ref = ssd_ref(x, a_log, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=2e-4)


def test_ssd_decode_matches_scan():
    """Stepping T times with ssd_decode == full chunked pass."""
    B, T, H, P, G, N = 1, 16, 2, 8, 1, 4
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, T, H, P))
    a_log = -jnp.abs(jax.random.normal(ks[1], (B, T, H))) * 0.2
    B_ = jax.random.normal(ks[2], (B, T, G, N))
    C_ = jax.random.normal(ks[3], (B, T, G, N))
    y_ref, _ = ssd_ref(x, a_log, B_, C_)
    state = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(T):
        y, state = ssd_decode(x[:, t], a_log[:, t], B_[:, t], C_[:, t], state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), atol=1e-5)


@pytest.mark.parametrize("B,T,H,D,chunk", [
    (2, 64, 2, 16, 16),
    (1, 32, 4, 8, 8),
])
def test_mlstm_chunked_vs_ref(B, T, H, D, chunk):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    i_pre = jax.random.normal(ks[3], (B, T, H))
    f_pre = jax.random.normal(ks[4], (B, T, H)) + 2.0
    h, (C, n) = mlstm_chunked(q, k, v, i_pre, f_pre, chunk)
    h_ref, (C_ref, n_ref) = mlstm_ref(q, k, v, i_pre, f_pre)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref), rtol=2e-4,
                               atol=2e-4)


def test_mlstm_decode_matches_ref():
    B, T, H, D = 1, 12, 2, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    i_pre = jax.random.normal(ks[3], (B, T, H))
    f_pre = jax.random.normal(ks[4], (B, T, H)) + 2.0
    h_ref, _ = mlstm_ref(q, k, v, i_pre, f_pre)
    state = (jnp.zeros((B, H, D, D)), jnp.zeros((B, H, D)))
    hs = []
    for t in range(T):
        h, state = mlstm_decode(q[:, t], k[:, t], v[:, t], i_pre[:, t],
                                f_pre[:, t], state)
        hs.append(h)
    np.testing.assert_allclose(np.asarray(jnp.stack(hs, 1)),
                               np.asarray(h_ref), atol=1e-5)


def test_ssd_state_continuation():
    """Splitting a sequence in two with state carry == one pass (the
    property partial-mode resume relies on for SSM archs)."""
    B, T, H, P, G, N = 1, 32, 2, 8, 1, 4
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, T, H, P))
    a_log = -jnp.abs(jax.random.normal(ks[1], (B, T, H))) * 0.2
    B_ = jax.random.normal(ks[2], (B, T, G, N))
    C_ = jax.random.normal(ks[3], (B, T, G, N))
    y_full, s_full = ssd_chunked(x, a_log, B_, C_, 8)
    cut = 16
    y1, s1 = ssd_chunked(x[:, :cut], a_log[:, :cut], B_[:, :cut],
                         C_[:, :cut], 8)
    y2, s2 = ssd_chunked(x[:, cut:], a_log[:, cut:], B_[:, cut:],
                         C_[:, cut:], 8, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=2e-4)
