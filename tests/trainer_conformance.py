"""Trainer-conformance suite: the executable contract of the Trainer
protocol (repro.rl.trainer_api) behind the orchestrator's update path.

Both registered trainers (``sync`` / ``streaming``) are driven by the
shared RolloutOrchestrator across every registered scheduling policy,
both engine kinds (discrete-event SimEngine and real-decode SlotEngine),
and EngineGroup replica counts {1, 2, 4} — the same sweep surface as
policy_conformance, so a trainer swap inherits the whole scheduling
contract:

  * conservation — every loaded prompt is trained exactly once through
    either trainer front, and all owed updates are delivered even when
    completions land mid-rollout (overlap mode);
  * staleness accounting — every UpdateRequest's ``staleness_mean/max``
    equals the values recomputed from its entries' per-token version
    stamps, trainer front and overlap notwithstanding;
  * sync-mode identity — wrapping a bare TrainFn in a SyncTrainer (with
    a nonzero modeled cost) changes NOTHING observable about scheduling:
    trained uid order, per-entry token streams (greedy decode included),
    and per-request staleness stats are bit-identical to the deprecated
    bare-callable path;
  * mode semantics under overlap — a weight sync landing mid-trajectory
    leaves stitched pi_old entries (>= 2 distinct per-token versions) in
    partial mode, and NEVER leaves a mixed-version trained entry in
    on-policy mode (in-flight entries are invalidated at the sync).
"""
import pytest

from policy_conformance import (CAPACITY, GROUP, MAX_GEN, N_PROMPTS,
                                ENGINE_FACTORIES, prompts)
from repro.core.buffer import EntryState, Mode, StatefulRolloutBuffer
from repro.core.orchestrator import (RolloutOrchestrator, SortedRLConfig,
                                     UpdateRequest, UpdateResult)
from repro.core.policy import available_policies, make_policy
from repro.rl.trainer_api import (StreamingTrainer, SyncTrainer, Trainer,
                                  as_trainer, available_trainers,
                                  make_trainer)
from repro.rollout.sim import SimEngine, lognormal_lengths

# the ISSUE-mandated sweep surface: both engines, replicas {1, 2, 4}
ENGINE_NAMES = ("sim", "slot", "group1_sim", "group2_sim", "group4_sim",
                "group2_slot")
UPDATE_COST = 0.5     # modeled trainer seconds per batch (nonzero on
                      # purpose: cost accounting must not perturb anything)


def build(policy_name, engine_name, trainer_kind, mode=Mode.PARTIAL,
          **policy_kwargs):
    eng = ENGINE_FACTORIES[engine_name]()
    buf = StatefulRolloutBuffer(mode)
    cfg = SortedRLConfig(mode=mode, rollout_batch=CAPACITY,
                         group_size=GROUP, update_batch=CAPACITY,
                         max_gen_len=MAX_GEN,
                         overlap_updates=(trainer_kind == "streaming"))
    policy = make_policy(policy_name, **policy_kwargs)
    reqs = []
    trainer = make_trainer(trainer_kind, fn=reqs.append,
                           update_cost=UPDATE_COST)
    return RolloutOrchestrator(eng, buf, cfg, policy, trainer), reqs


_DRIVE_CACHE = {}


def drive(trainer_kind, policy_name, engine_name, n_groups=2):
    """Run the policy's native driving pattern behind the given trainer
    front (memoized — deterministic, and the invariant tests only read);
    returns (orchestrator, captured UpdateRequests, loaded count)."""
    key = (trainer_kind, policy_name, engine_name, n_groups)
    if key not in _DRIVE_CACHE:
        _DRIVE_CACHE[key] = _drive(trainer_kind, policy_name, engine_name,
                                   n_groups)
    return _DRIVE_CACHE[key]


def _drive(trainer_kind, policy_name, engine_name, n_groups):
    if policy_name == "ungrouped":
        stream = iter([(p, None) for p in prompts(n_groups * N_PROMPTS)])
        orch, reqs = build(policy_name, engine_name, trainer_kind,
                           prompt_stream=stream)
        orch.run_steps(n_updates=n_groups * GROUP)
        loaded = len(orch.buffer.entries)   # never advances groups
    elif policy_name == "pipelined":
        orch, reqs = build(policy_name, engine_name, trainer_kind)
        for g in range(n_groups):
            orch.policy.queue_group(prompts(N_PROMPTS, start=g))
        orch.run_queued()
        loaded = n_groups * N_PROMPTS
    else:
        orch, reqs = build(policy_name, engine_name, trainer_kind)
        for g in range(n_groups):
            orch.run_group(prompts(N_PROMPTS, start=g))
        loaded = n_groups * N_PROMPTS
    return orch, reqs, loaded


@pytest.fixture(params=ENGINE_NAMES)
def engine_name(request):
    return request.param


@pytest.fixture(params=available_policies())
def policy_name(request):
    return request.param


@pytest.fixture(params=available_trainers())
def trainer_kind(request):
    return request.param


# -- registry + shim surface --------------------------------------------------

def test_registry_contract():
    names = available_trainers()
    assert "sync" in names and "streaming" in names
    for name in names:
        t = make_trainer(name, fn=lambda req: None)
        assert isinstance(t, Trainer)
        assert t.name == name
        assert t.pending == 0
    with pytest.raises(KeyError):
        make_trainer("no_such_trainer")
    assert SyncTrainer(lambda r: None).supports_overlap is False
    assert StreamingTrainer(lambda r: None).supports_overlap is True


def test_as_trainer_shim():
    # deprecated bare-callable path: wrapped into a zero-cost SyncTrainer
    calls = []
    t = as_trainer(calls.append)
    assert isinstance(t, SyncTrainer) and t.update_cost == 0.0
    # a Trainer passes through untouched
    st = make_trainer("streaming", fn=lambda r: None)
    assert as_trainer(st) is st
    with pytest.raises(TypeError):
        as_trainer(42)


def test_overlap_requires_capability():
    eng = SimEngine(capacity=CAPACITY, max_gen_len=MAX_GEN, seed=0)
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=CAPACITY,
                         group_size=GROUP, update_batch=CAPACITY,
                         max_gen_len=MAX_GEN, overlap_updates=True)
    with pytest.raises(ValueError, match="supports_overlap"):
        RolloutOrchestrator(eng, buf, cfg, make_policy("sorted"),
                            lambda req: None)


def test_negative_cost_rejected():
    t = make_trainer("sync", fn=lambda r: None, update_cost=-1.0)
    req = UpdateRequest(entries=[], version=0, group_epoch=0, final=True,
                        staleness_mean=0.0, staleness_max=0.0)
    with pytest.raises(ValueError, match=">= 0"):
        t.submit(req, now=0.0)


# -- the sweep: trainers x policies x engines x replicas ----------------------

def test_conservation(trainer_kind, policy_name, engine_name):
    orch, reqs, loaded = drive(trainer_kind, policy_name, engine_name)
    uids = [e.uid for r in reqs for e in r.entries]
    assert len(uids) == len(set(uids)), "an entry trained twice"
    if policy_name == "ungrouped":
        consumed = {u for u, e in orch.buffer.entries.items()
                    if e.state == EntryState.CONSUMED}
        assert set(uids) == consumed
    else:
        assert sorted(uids) == list(range(loaded)), \
            "every loaded prompt must be trained exactly once"
    # the trainer front must end drained: nothing submitted is in flight
    assert orch.trainer.pending == 0


def test_all_updates_delivered(trainer_kind, policy_name, engine_name):
    orch, reqs, loaded = drive(trainer_kind, policy_name, engine_name)
    assert orch.engine.free_slots() == orch.engine.capacity
    if policy_name == "ungrouped":
        return   # starves long prompts by design
    assert orch.buffer.group_clear()
    delivered = orch.metrics.updates + orch.metrics.updates_gated
    if orch.policy.strict_group_barrier:
        assert delivered == loaded // CAPACITY
    else:
        assert delivered >= loaded // CAPACITY
    # trainer-busy accounting: every delivered update charged its cost
    assert orch.metrics.update_time_total == pytest.approx(
        UPDATE_COST * orch.metrics.updates)


def test_staleness_accounting(trainer_kind, policy_name, engine_name):
    """Every request's staleness stats must equal the values recomputed
    from its entries' per-token version stamps — overlap must not change
    the accounting, only WHEN the version advances."""
    _, reqs, _ = drive(trainer_kind, policy_name, engine_name)
    assert reqs
    for r in reqs:
        st = [e.staleness(r.version) for e in r.entries]
        assert r.staleness_mean == pytest.approx(sum(st) / len(st))
        assert r.staleness_max == pytest.approx(max(st))


def test_buffer_invariants_throughout(trainer_kind, policy_name,
                                      engine_name):
    orch, _, _ = drive(trainer_kind, policy_name, engine_name)
    orch.buffer.check_invariants()


# -- sync-mode identity: the protocol shim changes nothing --------------------

def _token_streams(reqs):
    return {e.uid: (tuple(e.generated), tuple(e.versions))
            for r in reqs for e in r.entries}


def test_sync_mode_identity(policy_name, engine_name):
    """Bare callable (deprecated path) vs SyncTrainer with a modeled cost:
    trained uid order, token streams, version stamps, and staleness stats
    must be identical — cost accounting is observability-only."""
    # side A: the memoized sweep run behind SyncTrainer(update_cost>0);
    # side B: a fresh run through the deprecated bare-callable shim path
    orch_a, reqs_a, _ = drive("sync", policy_name, engine_name)
    if policy_name == "ungrouped":
        stream_b = iter([(p, None) for p in prompts(2 * N_PROMPTS)])
        orch_b, reqs_b = _build_bare(policy_name, engine_name,
                                     prompt_stream=stream_b)
        orch_b.run_steps(n_updates=2 * GROUP)
    elif policy_name == "pipelined":
        orch_b, reqs_b = _build_bare(policy_name, engine_name)
        for g in range(2):
            orch_b.policy.queue_group(prompts(N_PROMPTS, start=g))
        orch_b.run_queued()
    else:
        orch_b, reqs_b = _build_bare(policy_name, engine_name)
        for g in range(2):
            orch_b.run_group(prompts(N_PROMPTS, start=g))
    assert [[e.uid for e in r.entries] for r in reqs_a] == \
           [[e.uid for e in r.entries] for r in reqs_b]
    assert _token_streams(reqs_a) == _token_streams(reqs_b)
    assert [(r.staleness_mean, r.staleness_max) for r in reqs_a] == \
           [(r.staleness_mean, r.staleness_max) for r in reqs_b]
    # only the accounting differs: the shim run charged its modeled cost
    # (approx: wall-clock engines drift a few µs between submit and drain)
    assert orch_a.metrics.update_time_total == pytest.approx(
        UPDATE_COST * orch_a.metrics.updates)
    assert orch_a.metrics.update_overlap_frac == pytest.approx(0.0, abs=1e-3)
    assert orch_b.metrics.update_time_total == 0.0


def _build_bare(policy_name, engine_name, **policy_kwargs):
    """The deprecated bare-callable hand-off (as_trainer shim target)."""
    eng = ENGINE_FACTORIES[engine_name]()
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=CAPACITY,
                         group_size=GROUP, update_batch=CAPACITY,
                         max_gen_len=MAX_GEN)
    reqs = []
    orch = RolloutOrchestrator(eng, buf, cfg, make_policy(policy_name,
                                                          **policy_kwargs),
                               reqs.append)
    return orch, reqs


@pytest.mark.slow
def test_greedy_token_identity_slot():
    """Greedy (temperature 0) real decode: the SyncTrainer shim must not
    change a single sampled token vs the bare-callable path."""
    from engine_conformance import MAX_TOTAL, _tiny_model
    from repro.data import logic
    from repro.rollout.engine import SlotEngine

    def run(train_fn_or_trainer):
        t = _tiny_model()
        eng = SlotEngine(t["model"], lambda: t["params"], capacity=CAPACITY,
                         max_total_len=MAX_TOTAL, max_gen_len=MAX_GEN,
                         eos_id=logic.VOCAB.eos_id, pad_id=t["pad"],
                         temperature=0.0)
        buf = StatefulRolloutBuffer(Mode.PARTIAL)
        cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=CAPACITY,
                             group_size=GROUP, update_batch=CAPACITY,
                             max_gen_len=MAX_GEN)
        reqs = []
        fn = (make_trainer("sync", fn=reqs.append, update_cost=UPDATE_COST)
              if train_fn_or_trainer == "trainer" else reqs.append)
        orch = RolloutOrchestrator(eng, buf, cfg, make_policy("sorted"), fn)
        orch.run_group(prompts(N_PROMPTS))
        return reqs

    assert _token_streams(run("trainer")) == _token_streams(run("bare"))


# -- overlap semantics: retain vs invalidate at the in-flight sync ------------

def _overlap_sim(mode, update_cost=0.3):
    eng = SimEngine(capacity=8, max_gen_len=64, seed=0,
                    length_sampler=lognormal_lengths(median=16, sigma=1.0,
                                                     max_len=64))
    buf = StatefulRolloutBuffer(mode)
    cfg = SortedRLConfig(mode=mode, rollout_batch=8, group_size=4,
                         update_batch=8, max_gen_len=64,
                         overlap_updates=True)
    reqs = []
    trainer = make_trainer("streaming", fn=reqs.append,
                           update_cost=update_cost)
    orch = RolloutOrchestrator(eng, buf, cfg, make_policy("sorted"),
                               trainer)
    orch.run_group([[1, 1, 1, 2 + i % 5] for i in range(32)])
    return orch, reqs


def test_overlap_partial_stitches_pi_old():
    """A sync landing mid-trajectory must leave stitched entries (tokens
    recorded under >= 2 policy versions) in partial mode, with staleness
    stats that still recompute exactly from the stamps."""
    orch, reqs = _overlap_sim(Mode.PARTIAL)
    stitched = [e for r in reqs for e in r.entries
                if len(set(e.versions)) > 1]
    assert stitched, "no sync landed mid-trajectory — overlap not exercised"
    for r in reqs:
        st = [e.staleness(r.version) for e in r.entries]
        assert r.staleness_mean == pytest.approx(sum(st) / len(st))
        assert r.staleness_max == pytest.approx(max(st))
    assert orch.metrics.update_overlap_frac > 0.0


def test_overlap_on_policy_invalidates():
    """On-policy overlap: the in-flight sync invalidates running entries,
    so no trained trajectory ever mixes policy versions — and the
    discarded tokens show up in the scavenging waste counter."""
    orch, reqs = _overlap_sim(Mode.ON_POLICY)
    for r in reqs:
        for e in r.entries:
            assert len(set(e.versions)) <= 1, \
                f"on-policy entry {e.uid} trained across a sync: " \
                f"{sorted(set(e.versions))}"
    assert orch.metrics.tokens_discarded > 0
    assert orch.metrics.updates == len(reqs)
    # conservation survives the invalidations: every prompt still trains
    assert sum(len(r.entries) for r in reqs) == 32


def test_overlap_strictly_faster_than_serial():
    """The acceptance relation behind the overlap/fig1a_* bench rows, in
    miniature: same workload + same modeled trainer cost, overlapped
    wall-clock strictly below serialized, same work delivered."""
    def run(overlap):
        eng = SimEngine(capacity=8, max_gen_len=64, seed=0,
                        length_table={u: 4 + (u * 7) % 48
                                      for u in range(32)})
        buf = StatefulRolloutBuffer(Mode.PARTIAL)
        cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=8,
                             group_size=4, update_batch=8, max_gen_len=64,
                             overlap_updates=overlap)
        trainer = make_trainer("streaming" if overlap else "sync",
                               fn=lambda r: None, update_cost=0.3)
        orch = RolloutOrchestrator(eng, buf, cfg, make_policy("sorted"),
                                   trainer)
        orch.run_group([[1, 1, 1, 2 + i % 5] for i in range(32)])
        return orch.metrics

    serial, stream = run(False), run(True)
    assert serial.updates == stream.updates
    assert serial.tokens_generated == stream.tokens_generated
    assert stream.elapsed < serial.elapsed, (stream.elapsed, serial.elapsed)
    assert stream.update_overlap_frac > 0.0
    assert serial.update_overlap_frac == pytest.approx(0.0, abs=1e-9)
    assert serial.update_time_stalled == pytest.approx(
        serial.update_time_total)


# -- batch_skipped conservation visibility ------------------------------------

def test_batch_skipped_metric():
    """entries_to_batch reports skipped entries via UpdateResult metrics;
    the orchestrator folds them into metrics.batch_skipped so conservation
    checks can see silently-dropped entries."""
    eng = SimEngine(capacity=CAPACITY, max_gen_len=MAX_GEN, seed=0)
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    cfg = SortedRLConfig(mode=Mode.PARTIAL, rollout_batch=CAPACITY,
                         group_size=GROUP, update_batch=CAPACITY,
                         max_gen_len=MAX_GEN)

    def fn(req):
        return UpdateResult(metrics={"entries_skipped": 2.0})

    orch = RolloutOrchestrator(eng, buf, cfg, make_policy("sorted"), fn)
    orch.run_group(prompts(N_PROMPTS))
    assert orch.metrics.batch_skipped == 2 * orch.metrics.updates
    assert orch.metrics.summary()["batch_skipped"] == \
        orch.metrics.batch_skipped
