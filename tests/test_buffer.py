"""Property-based tests (hypothesis) on the stateful rollout buffer's
invariants: conservation (every prompt trained exactly once), per-mode
scavenging semantics, token/logprob/version alignment, grouped loading."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.buffer import (BufferEntry, EntryState, Mode,
                               StatefulRolloutBuffer)


def test_on_policy_scavenge_discards():
    buf = StatefulRolloutBuffer(Mode.ON_POLICY)
    [uid] = buf.load_prompts([[1, 2, 3]])
    buf.mark_running([uid])
    buf.record_tokens(uid, [5, 6], [-0.5, -0.7], version=0)
    buf.scavenge(uid)
    e = buf.entries[uid]
    assert e.generated == [] and e.logprobs == [] and e.versions == []
    assert e.interruptions == 1 and e.state == EntryState.PENDING


def test_partial_scavenge_keeps_prefix():
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    [uid] = buf.load_prompts([[1, 2, 3]])
    buf.mark_running([uid])
    buf.record_tokens(uid, [5, 6], [-0.5, -0.7], version=0)
    buf.scavenge(uid)
    buf.mark_running([uid])
    buf.record_tokens(uid, [7], [-0.1], version=1)
    e = buf.entries[uid]
    assert e.generated == [5, 6, 7]
    assert e.logprobs == [-0.5, -0.7, -0.1]
    assert e.versions == [0, 0, 1]         # stitched pi_old across versions
    assert e.staleness(1) == (1 + 1 + 0) / 3


@settings(max_examples=50, deadline=None)
@given(
    n_prompts=st.integers(1, 30),
    mode=st.sampled_from([Mode.ON_POLICY, Mode.PARTIAL]),
    schedule=st.lists(st.tuples(st.integers(0, 4), st.booleans()),
                      min_size=1, max_size=40),
)
def test_conservation(n_prompts, mode, schedule):
    """Under arbitrary run/record/scavenge/done interleavings, every prompt
    is consumed exactly once and alignment invariants hold throughout."""
    buf = StatefulRolloutBuffer(mode)
    buf.load_prompts([[1]] * n_prompts)
    version = 0
    for step, (k, interrupt) in enumerate(schedule):
        pending = buf.pending()[:max(k, 0) + 1]
        if pending:
            buf.mark_running([e.uid for e in pending])
        for e in buf.running():
            buf.record_tokens(e.uid, [step % 7], [-1.0], version)
        running = buf.running()
        for i, e in enumerate(running):
            if interrupt and i % 2 == 0:
                buf.scavenge(e.uid)
            else:
                buf.mark_done(e.uid, "eos")
        buf.consume([e.uid for e in buf.done()])
        buf.check_invariants()
        version += 1
    # drain: everything left finishes
    while buf.unconsumed():
        pend = buf.pending()
        if pend:
            buf.mark_running([e.uid for e in pend])
        for e in buf.running():
            buf.record_tokens(e.uid, [0], [-1.0], version)
            buf.mark_done(e.uid, "length")
        buf.consume([e.uid for e in buf.done()])
        buf.check_invariants()
    consumed = [e for e in buf.entries.values()
                if e.state == EntryState.CONSUMED]
    assert len(consumed) == n_prompts          # exactly once each
    buf.advance_group()
    assert buf.group_epoch == 1 and not buf.entries


@settings(max_examples=30, deadline=None)
@given(mode=st.sampled_from([Mode.ON_POLICY, Mode.PARTIAL]),
       interrupts=st.integers(0, 5))
def test_alignment_after_interruptions(mode, interrupts):
    buf = StatefulRolloutBuffer(mode)
    [uid] = buf.load_prompts([[1, 2]])
    for v in range(interrupts + 1):
        buf.mark_running([uid])
        buf.record_tokens(uid, [v, v + 1], [-0.1 * v, -0.2], v)
        if v < interrupts:
            buf.scavenge(uid)
    buf.mark_done(uid, "eos")
    e = buf.entries[uid]
    assert len(e.generated) == len(e.logprobs) == len(e.versions)
    if mode == Mode.PARTIAL:
        assert len(e.generated) == 2 * (interrupts + 1)
        assert e.interruptions == interrupts
    else:
        assert len(e.generated) == 2


def test_grouped_loading_barrier():
    buf = StatefulRolloutBuffer(Mode.ON_POLICY)
    buf.load_prompts([[1], [2]])
    assert not buf.group_clear()
    try:
        buf.advance_group()
        raised = False
    except AssertionError:
        raised = True
    assert raised


def test_pipelined_lookahead():
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    buf.load_prompts([[1]])
    buf.load_prompts_next_group([[2]])
    assert buf.group_epoch_load_allowed()
    lifecycles = sorted(e.lifecycle for e in buf.unconsumed())
    assert lifecycles == [0, 1]
    # consume group 0, advance non-strictly
    e0 = [e for e in buf.unconsumed() if e.lifecycle == 0][0]
    buf.mark_running([e0.uid])
    buf.record_tokens(e0.uid, [1], [-1.0], 0)
    buf.mark_done(e0.uid, "eos")
    buf.consume([e0.uid])
    assert buf.current_group_clear() and not buf.group_clear()
    buf.advance_group(strict=False)
    assert buf.group_epoch == 1
