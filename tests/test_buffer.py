"""Property-based tests (tests/proptest.py) on the stateful rollout
buffer's invariants: conservation (every prompt trained exactly once),
per-mode scavenging semantics, token/logprob/version alignment, grouped
loading."""
from proptest import booleans, cases, integers, lists, sampled_from, tuples

from repro.core.buffer import (BufferEntry, EntryState, Mode,
                               StatefulRolloutBuffer)


def test_on_policy_scavenge_discards():
    buf = StatefulRolloutBuffer(Mode.ON_POLICY)
    [uid] = buf.load_prompts([[1, 2, 3]])
    buf.mark_running([uid])
    buf.record_tokens(uid, [5, 6], [-0.5, -0.7], version=0)
    buf.scavenge(uid)
    e = buf.entries[uid]
    assert e.generated == [] and e.logprobs == [] and e.versions == []
    assert e.interruptions == 1 and e.state == EntryState.PENDING


def test_partial_scavenge_keeps_prefix():
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    [uid] = buf.load_prompts([[1, 2, 3]])
    buf.mark_running([uid])
    buf.record_tokens(uid, [5, 6], [-0.5, -0.7], version=0)
    buf.scavenge(uid)
    buf.mark_running([uid])
    buf.record_tokens(uid, [7], [-0.1], version=1)
    e = buf.entries[uid]
    assert e.generated == [5, 6, 7]
    assert e.logprobs == [-0.5, -0.7, -0.1]
    assert e.versions == [0, 0, 1]         # stitched pi_old across versions
    assert e.staleness(1) == (1 + 1 + 0) / 3


@cases(max_examples=50,
       n_prompts=integers(1, 30),
       mode=sampled_from([Mode.ON_POLICY, Mode.PARTIAL]),
       schedule=lists(tuples(integers(0, 4), booleans()),
                      min_size=1, max_size=40))
def test_conservation(n_prompts, mode, schedule):
    """Under arbitrary run/record/scavenge/done interleavings, every prompt
    is consumed exactly once and alignment invariants hold throughout."""
    buf = StatefulRolloutBuffer(mode)
    buf.load_prompts([[1]] * n_prompts)
    version = 0
    for step, (k, interrupt) in enumerate(schedule):
        pending = buf.pending()[:max(k, 0) + 1]
        if pending:
            buf.mark_running([e.uid for e in pending])
        for e in buf.running():
            buf.record_tokens(e.uid, [step % 7], [-1.0], version)
        running = buf.running()
        for i, e in enumerate(running):
            if interrupt and i % 2 == 0:
                buf.scavenge(e.uid)
            else:
                buf.mark_done(e.uid, "eos")
        buf.consume([e.uid for e in buf.done()])
        buf.check_invariants()
        version += 1
    # drain: everything left finishes
    while buf.unconsumed():
        pend = buf.pending()
        if pend:
            buf.mark_running([e.uid for e in pend])
        for e in buf.running():
            buf.record_tokens(e.uid, [0], [-1.0], version)
            buf.mark_done(e.uid, "length")
        buf.consume([e.uid for e in buf.done()])
        buf.check_invariants()
    consumed = [e for e in buf.entries.values()
                if e.state == EntryState.CONSUMED]
    assert len(consumed) == n_prompts          # exactly once each
    buf.advance_group()
    assert buf.group_epoch == 1 and not buf.entries


@cases(max_examples=30,
       mode=sampled_from([Mode.ON_POLICY, Mode.PARTIAL]),
       interrupts=integers(0, 5))
def test_alignment_after_interruptions(mode, interrupts):
    buf = StatefulRolloutBuffer(mode)
    [uid] = buf.load_prompts([[1, 2]])
    for v in range(interrupts + 1):
        buf.mark_running([uid])
        buf.record_tokens(uid, [v, v + 1], [-0.1 * v, -0.2], v)
        if v < interrupts:
            buf.scavenge(uid)
    buf.mark_done(uid, "eos")
    e = buf.entries[uid]
    assert len(e.generated) == len(e.logprobs) == len(e.versions)
    if mode == Mode.PARTIAL:
        assert len(e.generated) == 2 * (interrupts + 1)
        assert e.interruptions == interrupts
    else:
        assert len(e.generated) == 2


def test_grouped_loading_barrier():
    buf = StatefulRolloutBuffer(Mode.ON_POLICY)
    buf.load_prompts([[1], [2]])
    assert not buf.group_clear()
    try:
        buf.advance_group()
        raised = False
    except AssertionError:
        raised = True
    assert raised


def test_pipelined_lookahead():
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    buf.load_prompts([[1]])
    buf.load_prompts_next_group([[2]])
    assert buf.group_epoch_load_allowed()
    lifecycles = sorted(e.lifecycle for e in buf.unconsumed())
    assert lifecycles == [0, 1]
    # consume group 0, advance non-strictly
    e0 = [e for e in buf.unconsumed() if e.lifecycle == 0][0]
    buf.mark_running([e0.uid])
    buf.record_tokens(e0.uid, [1], [-1.0], 0)
    buf.mark_done(e0.uid, "eos")
    buf.consume([e0.uid])
    assert buf.current_group_clear() and not buf.group_clear()
    buf.advance_group(strict=False)
    assert buf.group_epoch == 1


# -- paper-implied edge cases not covered above -------------------------------

@cases(max_examples=20, rounds=integers(2, 6))
def test_scavenge_after_resume_version_stitching(rounds):
    """A partial-mode entry interrupted in EVERY round carries a version
    record that stitches the full history: tokens of round r tagged with
    version r, monotonically non-decreasing, aligned with logprobs."""
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    [uid] = buf.load_prompts([[1, 2, 3]])
    for r in range(rounds):
        buf.mark_running([uid])
        buf.record_tokens(uid, [10 + r, 20 + r], [-0.1, -0.2], version=r)
        buf.check_invariants()
        if r < rounds - 1:
            buf.scavenge(uid)
    buf.mark_done(uid, "eos")
    e = buf.entries[uid]
    assert e.interruptions == rounds - 1
    assert e.versions == [v for r in range(rounds) for v in (r, r)]
    assert e.versions == sorted(e.versions)          # stitched, in order
    assert len(e.generated) == len(e.logprobs) == 2 * rounds
    # staleness at consumption time (version == rounds) matches the record
    want = sum(rounds - v for v in e.versions) / len(e.versions)
    assert abs(e.staleness(rounds) - want) < 1e-12


def test_advance_group_nonstrict_lookahead_bound():
    """advance_group(strict=False) requires only the *current* epoch to be
    consumed, and the lookahead window stays bounded at one group."""
    buf = StatefulRolloutBuffer(Mode.PARTIAL)
    buf.load_prompts([[1]])
    [nxt] = buf.load_prompts_next_group([[2]])
    # current group not consumed -> even the relaxed advance must refuse
    try:
        buf.advance_group(strict=False)
        raised = False
    except AssertionError:
        raised = True
    assert raised
    # consume the current group; relaxed advance then succeeds
    [e0] = [e for e in buf.unconsumed() if e.lifecycle == 0]
    buf.mark_running([e0.uid])
    buf.record_tokens(e0.uid, [1], [-1.0], 0)
    buf.mark_done(e0.uid, "eos")
    buf.consume([e0.uid])
    buf.advance_group(strict=False)
    assert buf.group_epoch == 1
    # the lookahead entry survived the advance and is now current-epoch
    assert buf.entries[nxt].lifecycle == buf.group_epoch
    assert buf.group_epoch_load_allowed()
    buf.load_prompts_next_group([[3]])               # epoch 2: still allowed
    assert buf.group_epoch_load_allowed()
    buf.check_invariants()                           # lifecycle <= epoch + 1


def test_staleness_mixed_version_trajectory():
    """staleness() is the mean per-token version lag, not the worst case."""
    e = BufferEntry(uid=0, prompt=[1], generated=[5, 6, 7],
                    logprobs=[-1.0] * 3, versions=[0, 2, 3])
    assert abs(e.staleness(4) - (4 + 2 + 1) / 3) < 1e-12
    assert abs(e.staleness(3) - (3 + 1 + 0) / 3) < 1e-12
    # no generated tokens -> zero staleness by definition
    assert BufferEntry(uid=1, prompt=[1]).staleness(7) == 0.0
