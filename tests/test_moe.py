"""MoE: capacity-dispatch path vs the loop-over-experts oracle, aux-loss
sanity, capacity-drop behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import moe as MOE

KEY = jax.random.PRNGKey(5)


def _setup(capacity_factor=8.0, experts=4, k=2):
    cfg = get_smoke_config("granite_moe_3b_a800m")
    cfg = cfg.replace(param_dtype=jnp.float32, compute_dtype=jnp.float32,
                      moe=cfg.moe.__class__(
                          num_experts=experts, experts_per_token=k,
                          d_ff_expert=32, capacity_factor=capacity_factor))
    p = MOE.init_moe_mlp(KEY, cfg, jnp.float32)
    return cfg, p


def test_dense_dispatch_matches_oracle():
    """With generous capacity (no drops) the scatter/gather path equals the
    explicit loop over experts."""
    cfg, p = _setup(capacity_factor=8.0)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 16, cfg.d_model))
    y, aux = MOE.moe_mlp_dense(p, cfg, x)
    y_ref = MOE.moe_mlp_ref(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
    assert float(aux["load_balance"]) > 0


def test_capacity_drop():
    """With capacity_factor << 1 some tokens are dropped (output zero for
    their expert contribution) but nothing NaNs."""
    cfg, p = _setup(capacity_factor=0.1)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 32, cfg.d_model))
    y, _ = MOE.moe_mlp_dense(p, cfg, x)
    y_ref = MOE.moe_mlp_ref(p, cfg, x)
    assert not bool(jnp.isnan(y).any())
    # dropped tokens make outputs differ from the no-drop oracle
    assert float(jnp.abs(y - y_ref).max()) > 1e-3


def test_dispatch_indices_capacity_order():
    idx = jnp.array([[0], [0], [0], [1]])
    pos, keep = MOE._dispatch_indices(idx, E=2, C=2)
    np.testing.assert_array_equal(np.asarray(pos[:, 0]), [0, 1, 2, 0])
    np.testing.assert_array_equal(np.asarray(keep[:, 0]),
                                  [True, True, False, True])


def test_ep_path_matches_dense_single_device():
    """shard_map EP path on a 1x1 mesh == dense-dispatch path."""
    from repro.launch.mesh import make_compat_mesh
    cfg, p = _setup(capacity_factor=8.0, experts=4, k=2)
    mesh = make_compat_mesh((1, 1), ("data", "model"))
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 16, cfg.d_model))
    y_ep, aux_ep = MOE.moe_mlp_ep(p, cfg, x, mesh)
    y_d, _ = MOE.moe_mlp_dense(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_d), atol=2e-5)
