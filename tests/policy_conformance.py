"""Policy-conformance suite: the executable contract of SchedulerPolicy.

Every registered policy (repro.core.policy registry) is driven by the
shared RolloutOrchestrator against BOTH the discrete-event SimEngine and
a tiny-model SlotEngine (real JAX decode), so a new registry entry
inherits the whole contract:

  * conservation — every prompt loaded into a group run is trained
    exactly once (streaming policies: trained uids are unique and no
    admitted entry is silently dropped);
  * curriculum ordering — update batches are monotone in the policy's
    ``train_order_key`` whenever the policy declares ``ordered_training``;
  * no-starvation — the workload drains: the engine ends empty, the
    buffer ends clear, and every update the workload owes is delivered;
  * group barrier — trained lifecycles never decrease (group g trains
    before group g+1); strict policies never mix epochs inside a run.

Also pins the update-gate mechanics (PipelineRL-style staleness cap):
vetoed batches are consumed-but-untrained and counted in
``metrics.updates_gated``, without breaking conservation of consumption.
"""
import pytest

from engine_conformance import make_slot
from repro.core.buffer import EntryState, Mode, StatefulRolloutBuffer
from repro.core.orchestrator import (RolloutOrchestrator, SortedRLConfig,
                                     UpdateRequest)
from repro.core.policy import (SchedulerPolicy, available_policies,
                               make_policy)
from repro.rollout.sim import SimEngine, lognormal_lengths

CAPACITY = 4
MAX_GEN = 6
GROUP = 2
N_PROMPTS = CAPACITY * GROUP            # one group


def make_sim_varied():
    # short-median sampler so generation lengths actually vary in [1, 6]
    return SimEngine(capacity=CAPACITY, max_gen_len=MAX_GEN, seed=0,
                     length_sampler=lognormal_lengths(median=3, sigma=0.8,
                                                      max_len=MAX_GEN))


def make_slot_varied():
    # real eos id: sampled decode finishes early sometimes (varied lengths)
    from repro.data import logic
    return make_slot(eos_id=logic.VOCAB.eos_id)


def make_group_sim_varied(n_replicas):
    """EngineGroup over `n_replicas` SimEngine shards of the same total
    capacity — the replica sweep: every policy must hold its contract
    regardless of how rollout is sharded."""
    from repro.rollout.group import EngineGroup

    def factory():
        return EngineGroup([
            SimEngine(capacity=CAPACITY // n_replicas, max_gen_len=MAX_GEN,
                      seed=i,
                      length_sampler=lognormal_lengths(median=3, sigma=0.8,
                                                       max_len=MAX_GEN))
            for i in range(n_replicas)])
    return factory


def make_group_slot_varied():
    # real-decode replica coverage: two paged SlotEngine shards
    from engine_conformance import make_group_slot
    from repro.data import logic
    return make_group_slot(eos_id=logic.VOCAB.eos_id)


def make_slot_roofline():
    """All three roofline knobs at once — packed segment-masked prefill,
    fused greedy sampling (latent under sampled decode), and int8 KV
    pages — must satisfy every scheduling-policy contract unchanged."""
    from repro.data import logic
    return make_slot(eos_id=logic.VOCAB.eos_id, packed_prefill=True,
                     fused_sampling=True, kv_quant="int8")


def make_group_sim_tail(n_replicas, **group_kw):
    """Replica sweep with the PR-5 tail machinery on: async stepping,
    drain-phase packing, migration, and simulated KV residency.  Every
    policy must hold the whole contract with entries migrating between
    replicas mid-flight."""
    from repro.rollout.group import EngineGroup

    def factory():
        return EngineGroup([
            SimEngine(capacity=CAPACITY // n_replicas, max_gen_len=MAX_GEN,
                      seed=i, kv_residency=True,
                      length_sampler=lognormal_lengths(median=3, sigma=0.8,
                                                       max_len=MAX_GEN))
            for i in range(n_replicas)], **group_kw)
    return factory


ENGINE_FACTORIES = {"sim": make_sim_varied, "slot": make_slot_varied,
                    "slot_roofline": make_slot_roofline,
                    # num_replicas sweep {1, 2, 4} (total capacity fixed)
                    "group1_sim": make_group_sim_varied(1),
                    "group2_sim": make_group_sim_varied(2),
                    "group4_sim": make_group_sim_varied(4),
                    "group2_slot": make_group_slot_varied,
                    # PR-5 tail machinery (async + drain_pack + migration)
                    "group4_sim_async": make_group_sim_tail(
                        4, async_step=True, migrate_kv=True),
                    "group2_sim_pack": make_group_sim_tail(
                        2, balancer="drain_pack", async_step=True)}


def prompts(n, start=0):
    return [[1, 1, 1, 2 + (start + i) % 5] for i in range(n)]


def build(policy_name, engine_name, mode=Mode.PARTIAL, **policy_kwargs):
    eng = ENGINE_FACTORIES[engine_name]()
    buf = StatefulRolloutBuffer(mode)
    cfg = SortedRLConfig(mode=mode, rollout_batch=CAPACITY,
                         group_size=GROUP, update_batch=CAPACITY,
                         max_gen_len=MAX_GEN)
    policy = make_policy(policy_name, **policy_kwargs)
    batches = []

    def train_fn(req: UpdateRequest):
        batches.append((list(req.entries), req.group_epoch))

    return RolloutOrchestrator(eng, buf, cfg, policy, train_fn), batches


_DRIVE_CACHE = {}


def drive(policy_name, engine_name, n_groups=2):
    """Run `n_groups` groups' worth of work in the policy's native driving
    pattern (memoized — the run is deterministic and the invariant tests
    only read); returns (orchestrator, trained batches, loaded prompt
    count)."""
    key = (policy_name, engine_name, n_groups)
    if key not in _DRIVE_CACHE:
        _DRIVE_CACHE[key] = _drive(policy_name, engine_name, n_groups)
    return _DRIVE_CACHE[key]


def _drive(policy_name, engine_name, n_groups):
    if policy_name == "ungrouped":
        stream = iter([(p, None) for p in prompts(n_groups * N_PROMPTS)])
        orch, batches = build(policy_name, engine_name,
                              prompt_stream=stream)
        orch.run_steps(n_updates=n_groups * GROUP)
        loaded = len(orch.buffer.entries)   # never advances groups
    elif policy_name == "pipelined":
        orch, batches = build(policy_name, engine_name)
        for g in range(n_groups):
            orch.policy.queue_group(prompts(N_PROMPTS, start=g))
        orch.run_queued()
        loaded = n_groups * N_PROMPTS
    else:
        orch, batches = build(policy_name, engine_name)
        for g in range(n_groups):
            orch.run_group(prompts(N_PROMPTS, start=g))
        loaded = n_groups * N_PROMPTS
    return orch, batches, loaded


@pytest.fixture(params=sorted(ENGINE_FACTORIES))
def engine_name(request):
    return request.param


@pytest.fixture(params=available_policies())
def policy_name(request):
    return request.param


# -- registry surface ---------------------------------------------------------

def test_registry_contract():
    names = available_policies()
    # the four paper strategies + the beyond-paper pipelined variant must
    # all be selectable by name
    for required in ("sorted", "baseline", "posthoc_sort", "ungrouped",
                     "pipelined"):
        assert required in names
    for name in names:
        p = make_policy(name)
        assert isinstance(p, SchedulerPolicy)
        assert p.name == name
    with pytest.raises(KeyError):
        make_policy("no_such_policy")


# -- the four invariants, every policy x both engines -------------------------

def test_conservation(policy_name, engine_name):
    orch, batches, loaded = drive(policy_name, engine_name)
    uids = [e.uid for b, _ in batches for e in b]
    assert len(uids) == len(set(uids)), "an entry trained twice"
    if policy_name == "ungrouped":
        # streaming: trained == consumed; everything else admitted is
        # still live in the buffer (nothing silently dropped)
        consumed = {u for u, e in orch.buffer.entries.items()
                    if e.state == EntryState.CONSUMED}
        assert set(uids) == consumed
        assert len(uids) + sum(
            e.state != EntryState.CONSUMED
            for e in orch.buffer.entries.values()) == loaded
    else:
        assert sorted(uids) == list(range(loaded)), \
            "every loaded prompt must be trained exactly once"


def test_curriculum_ordering(policy_name, engine_name):
    orch, batches, _ = drive(policy_name, engine_name)
    assert batches, "policy produced no updates"
    policy = orch.policy
    if not policy.ordered_training:
        return   # baseline shuffles by design
    for b, _ in batches:
        keys = [policy.train_order_key(e) for e in b]
        assert keys == sorted(keys), \
            f"batch not monotone in train_order_key: {keys}"


def test_no_starvation(policy_name, engine_name):
    orch, batches, loaded = drive(policy_name, engine_name)
    # the engine must end drained and the workload must not wedge
    assert orch.engine.free_slots() == orch.engine.capacity
    if policy_name == "ungrouped":
        return   # starves long prompts by design (the §4.4.2 collapse)
    assert orch.buffer.group_clear()
    trained = [e for b, _ in batches for e in b]
    assert len(trained) == loaded
    # every owed update was delivered (update_batch divides the workload);
    # relaxed-barrier policies may split leftovers at group boundaries
    delivered = orch.metrics.updates + orch.metrics.updates_gated
    if orch.policy.strict_group_barrier:
        assert delivered == loaded // CAPACITY
    else:
        assert delivered >= loaded // CAPACITY


def test_group_barrier(policy_name, engine_name):
    orch, batches, _ = drive(policy_name, engine_name)
    if policy_name == "ungrouped":
        return   # explicitly barrier-free
    lifecycles = [e.lifecycle for b, _ in batches for e in b]
    assert lifecycles == sorted(lifecycles), \
        "a later group trained before an earlier one"
    if orch.policy.strict_group_barrier:
        for b, epoch in batches:
            assert all(e.lifecycle == epoch for e in b), \
                "strict policy mixed group epochs inside a run"


def test_buffer_invariants_throughout(policy_name, engine_name):
    orch, _, _ = drive(policy_name, engine_name)
    orch.buffer.check_invariants()


# -- update-gate mechanics (PipelineRL-style off-policy cap) ------------------

def test_update_gate_consumes_without_training():
    # max_staleness=-1: every non-final batch is "too stale" and vetoed
    orch, batches = build("length_binned", "sim", max_staleness=-1.0)
    orch.run_group(prompts(N_PROMPTS))
    assert orch.metrics.updates_gated > 0
    assert orch.metrics.updates + orch.metrics.updates_gated == GROUP
    # conservation of consumption holds even for vetoed batches
    assert orch.buffer.group_clear()
    trained = [e for b, _ in batches for e in b]
    assert len(trained) < N_PROMPTS           # something was vetoed
    # version only advances on trained updates
    assert orch.version == orch.metrics.updates


def test_gate_passes_when_within_cap():
    orch, batches = build("length_binned", "sim", max_staleness=1e9)
    orch.run_group(prompts(N_PROMPTS))
    assert orch.metrics.updates_gated == 0
    assert sum(len(b) for b, _ in batches) == N_PROMPTS


def test_ungrouped_without_stream_terminates():
    orch, batches = build("ungrouped", "sim", prompt_stream=None)
    orch.run_steps(n_updates=3)     # no stream, no prompts: returns
    assert batches == []
