"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig1a_breakdown/*   latency breakdown (rollout dominance, Fig. 1a/1c)
  fig5_throughput/*   throughput + bubble ratio per strategy (Fig. 5, Eq. 4)
  fig6a_ablation/*    grouped-rollout / post-hoc-sort ablations (Fig. 6a)
  fig6b_group_size/*  group-size sensitivity (Fig. 6b)
  fig3_logic_rl/*     real RL token-efficiency on K&K (Fig. 3, quick mode)
  roofline_table/*    per (arch x shape) roofline terms (§Roofline)

Full-scale variants: bench_logic_rl --full, repro.launch.dryrun --all.

``--smoke``: seconds-scale pass (reduced simulator workloads, no jit-heavy
roofline or real-RL sections) — the default verification path; full runs
are opt-in.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_ablation, bench_breakdown, bench_logic_rl,
                            bench_throughput, roofline)
    smoke = "--smoke" in sys.argv
    if smoke:
        sections = (("breakdown", bench_breakdown.main),
                    ("throughput", lambda: bench_throughput.main(smoke=True)),
                    ("ablation", bench_ablation.main))
    else:
        sections = (("breakdown", bench_breakdown.main),
                    ("throughput", bench_throughput.main),
                    ("ablation", bench_ablation.main),
                    ("roofline", roofline.main))
    rows = []
    for mod, fn in sections:
        t0 = time.time()
        rows.extend(fn())
        print(f"# {mod} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if "--skip-rl" not in sys.argv and not smoke:
        t0 = time.time()
        rows.extend(bench_logic_rl.main(quick=True))
        print(f"# logic_rl done in {time.time()-t0:.1f}s", file=sys.stderr)
    print("name,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
