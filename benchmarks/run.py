"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig1a_breakdown/*   latency breakdown (rollout dominance, Fig. 1a/1c)
  fig5_throughput/*   throughput + bubble ratio per strategy (Fig. 5, Eq. 4)
  fig6a_ablation/*    grouped-rollout / post-hoc-sort ablations (Fig. 6a)
  fig6b_group_size/*  group-size sensitivity (Fig. 6b)
  fill_policy/*       beyond-paper slot-fill study
  policy_sweep/*      every registered SchedulerPolicy, by name
  fig3_logic_rl/*     real RL token-efficiency on K&K (Fig. 3, quick mode)
  roofline_table/*    per (arch x shape) roofline terms (§Roofline)

Full-scale variants: bench_logic_rl --full, repro.launch.dryrun --all.

``--smoke``: seconds-scale pass (reduced simulator workloads, no jit-heavy
roofline or real-RL sections) — the default verification path; full runs
are opt-in.  The smoke pass sweeps every registered scheduling policy by
name and runs examples/quickstart.py end to end, so a registry entry (or
the quickstart) that rots fails the smoke gate.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time


def quickstart_smoke_row() -> str:
    """Run examples/quickstart.py in a subprocess as a smoke check."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    t0 = time.time()
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "examples", "quickstart.py")],
        capture_output=True, text=True, env=env, timeout=600)
    dt = time.time() - t0
    ok = (proc.returncode == 0
          and "micro-curriculum batch means:" in proc.stdout)
    if not ok:
        print(proc.stdout, file=sys.stderr)
        print(proc.stderr, file=sys.stderr)
        raise RuntimeError("examples/quickstart.py smoke check failed")
    return f"smoke/quickstart,{dt*1e6:.0f},ok=1"


def main() -> None:
    from benchmarks import (bench_ablation, bench_breakdown, bench_logic_rl,
                            bench_throughput, roofline)
    smoke = "--smoke" in sys.argv
    if smoke:
        # ablation.main carries the acceptance-pinned fig6a/6b rows AND the
        # all-registered-policies sweep
        sections = (("breakdown", bench_breakdown.main),
                    ("throughput", lambda: bench_throughput.main(smoke=True)),
                    ("ablation", bench_ablation.main),
                    ("quickstart", lambda: [quickstart_smoke_row()]))
    else:
        sections = (("breakdown", bench_breakdown.main),
                    ("throughput", bench_throughput.main),
                    ("ablation", bench_ablation.main),
                    ("quickstart", lambda: [quickstart_smoke_row()]),
                    ("roofline", roofline.main))
    rows = []
    for mod, fn in sections:
        t0 = time.time()
        rows.extend(fn())
        print(f"# {mod} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if "--skip-rl" not in sys.argv and not smoke:
        t0 = time.time()
        rows.extend(bench_logic_rl.main(quick=True))
        print(f"# logic_rl done in {time.time()-t0:.1f}s", file=sys.stderr)
    print("name,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
